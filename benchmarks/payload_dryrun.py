import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""FCF-on-mesh dry-run: the paper's technique measured at the HLO level on
the paper's own model, at production item counts (Table 1 scale).

Setting: the item-factor matrix Q (M x K) is the ENTIRE model (unlike an
LLM, where vocab tables are <2% of weights and are model-sharded anyway —
see the refuted LLM-payload iteration in §Perf). Clients = data-parallel
shards; each round every client solves its users' p_i against Q* and the
per-round gradient aggregation is the data-axis all-reduce. Payload
selection shrinks exactly that collective:

  full:     all-reduce of dQ  (M x K)      — the paper's Table-1 payload
  selected: all-reduce of dQ* (M_s x K)    — 90% smaller at keep=0.1

Run:  PYTHONPATH=src python -m benchmarks.payload_dryrun --items 1000000
"""
import argparse
import json
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels import ops
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import batch_axes, make_production_mesh

from benchmarks.common import results_path


def full_round(q, x, lr=0.01):
    """One FCF round: cohort gradients (Eqs. 5-6) -> SGD step on Q."""
    from repro.cf.local import solve_user_factors
    p = solve_user_factors(q, x)
    grads = ops.fcf_item_gradients(q, p, x)          # (M, K) summed over users
    return q - lr * grads


def payload_round(q, x, sel, lr=0.01):
    """Paper round: only Q*[sel] moves; gradient collective is (M_s, K)."""
    from repro.cf.local import solve_user_factors
    q_star = q[sel]                                   # payload download
    x_star = x[:, sel]
    p = solve_user_factors(q_star, x_star)
    grads = ops.fcf_item_gradients(q_star, p, x_star)   # (M_s, K)
    return q.at[sel].add(-lr * grads)


def lower_one(name, fn, args, shardings, mesh):
    with mesh:
        jitted = jax.jit(fn, in_shardings=shardings)
        compiled = jitted.lower(*args).compile()
    coll = collective_bytes(compiled.as_text())
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return {"variant": name, "collective_bytes": coll,
            "flops": float(cost.get("flops", 0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0))}


def run(items: int = 1_000_000, factors: int = 25, theta: int = 1024,
        keep: float = 0.10, multi_pod: bool = False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    baxes = batch_axes(mesh)
    m_s = int(keep * items) // 16 * 16

    q = jax.ShapeDtypeStruct((items, factors), jnp.float32)
    x = jax.ShapeDtypeStruct((theta, items), jnp.float32)
    sel = jax.ShapeDtypeStruct((m_s,), jnp.int32)
    ns = lambda s: NamedSharding(mesh, s)
    # Q replicated (every client holds the payload); users over data
    recs = [
        lower_one("fcf_full", full_round, (q, x),
                  (ns(P()), ns(P(baxes))), mesh),
        lower_one("fcf_payload_10pct", payload_round, (q, x, sel),
                  (ns(P()), ns(P(baxes)), ns(P())), mesh),
    ]
    out = {"items": items, "factors": factors, "theta": theta, "keep": keep,
           "mesh": "pod2x16x16" if multi_pod else "pod16x16",
           "variants": recs}
    path = results_path("payload_dryrun",
                        f"fcf_{items}_{out['mesh']}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)

    print(f"\n## FCF payload dry-run — M={items:,} items, K={factors}, "
          f"Theta={theta}, mesh={out['mesh']}\n")
    base = recs[0]["collective_bytes"]["total"]
    for r in recs:
        t = r["collective_bytes"]["total"]
        print(f"{r['variant']:<22} collective {t / 1e6:10.1f} MB/device   "
              f"({100 * t / max(base, 1):5.1f}% of full)")
    return out


def dry_run(items: int = 1_000_000, factors: int = 25,
            keep: float = 0.10) -> Dict:
    """Payload arithmetic only — no mesh construction, no HLO lowering."""
    from repro.compress import CodecConfig, wire_bytes

    m_s = int(keep * items) // 16 * 16
    full = wire_bytes(CodecConfig(name="fp32"), items, factors)
    sel = wire_bytes(CodecConfig(name="fp32"), m_s, factors)
    print(f"[dry-run] payload_dryrun — M={items:,}: full collective "
          f"{full / 1e6:.1f} MB, keep={keep:.2f} -> {sel / 1e6:.1f} MB "
          f"({100 * sel / full:.1f}%); no lowering performed")
    return {"dry_run": True, "full_bytes": full, "selected_bytes": sel}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=int, default=1_000_000)
    ap.add_argument("--theta", type=int, default=1024)
    ap.add_argument("--keep", type=float, default=0.10)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true",
                    help="payload byte math only; skip mesh + HLO lowering")
    args = ap.parse_args(argv)
    if args.dry_run:
        return dry_run(args.items, keep=args.keep)
    return run(args.items, theta=args.theta, keep=args.keep,
               multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
