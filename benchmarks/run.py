"""Benchmark orchestrator — one section per paper table/figure plus the
deliverable reports. Default scale finishes on a CPU container; --full
switches the FCF grid to paper-sized datasets and the full level sweep.

  PYTHONPATH=src python -m benchmarks.run [--full | --dry-run]
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale FCF grid (hours)")
    ap.add_argument("--skip-fcf", action="store_true",
                    help="only the arithmetic/kernel/roofline sections")
    ap.add_argument("--dry-run", action="store_true",
                    help="run every section's dry-run smoke, execute nothing")
    args = ap.parse_args(argv)

    from benchmarks import (async_cohorts, convergence, fault_tolerance,
                            fcf_experiments, kernel_bench, obs_overhead,
                            optimizer_state, payload_compression,
                            payload_table, reduction_sweep, roofline,
                            serving, sharded_rounds, table4)

    t0 = time.time()
    print("=" * 72)
    print("repro benchmarks — FCF-BTS payload optimization (RecSys'21)")
    print("=" * 72)

    if args.dry_run:
        payload_table.main(["--dry-run"])
        kernel_bench.main(["--dry-run"])
        fcf_experiments.main(["--dry-run"])
        reduction_sweep.main(["--dry-run"])
        table4.main(["--dry-run"])
        convergence.main(["--dry-run"])
        payload_compression.main(["--dry-run"])
        sharded_rounds.main(["--dry-run"])
        async_cohorts.main(["--dry-run"])
        fault_tolerance.main(["--dry-run"])
        optimizer_state.main(["--dry-run"])
        serving.main(["--dry-run"])
        obs_overhead.main(["--dry-run"])
        roofline.main(["--dry-run"])
        print(f"\n[dry-run] all sections smoke-checked in "
              f"{time.time() - t0:.1f}s")
        return

    payload_table.run()
    kernel_bench.run()

    if not args.skip_fcf:
        scale = fcf_experiments.FULL if args.full else fcf_experiments.QUICK
        levels = (reduction_sweep.PAPER_LEVELS if args.full
                  else reduction_sweep.QUICK_LEVELS)
        reduction_sweep.run(scale, levels)
        table4.run(scale)
        convergence.run(scale)
        if args.full:
            # full scale regenerates the committed Pareto artifact
            payload_compression.run()
        else:
            # default CPU scale: smaller grid, don't clobber the artifact
            payload_compression.run(rounds=60, theta=30, keeps=(0.10,),
                                    time_rounds=20, out_path=None)

    # sharded engine scaling (spawns fake-device workers; CPU-sized grid)
    sharded_rounds.run(quick=not args.full)

    if args.full:
        # full scale regenerates the committed staleness-curve artifact
        async_cohorts.run()
    else:
        async_cohorts.run_quick()

    # fault tolerance: quality under dropout, corruption pricing, resume
    if args.full:
        fault_tolerance.run()     # regenerates BENCH_fault_tolerance.json
    else:
        fault_tolerance.run_quick()

    # optimizer-state compression: resident footprint, throughput, parity
    if args.full:
        optimizer_state.run()     # regenerates BENCH_optimizer_state.json
    else:
        optimizer_state.run_quick()

    # serving read path: fused compressed scoring vs the dense baseline
    if args.full:
        serving.run()                     # regenerates BENCH_serving.json
    else:
        serving.run(item_scales=(8192,), batches=(8, 64), iters=5,
                    out_path=None)

    # in-loop telemetry cost: enabled-vs-disabled scan engine throughput
    obs_overhead.run(quick=not args.full)

    roofline.run(mesh="pod16x16")
    roofline.run(mesh="pod2x16x16")

    print(f"\ntotal benchmark wall time: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
