"""Benchmark orchestrator — one section per paper table/figure plus the
deliverable reports. Default scale finishes on a CPU container; --full
switches the FCF grid to paper-sized datasets and the full level sweep.

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale FCF grid (hours)")
    ap.add_argument("--skip-fcf", action="store_true",
                    help="only the arithmetic/kernel/roofline sections")
    args = ap.parse_args()

    from benchmarks import (convergence, fcf_experiments, kernel_bench,
                            payload_table, reduction_sweep, roofline, table4)

    t0 = time.time()
    print("=" * 72)
    print("repro benchmarks — FCF-BTS payload optimization (RecSys'21)")
    print("=" * 72)

    payload_table.run()
    kernel_bench.run()

    if not args.skip_fcf:
        scale = fcf_experiments.FULL if args.full else fcf_experiments.QUICK
        levels = (reduction_sweep.PAPER_LEVELS if args.full
                  else reduction_sweep.QUICK_LEVELS)
        reduction_sweep.run(scale, levels)
        table4.run(scale)
        convergence.run(scale)

    roofline.run(mesh="pod16x16")
    roofline.run(mesh="pod2x16x16")

    print(f"\ntotal benchmark wall time: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
