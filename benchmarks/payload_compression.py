"""Bytes-vs-quality Pareto sweep over (strategy x codec x payload fraction).

The paper reduces payload along ONE axis — which rows move (bandit
selection, ~90% fewer rows at keep=0.1). The compression subsystem adds
the second axis — bits per row. This benchmark charts the joint frontier:
for each (strategy, codec, keep_fraction) cell it runs the scan engine,
then reports

  * bytes/round (down + up, priced by ``compress.wire_bytes`` — the same
    accounting the engine's traced counters use),
  * reduction vs the paper's reference point (FCF full payload, fp32),
  * reduction vs the SAME selection level in fp32 (the pure codec win),
  * precision@10 / F1 degradation vs the full-fp32 upper bound,
  * steady-state rounds/sec of the compiled engine (is the codec free?).

Headline rows (asserted, persisted to ``BENCH_payload_compression.json``):
the paper's ~90% reduction cell (bts, fp32, keep=0.1) and how far
int8+BTS pushes beyond it (>= 4x the bytes-reduction at matched payload
fraction, i.e. combining both axes).

Usage:  PYTHONPATH=src python -m benchmarks.payload_compression
        [--quick] [--dry-run] [--dataset movielens-mini]
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import CODECS, CodecConfig, direction_configs, wire_bytes
from repro.data.synthetic import load_dataset
from repro.federated.simulation import (
    FLSimConfig, run_fcf_simulation, _build, _make_round_fn, _num_select,
)

from benchmarks.common import markdown_table

OUT_PATH = "BENCH_payload_compression.json"

STRATEGIES = ("bts", "random")
KEEPS = (0.10, 0.25)
# the third payload axis (ROADMAP follow-up): how much of each row the topk
# uplink keeps. 0.25 is the codec's default; the sweep charts the frontier.
TOPK_FRACTIONS = (0.125, 0.25, 0.5)


def _variants(codecs: Sequence[str],
              topk_fractions: Sequence[float]) -> List[Dict]:
    """Expand the codec list into sweep cells.

    ``topk`` fans out over ``topk_fractions`` (labelled ``topk@f``) and
    ``int4`` gains an error-feedback twin (``int4+ef`` — the uplink carries
    the quantization residual forward, same mechanism as topk's EF).
    """
    out: List[Dict] = []
    for codec in codecs:
        if codec == "topk":
            for f in topk_fractions:
                out.append({"codec": codec, "label": f"topk@{f:g}",
                            "kwargs": {"codec_topk_fraction": f}})
        elif codec == "int4":
            out.append({"codec": codec, "label": "int4", "kwargs": {}})
            out.append({"codec": codec, "label": "int4+ef",
                        "kwargs": {"codec_int4_error_feedback": True}})
        else:
            out.append({"codec": codec, "label": codec, "kwargs": {}})
    return out


def _per_round_bytes(cfg: FLSimConfig, num_items: int) -> Dict[str, int]:
    """Bytes/round for one cell — the engine's own row count (_num_select)
    and wire pricing (compress.wire_bytes), so this can't drift from the
    simulation's traced counters."""
    codec_cfg = CodecConfig(name=cfg.codec,
                            topk_fraction=cfg.codec_topk_fraction,
                            error_feedback=cfg.codec_error_feedback,
                            int4_error_feedback=cfg.codec_int4_error_feedback)
    down_cfg, up_cfg = direction_configs(codec_cfg)
    m_s = _num_select(cfg, num_items)
    down = wire_bytes(down_cfg, m_s, cfg.num_factors)
    up = wire_bytes(up_cfg, m_s, cfg.num_factors) * cfg.theta
    return {"down": down, "up": up, "total": down + up}


def _rounds_per_sec(train, test, cfg: FLSimConfig, rounds: int = 60) -> float:
    """Steady-state scan throughput of the codec-routed engine."""
    train_j = jnp.asarray(train, jnp.float32)
    setup = _build(train_j, jnp.asarray(test, jnp.float32), cfg)
    round_fn = _make_round_fn(train_j, setup)

    def scan_chunk(state, cohorts):
        def body(st, cohort):
            st, _ = round_fn(st, cohort)
            return st, None
        return jax.lax.scan(body, state, cohorts)

    run_chunk = jax.jit(scan_chunk)
    cohorts = jnp.asarray(
        np.resize(setup.cohorts, (rounds,) + setup.cohorts.shape[1:]))
    state, _ = run_chunk(setup.state0, cohorts)        # warmup / compile
    jax.block_until_ready(state.q)
    best = 0.0
    for _ in range(2):
        t0 = time.perf_counter()
        state, _ = run_chunk(setup.state0, cohorts)
        jax.block_until_ready(state.q)
        best = max(best, rounds / (time.perf_counter() - t0))
    return best


def run(dataset: str = "movielens-mini", rounds: int = 200, theta: int = 50,
        strategies: Sequence[str] = STRATEGIES,
        codecs: Sequence[str] = CODECS,
        keeps: Sequence[float] = KEEPS,
        topk_fractions: Sequence[float] = TOPK_FRACTIONS,
        time_rounds: int = 60, seed: int = 0,
        out_path: Optional[str] = OUT_PATH) -> Dict:
    spec, train, test = load_dataset(dataset, seed=seed)
    num_items = train.shape[1]
    base = FLSimConfig(rounds=rounds, theta=theta, eval_every=max(rounds // 8, 1),
                       eval_users=min(256, train.shape[0]), seed=seed)

    # the paper's reference point: FCF full payload, fp32 wire
    full_cfg = replace(base, strategy="full", keep_fraction=1.0)
    full_res = run_fcf_simulation(train, test, full_cfg)
    full_bytes = _per_round_bytes(full_cfg, num_items)["total"]
    full_p10 = full_res.final["precision"]
    full_f1 = full_res.final["f1"]

    cells: List[Dict] = []
    for strategy in strategies:
        for keep in keeps:
            for var in _variants(codecs, topk_fractions):
                cfg = replace(base, strategy=strategy, keep_fraction=keep,
                              codec=var["codec"], **var["kwargs"])
                t0 = time.time()
                res = run_fcf_simulation(train, test, cfg)
                secs = time.time() - t0
                rps = _rounds_per_sec(train, test, cfg, rounds=time_rounds)
                per_round = _per_round_bytes(cfg, num_items)
                fp32_same = _per_round_bytes(
                    replace(cfg, codec="fp32"), num_items)["total"]
                cells.append({
                    "strategy": strategy, "codec": var["label"],
                    "codec_base": var["codec"], "keep": keep,
                    "topk_fraction": cfg.codec_topk_fraction
                    if var["codec"] == "topk" else None,
                    "int4_error_feedback": cfg.codec_int4_error_feedback,
                    "precision_at_10": res.final["precision"],
                    "f1": res.final["f1"], "map": res.final["map"],
                    "bytes_per_round": per_round,
                    "bytes_down_total": res.bytes_down,
                    "bytes_up_total": res.bytes_up,
                    "rounds_per_sec": rps,
                    "reduction_vs_full_fp32":
                        full_bytes / per_round["total"],
                    "reduction_vs_same_keep_fp32":
                        fp32_same / per_round["total"],
                    "precision_drop_pct_vs_full": 100.0 * (
                        1.0 - res.final["precision"] / max(full_p10, 1e-9)),
                    "f1_drop_pct_vs_full": 100.0 * (
                        1.0 - res.final["f1"] / max(full_f1, 1e-9)),
                    "sim_seconds": secs,
                })

    def cell(strategy, codec, keep):
        for c in cells:
            if (c["strategy"], c["codec"], c["keep"]) == (strategy, codec, keep):
                return c
        return None

    paper_row = cell("bts", "fp32", 0.10)
    int8_row = cell("bts", "int8", 0.10)
    headline = {
        "full_fp32_bytes_per_round": full_bytes,
        "full_fp32_precision_at_10": full_p10,
        "full_fp32_f1": full_f1,
        # the paper's Table-4 row: ~90% payload reduction from selection
        "paper_row_reduction_vs_full": paper_row["reduction_vs_full_fp32"]
        if paper_row else None,
        # the new joint-axis row: selection x int8 quantization
        "int8_bts_reduction_vs_full": int8_row["reduction_vs_full_fp32"]
        if int8_row else None,
        "int8_bts_reduction_vs_same_keep_fp32":
            int8_row["reduction_vs_same_keep_fp32"] if int8_row else None,
        "int8_bts_precision_drop_pct_vs_full":
            int8_row["precision_drop_pct_vs_full"] if int8_row else None,
    }

    out = {
        "dataset": {"name": spec.name, "users": int(train.shape[0]),
                    "items": int(num_items)},
        "config": {"rounds": rounds, "theta": theta,
                   "num_factors": base.num_factors, "seed": seed},
        "headline": headline,
        "cells": cells,
    }

    print(f"\n## Payload compression Pareto — {spec.name} "
          f"(M={num_items}, K={base.num_factors}, Theta={theta}, "
          f"{rounds} rounds; full-fp32: P@10={full_p10:.4f}, "
          f"{full_bytes / 1e3:.1f} KB/round)\n")
    rows = []
    for c in sorted(cells, key=lambda c: -c["reduction_vs_full_fp32"]):
        rows.append((
            c["strategy"], c["codec"], f"{c['keep']:.2f}",
            f"{c['bytes_per_round']['total'] / 1e3:.1f}",
            f"{c['reduction_vs_full_fp32']:.1f}x",
            f"{c['precision_at_10']:.4f}",
            f"{c['precision_drop_pct_vs_full']:+.1f}%",
            f"{c['rounds_per_sec']:.0f}",
        ))
    print(markdown_table(
        ("strategy", "codec", "keep", "KB/round", "vs full fp32",
         "P@10", "P@10 drop", "rounds/s"), rows))
    if paper_row and int8_row:
        print(f"\npaper row (bts, fp32, keep=0.10): "
              f"{paper_row['reduction_vs_full_fp32']:.1f}x fewer bytes "
              f"({100 * (1 - 1 / paper_row['reduction_vs_full_fp32']):.0f}% "
              f"reduction)")
        print(f"int8+BTS  (bts, int8, keep=0.10): "
              f"{int8_row['reduction_vs_full_fp32']:.1f}x fewer bytes, "
              f"P@10 drop {int8_row['precision_drop_pct_vs_full']:+.1f}% "
              f"(target >= 4x)")
        assert int8_row["reduction_vs_full_fp32"] >= 4.0, \
            "int8+BTS must cut bytes/round by >= 4x at matched fraction"

    if out_path:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=1, default=float)
        print(f"\nwrote {out_path}")
    return out


def dry_run() -> Dict:
    """Accounting-only smoke: no simulations, just the byte math."""
    base = FLSimConfig(rounds=1, theta=50)
    num_items = 300
    rows = []
    variants = _variants(CODECS, TOPK_FRACTIONS)
    for var in variants:
        cfg = replace(base, strategy="bts", keep_fraction=0.1,
                      codec=var["codec"], **var["kwargs"])
        b = _per_round_bytes(cfg, num_items)
        rows.append((var["label"], b["down"], b["up"], b["total"]))
    print("\n[dry-run] payload_compression — bytes/round at M=300, "
          "K=25, Theta=50, keep=0.10\n")
    print(markdown_table(("codec", "down B", "up B", "total B"), rows))
    return {"dry_run": True, "cells_planned":
            len(STRATEGIES) * len(variants) * len(KEEPS) + 1}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="movielens-mini")
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--quick", action="store_true",
                    help="fewer cells / rounds for smoke runs")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the planned grid + byte math, run nothing")
    args = ap.parse_args(argv)
    if args.dry_run:
        return dry_run()
    if args.quick:
        return run(dataset=args.dataset, rounds=40, theta=20,
                   strategies=("bts",), keeps=(0.10,), time_rounds=20,
                   out_path=None)
    return run(dataset=args.dataset, rounds=args.rounds)


if __name__ == "__main__":
    main()
