"""Observability overhead: scan-engine rounds/sec with telemetry on vs off.

The obs layer's hard contract is zero overhead when DISABLED (bit-identical
trajectories, enforced in tests/test_obs.py). This bench prices the ENABLED
path: the traced :func:`repro.obs.telemetry.telemetry_round` update plus one
batched ``io_callback`` per compiled chunk, measured as steady-state
rounds/sec of the default scan engine with and without an active
:class:`repro.obs.ObsConfig`.

The telemetry update is O(num_arms) scatter-adds and a top-k against an
O(theta * m_s * k) round body, so the enabled path should stay within a
modest factor of the disabled one; the ``--dry-run`` smoke asserts it does
at toy scale (>= 0.3x — generous, CPU dry-runs are noisy) and the full run
reports the measured ratio at MIND-like scale.

Usage:  PYTHONPATH=src python -m benchmarks.obs_overhead [--quick] [--dry-run]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional, Sequence

import jax
import numpy as np

from repro.federated.simulation import FLSimConfig, run_fcf_simulation
from repro.obs import InMemorySink, ObsConfig

from benchmarks.common import markdown_table
from benchmarks.round_engine import make_data

REPEATS = 3
# dry-run floor for enabled/disabled rounds-per-sec; deliberately loose —
# it guards against pathological overhead (a sync per round, an unbatched
# callback), not against CPU timing noise
DRY_RUN_MIN_RATIO = 0.3


def _time_sim(train, test, cfg: FLSimConfig) -> float:
    """Best-of steady-state rounds/sec of one full simulation run."""
    run_fcf_simulation(train, test, cfg)          # warmup / compile
    best = 0.0
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        result = run_fcf_simulation(train, test, cfg)
        jax.block_until_ready(result.server_state.q)
        best = max(best, cfg.rounds / (time.perf_counter() - t0))
    return best


def measure(users: int, items: int, rounds: int,
            telemetry_every: int = 1, seed: int = 0) -> Dict:
    train, test = make_data(users, items, seed=seed)
    base = dict(strategy="bts", keep_fraction=0.1,
                theta=min(100, users), num_factors=25,
                rounds=rounds, eval_every=10 * rounds, seed=seed)
    rps_off = _time_sim(train, test, FLSimConfig(**base))
    sink = InMemorySink()
    rps_on = _time_sim(train, test, FLSimConfig(
        **base, obs=ObsConfig(enabled=True, sink=sink,
                              telemetry_every=telemetry_every)))
    expected = len([t for t in range(1, rounds + 1)
                    if t == 1 or t % telemetry_every == 0])
    events_per_run = len(sink.events) // (REPEATS + 1)   # warmup + repeats
    assert events_per_run == expected, \
        f"expected {expected} telemetry events/run, got {events_per_run}"
    return {
        "users": users, "items": items, "rounds": rounds,
        "telemetry_every": telemetry_every,
        "disabled_rounds_per_sec": rps_off,
        "enabled_rounds_per_sec": rps_on,
        "enabled_over_disabled": rps_on / rps_off,
    }


def run(quick: bool = False) -> Dict:
    users, items = (1000, 2000) if quick else (5000, 10_000)
    rounds = 50 if quick else 100
    rows = []
    out: Dict = {"scale": {"users": users, "items": items, "k": 25,
                           "keep_fraction": 0.1},
                 "cells": []}
    for every in (1, 10):
        cell = measure(users, items, rounds, telemetry_every=every)
        out["cells"].append(cell)
        rows.append((f"every={every}",
                     f"{cell['disabled_rounds_per_sec']:.1f}",
                     f"{cell['enabled_rounds_per_sec']:.1f}",
                     f"{cell['enabled_over_disabled']:.2f}x"))
    print(f"\n## Telemetry overhead — scan engine rounds/sec "
          f"(M={items}, K=25)\n")
    print(markdown_table(
        ("telemetry", "disabled (r/s)", "enabled (r/s)", "ratio"), rows))
    return out


def dry_run() -> Dict:
    """Toy-scale smoke: telemetry-on must stay within a loose factor of off."""
    cell = measure(users=40, items=60, rounds=8, telemetry_every=1, seed=0)
    ratio = cell["enabled_over_disabled"]
    assert ratio >= DRY_RUN_MIN_RATIO, \
        (f"telemetry-enabled engine ran at {ratio:.2f}x the disabled "
         f"rounds/sec (floor {DRY_RUN_MIN_RATIO}x) — the in-loop path "
         "is adding pathological overhead")
    print(f"[dry-run] obs_overhead — 8 toy rounds: enabled runs at "
          f"{ratio:.2f}x disabled throughput (floor {DRY_RUN_MIN_RATIO}x)")
    return {"dry_run": True, **cell}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller scale for smoke runs")
    ap.add_argument("--dry-run", action="store_true",
                    help="toy-scale overhead smoke with a loose floor")
    args = ap.parse_args(argv)
    return dry_run() if args.dry_run else run(quick=args.quick)


if __name__ == "__main__":
    main()
