"""Fault tolerance: quality-under-dropout, corruption overhead, resume cost.

Three sections, one artifact (``BENCH_fault_tolerance.json``):

  * DROPOUT CURVES — for dropout in {0, 0.1, 0.3, 0.5} x {bts, random}
    run the scan engine on movielens-mini with the deterministic fault
    schedule dropping that fraction of each cohort (dropped clients are
    exact no-ops: gradients renormalized over survivors, bandit rewards
    attributed only to observed pulls). P@10 vs dropout, BTS against the
    random-selection baseline, answers whether payload *optimization*
    stays ahead of payload *sampling* when cohorts degrade — the paper's
    comparison under the failure mode real fleets actually have.
  * CORRUPTION / RETRANSMIT — with wire-payload bit corruption enabled,
    every uplink row carries a 4-byte checksum and corrupted rows are
    rejected into the error-feedback residual for retransmission. The
    section prices that: checksum overhead vs the clean uplink, plus the
    retransmit bytes actually burned (both from the traced in-state
    counters, not estimates).
  * CRASH-RESUME — run R rounds uninterrupted; run the same config with a
    simulated host crash mid-training plus checkpoints at eval
    boundaries; resume from the newest verified checkpoint. Reports the
    wall-clock overhead of crash+resume vs uninterrupted and asserts the
    two trajectories converge to IDENTICAL final metrics (the bit-parity
    contract tier-1 enforces on small cases, priced here at bench scale).

Usage:  PYTHONPATH=src python -m benchmarks.fault_tolerance [--quick|--dry-run]
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time
from dataclasses import replace
from typing import Dict, List, Optional, Sequence

import numpy as np

from benchmarks.common import markdown_table, per_round_payload_bytes

OUT_PATH = "BENCH_fault_tolerance.json"
DROPOUT_RATES = (0.0, 0.1, 0.3, 0.5)
STRATEGIES = ("bts", "random")
CORRUPT_RATES = (0.02, 0.1)


def _fault_cfg(**kw):
    from repro.faults import FaultConfig
    return FaultConfig(enabled=True, **kw)


def _counters(res) -> Dict[str, float]:
    """The traced FaultState counters off a finished run (zeros if off)."""
    faults = res.server_state.faults
    if faults == ():                        # faults disabled: () sentinel
        return {"dropped": 0.0, "stragglers": 0.0, "corrupt_rows": 0.0,
                "retransmit_bytes": 0.0}
    return {
        "dropped": float(faults.dropped),
        "stragglers": float(faults.stragglers),
        "corrupt_rows": float(faults.corrupt_rows),
        "retransmit_bytes": float(faults.retransmit_bytes),
    }


def run(dataset: str = "movielens-mini", rounds: int = 120, theta: int = 40,
        dropout_rates: Sequence[float] = DROPOUT_RATES,
        strategies: Sequence[str] = STRATEGIES,
        corrupt_rates: Sequence[float] = CORRUPT_RATES,
        codec: str = "int8", keep: float = 0.1, seed: int = 0,
        out_path: Optional[str] = OUT_PATH) -> Dict:
    from repro.data.synthetic import load_dataset
    from repro.faults import SimulatedCrash
    from repro.federated.simulation import FLSimConfig, run_fcf_simulation

    if not dropout_rates or dropout_rates[0] != 0.0:
        raise ValueError("dropout_rates must start with 0.0 (the clean "
                         "baseline the degradation curves are relative to)")
    spec, train, test = load_dataset(dataset, seed=seed)
    num_items = train.shape[1]
    num_select = max(1, int(round(keep * num_items)))
    base = FLSimConfig(rounds=rounds, theta=theta, keep_fraction=keep,
                       codec=codec, eval_every=max(rounds // 6, 1),
                       eval_users=min(256, train.shape[0]), seed=seed)
    theta_eff = min(theta, train.shape[0])
    bytes_pr = per_round_payload_bytes(num_select, base.num_factors,
                                       codec=codec, theta=theta_eff)

    # ---------------- dropout curves: P@10 vs dropout, bts vs random ----
    cells: List[Dict] = []
    clean_p10: Dict[str, float] = {}
    for strategy in strategies:
        for rate in dropout_rates:
            faults = _fault_cfg(dropout_rate=rate, seed=seed) \
                if rate > 0.0 else None
            cfg = replace(base, strategy=strategy, faults=faults)
            t0 = time.perf_counter()
            res = run_fcf_simulation(train, test, cfg)
            secs = time.perf_counter() - t0
            if rate == 0.0:
                clean_p10[strategy] = res.final["precision"]
            counters = _counters(res)
            cells.append({
                "strategy": strategy, "dropout_rate": rate,
                "precision_at_10": res.final["precision"],
                "f1": res.final["f1"], "map": res.final["map"],
                "p10_drop_pct_vs_clean": 100.0 * (
                    1.0 - res.final["precision"]
                    / max(clean_p10[strategy], 1e-9)),
                "dropped_per_round": counters["dropped"] / rounds,
                "rounds_per_sec": rounds / secs,
                "bytes_per_round": bytes_pr,
                "sim_seconds": secs,
            })

    # ---------------- corruption: checksum + retransmit byte overhead ---
    clean = run_fcf_simulation(train, test, replace(base, strategy="bts"))
    corruption_cells: List[Dict] = []
    for rate in corrupt_rates:
        cfg = replace(base, strategy="bts",
                      faults=_fault_cfg(corrupt_rate=rate, seed=seed))
        res = run_fcf_simulation(train, test, cfg)
        counters = _counters(res)
        corruption_cells.append({
            "corrupt_rate": rate,
            "precision_at_10": res.final["precision"],
            "bytes_up": res.bytes_up,
            "uplink_overhead_pct": 100.0 * (
                res.bytes_up / max(clean.bytes_up, 1) - 1.0),
            "corrupted_rows": counters["corrupt_rows"],
            "retransmit_bytes": counters["retransmit_bytes"],
        })

    # ---------------- crash-resume: overhead + identical trajectory -----
    resume_cfg = replace(base, strategy="bts",
                         faults=_fault_cfg(dropout_rate=0.1, seed=seed))
    t0 = time.perf_counter()
    uninterrupted = run_fcf_simulation(train, test, resume_cfg)
    uninterrupted_s = time.perf_counter() - t0
    crash_round = rounds // 2 + 1
    ckpt_dir = tempfile.mkdtemp(prefix="bench_ft_ckpt_")
    try:
        crashed_cfg = replace(
            resume_cfg, checkpoint_dir=ckpt_dir,
            faults=resume_cfg.faults._replace(crash_round=crash_round))
        t0 = time.perf_counter()
        try:
            run_fcf_simulation(train, test, crashed_cfg)
            raise RuntimeError("simulated crash never fired")
        except SimulatedCrash:
            pass
        crash_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        resumed = run_fcf_simulation(train, test, replace(
            resume_cfg, checkpoint_dir=ckpt_dir, resume_from=ckpt_dir))
        resume_s = time.perf_counter() - t0
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    # parity is a STATE contract: the resumed run's history is shorter by
    # construction (evals before the crash were already logged), so compare
    # the final table bitwise plus the last eval row — not rolling means
    # whose windows span different numbers of evals
    bit_identical = bool(
        np.array_equal(np.asarray(uninterrupted.server_state.q),
                       np.asarray(resumed.server_state.q))
        and all(uninterrupted.smoothed(k, 1) == resumed.smoothed(k, 1)
                for k in ("precision", "recall", "f1", "map")))
    resume_section = {
        "crash_round": crash_round, "rounds": rounds,
        "uninterrupted_seconds": uninterrupted_s,
        "crash_seconds": crash_s, "resume_seconds": resume_s,
        "overhead_pct": 100.0 * (
            (crash_s + resume_s) / max(uninterrupted_s, 1e-9) - 1.0),
        "resume_rounds_per_sec": rounds / max(resume_s, 1e-9),
        "bit_identical": bit_identical,
    }
    assert bit_identical, \
        "crash+resume diverged from the uninterrupted trajectory"

    worst = max(c["p10_drop_pct_vs_clean"] for c in cells
                if c["strategy"] == "bts")
    headline = {
        "bts_p10_drop_pct_at_max_dropout": worst,
        "max_uplink_overhead_pct": max(
            c["uplink_overhead_pct"] for c in corruption_cells),
        "resume_overhead_pct": resume_section["overhead_pct"],
        "resume_bit_identical": bit_identical,
    }

    out = {
        "dataset": {"name": spec.name, "users": int(train.shape[0]),
                    "items": int(num_items)},
        "config": {"rounds": rounds, "theta": theta, "keep_fraction": keep,
                   "codec": codec, "num_factors": base.num_factors,
                   "seed": seed},
        "headline": headline,
        "dropout_cells": cells,
        "corruption_cells": corruption_cells,
        "resume": resume_section,
    }

    print(f"\n## Fault tolerance — P@10 vs dropout, corruption overhead, "
          f"crash-resume ({spec.name}: M={num_items}, Theta={theta}, "
          f"{codec}, {rounds} rounds)\n")
    rows = [(c["strategy"], c["dropout_rate"],
             f"{c['precision_at_10']:.4f}",
             f"{c['p10_drop_pct_vs_clean']:+.1f}%",
             f"{c['dropped_per_round']:.1f}",
             f"{c['rounds_per_sec']:.0f}") for c in cells]
    print(markdown_table(("strategy", "dropout", "P@10", "vs clean",
                          "dropped/round", "rounds/s"), rows))
    print()
    rows = [(c["corrupt_rate"], f"{c['precision_at_10']:.4f}",
             f"{c['uplink_overhead_pct']:+.2f}%",
             int(c["corrupted_rows"]), int(c["retransmit_bytes"]))
            for c in corruption_cells]
    print(markdown_table(("corrupt rate", "P@10", "uplink overhead",
                          "rows rejected", "retransmit bytes"), rows))
    print(f"\ncrash at round {crash_round}/{rounds}: resume overhead "
          f"{resume_section['overhead_pct']:+.1f}% wall-clock, final "
          f"metrics bit-identical={bit_identical}")

    if out_path:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=1, default=float)
        print(f"\nwrote {out_path}")
    return out


def run_quick(dataset: str = "movielens-mini") -> Dict:
    """The one quick-smoke grid (CLI --quick and benchmarks.run both use
    this, so the two can't drift): bts only, dropout {0, 0.3}, no artifact."""
    return run(dataset=dataset, rounds=30, theta=20,
               dropout_rates=(0.0, 0.3), strategies=("bts",),
               corrupt_rates=(0.1,), out_path=None)


def dry_run() -> Dict:
    """No simulations: schedule determinism + checksum byte math only."""
    from repro.compress import (CHECKSUM_BYTES_PER_ROW, CodecConfig,
                                direction_configs, wire_bytes)
    from repro.faults import FaultConfig, build_fault_schedule

    cfg = FaultConfig(enabled=True, dropout_rate=0.3, straggler_rate=0.1,
                      corrupt_rate=0.05, seed=0)
    a = build_fault_schedule(cfg, rounds=400, cohort_size=50, num_select=30,
                             seed=0)
    b = build_fault_schedule(cfg, rounds=400, cohort_size=50, num_select=30,
                             seed=0)
    assert np.array_equal(a.survivors, b.survivors) \
        and np.array_equal(a.corrupt, b.corrupt), \
        "fault schedule must be deterministic in (config, seed)"
    drop_frac = 1.0 - a.survivors.mean()
    assert abs(drop_frac - (cfg.dropout_rate + cfg.straggler_rate)) < 0.02, \
        f"schedule removes {drop_frac:.3f}, configured 0.4"
    rows = []
    for codec in ("fp32", "int8", "topk"):
        _, up = direction_configs(CodecConfig(name=codec))
        per_row = wire_bytes(up, 1, 25)
        rows.append((codec, per_row,
                     f"{100.0 * CHECKSUM_BYTES_PER_ROW / per_row:.2f}%"))
    print("\n[dry-run] fault_tolerance — checksum overhead per uplink row "
          "(K=25) + schedule determinism\n")
    print(markdown_table(("codec", "row bytes", "checksum overhead"), rows))
    print(f"schedule check: {drop_frac:.3f} of cohort slots removed "
          f"(dropout 0.3 + straggler 0.1), corrupt draws "
          f"{a.corrupt.mean():.3f} vs rate {cfg.corrupt_rate}")
    return {"dry_run": True, "removed_fraction": float(drop_frac)}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="movielens-mini")
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--quick", action="store_true",
                    help="fewer cells / rounds for smoke runs")
    ap.add_argument("--dry-run", action="store_true",
                    help="schedule + byte math only, run nothing")
    args = ap.parse_args(argv)
    if args.dry_run:
        return dry_run()
    if args.quick:
        return run_quick(dataset=args.dataset)
    return run(dataset=args.dataset, rounds=args.rounds)


if __name__ == "__main__":
    main()
