"""Kernel + server-side-overhead microbenchmarks.

Times the production CPU paths (the Pallas kernels' jnp oracles; interpret
mode is a correctness harness, not a timing one) and the bandit server ops
at production arm counts — the paper's claim (iv): payload optimization
adds no client cost and negligible server cost.

CSV: name,us_per_call,derived

Usage:  PYTHONPATH=src python -m benchmarks.kernel_bench [--dry-run]
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.bandit import bts_init, bts_select, bts_update
from repro.kernels import ops

from benchmarks.common import time_fn


def dry_run() -> List[Dict]:
    """One tiny un-timed call per kernel path: catches import/shape rot."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (64, 25), jnp.float32)
    p = jax.random.normal(key, (8, 25), jnp.float32)
    x = (jax.random.uniform(key, (8, 64)) < 0.1).astype(jnp.float32)
    jax.block_until_ready(ops.fcf_item_gradients(q, p, x))
    table = jax.random.normal(key, (128, 32), jnp.float32)
    idx = jnp.arange(16, dtype=jnp.int32)
    jax.block_until_ready(ops.gather_rows(table, idx))
    # scatter ops donate their table: rebind so later calls see live buffers
    table = ops.scatter_add_rows(table, idx, jnp.ones((16, 32), jnp.float32))
    jax.block_until_ready(table)
    codes, scales = ops.gather_quantize_rows(table, idx)
    table = ops.dequant_scatter_set_rows(table, idx, codes, scales)
    jax.block_until_ready(table)
    state = bts_init(256, 0.0, 10_000.0)
    sel, _ = bts_select(state, key, 25)
    jax.block_until_ready(bts_update(
        state, sel, jnp.zeros((25,), jnp.float32)))
    print("[dry-run] kernel_bench — all kernel paths dispatched OK")
    return [{"name": "dry_run", "us_per_call": 0.0, "derived": "ok"}]


def run() -> List[Dict]:
    key = jax.random.PRNGKey(0)
    rows: List[Dict] = []

    def add(name, us, derived=""):
        rows.append({"name": name, "us_per_call": us, "derived": derived})

    # FCF fused item-gradient: cohort Theta=100 users, paper-scale items
    for m in (3064, 17632):
        n, k = 100, 25
        q = jax.random.normal(key, (m, k), jnp.float32)
        p = jax.random.normal(key, (n, k), jnp.float32)
        x = (jax.random.uniform(key, (n, m)) < 0.01).astype(jnp.float32)
        f = jax.jit(lambda q, p, x: ops.fcf_item_gradients(q, p, x))
        us = time_fn(f, q, p, x)
        flops = 2 * 2 * n * m * k      # residual matmul + grad matmul
        add(f"fcf_grad_m{m}", us, f"{flops / us / 1e3:.1f}GFLOP/s")

    # payload gather/scatter at LLM vocab scale
    table = jax.random.normal(key, (151_936, 256), jnp.float32)
    idx = jax.random.randint(key, (15_000,), 0, table.shape[0], jnp.int32)
    g = jax.jit(ops.gather_rows)
    us = time_fn(g, table, idx)
    add("gather_rows_150k_to_15k", us,
        f"{idx.shape[0] * table.shape[1] * 4 / us / 1e3:.1f}GB/s")
    rowsv = jax.random.normal(key, (15_000, 256), jnp.float32)
    s = jax.jit(ops.scatter_add_rows)
    us = time_fn(s, table, idx, rowsv)
    add("scatter_add_rows_15k", us)

    # fused payload compression kernels (int8 wire) at the same scale
    gq = jax.jit(ops.gather_quantize_rows)
    us = time_fn(gq, table, idx)
    add("gather_quantize_rows_15k", us,
        f"{idx.shape[0] * table.shape[1] * 4 / us / 1e3:.1f}GB/s-in")
    codes, scales = ops.gather_quantize_rows(table, idx)
    dq = jax.jit(ops.dequant_scatter_set_rows)
    us = time_fn(dq, table, idx, codes, scales)
    add("dequant_scatter_set_rows_15k", us)

    # flash attention oracle at a serving shape
    q = jax.random.normal(key, (1, 8, 1024, 128), jnp.float32)
    k_ = jax.random.normal(key, (1, 2, 1024, 128), jnp.float32)
    v = jax.random.normal(key, (1, 2, 1024, 128), jnp.float32)
    a = jax.jit(lambda q, k, v: ops.attention(q, k, v, causal=True))
    us = time_fn(a, q, k_, v)
    add("attention_gqa_1k", us,
        f"{4 * 1024 * 1024 * 8 * 128 / us / 1e3:.1f}GFLOP/s")

    # bandit server overhead at production arm counts (paper claim iv)
    for arms in (100_000, 1_000_000):
        state = bts_init(arms, 0.0, 10_000.0)
        sel = jax.jit(lambda s, k: bts_select(s, k, arms // 10))
        us = time_fn(sel, state, key)
        add(f"bts_select_{arms // 1000}k_arms", us,
            f"{arms / us:.0f}arms/us")
        idxs, _ = bts_select(state, key, arms // 10)
        rewards = jax.random.normal(key, (arms // 10,), jnp.float32)
        upd = jax.jit(bts_update)
        us = time_fn(upd, state, idxs, rewards)
        add(f"bts_update_{arms // 1000}k_arms", us)

    print("\n## Kernel / server microbenchmarks (CPU production paths)\n")
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    return rows


def main(argv: Optional[Sequence[str]] = None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="single tiny call per kernel, no timing")
    args = ap.parse_args(argv)
    return dry_run() if args.dry_run else run()


if __name__ == "__main__":
    main()
