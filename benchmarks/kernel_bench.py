"""Kernel + server-side-overhead microbenchmarks.

Times the production CPU paths (the Pallas kernels' jnp oracles; interpret
mode is a correctness harness, not a timing one) and the bandit server ops
at production arm counts — the paper's claim (iv): payload optimization
adds no client cost and negligible server cost.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core.bandit import bts_init, bts_select, bts_update
from repro.kernels import ops

from benchmarks.common import time_fn


def run() -> List[Dict]:
    key = jax.random.PRNGKey(0)
    rows: List[Dict] = []

    def add(name, us, derived=""):
        rows.append({"name": name, "us_per_call": us, "derived": derived})

    # FCF fused item-gradient: cohort Theta=100 users, paper-scale items
    for m in (3064, 17632):
        n, k = 100, 25
        q = jax.random.normal(key, (m, k), jnp.float32)
        p = jax.random.normal(key, (n, k), jnp.float32)
        x = (jax.random.uniform(key, (n, m)) < 0.01).astype(jnp.float32)
        f = jax.jit(lambda q, p, x: ops.fcf_item_gradients(q, p, x))
        us = time_fn(f, q, p, x)
        flops = 2 * 2 * n * m * k      # residual matmul + grad matmul
        add(f"fcf_grad_m{m}", us, f"{flops / us / 1e3:.1f}GFLOP/s")

    # payload gather/scatter at LLM vocab scale
    table = jax.random.normal(key, (151_936, 256), jnp.float32)
    idx = jax.random.randint(key, (15_000,), 0, table.shape[0], jnp.int32)
    g = jax.jit(ops.gather_rows)
    us = time_fn(g, table, idx)
    add("gather_rows_150k_to_15k", us,
        f"{idx.shape[0] * table.shape[1] * 4 / us / 1e3:.1f}GB/s")
    rowsv = jax.random.normal(key, (15_000, 256), jnp.float32)
    s = jax.jit(ops.scatter_add_rows)
    us = time_fn(s, table, idx, rowsv)
    add("scatter_add_rows_15k", us)

    # flash attention oracle at a serving shape
    q = jax.random.normal(key, (1, 8, 1024, 128), jnp.float32)
    k_ = jax.random.normal(key, (1, 2, 1024, 128), jnp.float32)
    v = jax.random.normal(key, (1, 2, 1024, 128), jnp.float32)
    a = jax.jit(lambda q, k, v: ops.attention(q, k, v, causal=True))
    us = time_fn(a, q, k_, v)
    add("attention_gqa_1k", us,
        f"{4 * 1024 * 1024 * 8 * 128 / us / 1e3:.1f}GFLOP/s")

    # bandit server overhead at production arm counts (paper claim iv)
    for arms in (100_000, 1_000_000):
        state = bts_init(arms, 0.0, 10_000.0)
        sel = jax.jit(lambda s, k: bts_select(s, k, arms // 10))
        us = time_fn(sel, state, key)
        add(f"bts_select_{arms // 1000}k_arms", us,
            f"{arms / us:.0f}arms/us")
        idxs, _ = bts_select(state, key, arms // 10)
        rewards = jax.random.normal(key, (arms // 10,), jnp.float32)
        upd = jax.jit(bts_update)
        us = time_fn(upd, state, idxs, rewards)
        add(f"bts_update_{arms // 1000}k_arms", us)

    print("\n## Kernel / server microbenchmarks (CPU production paths)\n")
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    return rows


if __name__ == "__main__":
    run()
