"""Paper Figure 2: recommendation performance vs payload reduction.

For each dataset, sweeps payload-reduction levels and compares FCF-BTS
against FCF-Random, with FCF (Original) as the upper bound and TopList as
the static baseline. Prints one markdown block per dataset and returns the
raw grid for EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
from typing import Dict, Optional, Sequence

from benchmarks.common import markdown_table
from benchmarks.fcf_experiments import (
    FULL, QUICK, GridScale, cell_key, ensure_cells, grid_mean,
    toplist_baseline,
)

# payload reduction % -> keep fraction (paper Sec. 7 grid)
PAPER_LEVELS = (25, 50, 75, 80, 85, 90, 95, 98)
QUICK_LEVELS = (50, 75, 90, 95)


def run(scale: GridScale = QUICK,
        levels: Sequence[int] = QUICK_LEVELS) -> Dict:
    out: Dict = {"scale": scale.name, "levels": list(levels), "datasets": {}}
    for ds in scale.datasets:
        full = grid_mean(ensure_cells(scale, ds, "full", 1.0))
        top = toplist_baseline(scale, ds, seed=0)["final"]
        rows = []
        ds_out = {"full": full, "toplist": top, "levels": {}}
        for lvl in levels:
            keep = 1.0 - lvl / 100.0
            bts = grid_mean(ensure_cells(scale, ds, "bts", keep))
            rnd = grid_mean(ensure_cells(scale, ds, "random", keep))
            ds_out["levels"][str(lvl)] = {"bts": bts, "random": rnd}
            rows.append((f"{lvl}%",
                         f"{bts['f1'][0]:.4f}±{bts['f1'][1]:.3f}",
                         f"{rnd['f1'][0]:.4f}±{rnd['f1'][1]:.3f}",
                         f"{100 * (bts['f1'][0] / max(rnd['f1'][0], 1e-9) - 1):+.1f}%"))
        print(f"\n## Figure 2 analogue — {ds} "
              f"(FCF full F1 = {full['f1'][0]:.4f}, "
              f"TopList F1 = {top['f1']:.4f})\n")
        print(markdown_table(
            ("payload cut", "FCF-BTS F1", "FCF-Random F1", "BTS vs Random"),
            rows))
        out["datasets"][ds] = ds_out
    return out


def dry_run(scale: GridScale = QUICK,
            levels: Sequence[int] = QUICK_LEVELS) -> Dict:
    cells = [cell_key(scale, ds, s, 1.0 - lvl / 100.0, 0)
             for ds in scale.datasets for lvl in levels
             for s in ("bts", "random")]
    print(f"[dry-run] reduction_sweep — would read {len(cells)} grid "
          f"points at scale '{scale.name}' (none executed)")
    return {"dry_run": True, "cells": cells}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick",
                    choices=("quick", "mid", "full"))
    ap.add_argument("--dry-run", action="store_true",
                    help="list the grid points, execute nothing")
    args = ap.parse_args(argv)
    from benchmarks.fcf_experiments import MID
    scale = {"quick": QUICK, "mid": MID, "full": FULL}[args.scale]
    levels = QUICK_LEVELS if args.scale == "quick" else PAPER_LEVELS
    return dry_run(scale, levels) if args.dry_run else run(scale, levels)


if __name__ == "__main__":
    main()
