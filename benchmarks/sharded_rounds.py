"""Sharded round engine: rounds/sec + bytes-moved-per-device vs mesh size.

Measures the ``backend="shard"`` engine (shard_map data-parallel FL rounds:
row-sharded tables, one cohort block per device, collective payload
movement) against the single-device ``backend="scan"`` baseline, for all
four strategies x {fp32, int8} wire formats at D in {1, 2, 4, 8} devices.

CPU has one physical device, and ``--xla_force_host_platform_device_count``
only takes effect before jax initializes — so every D runs in its own worker
subprocess with fake CPU devices. Fake devices share the host's cores:
rounds/sec at D>1 measures the *overhead* of the sharded program
(collectives + smaller per-device batches on shared silicon), not a
speedup — the speedup story is the per-device numbers: each device holds
1/D of every (M, K) table and solves 1/D of the cohort, while the bytes
crossing the interconnect stay payload-sized (reported here as
``collective_bytes_per_device_per_round``, where int8 cuts the dominant
downlink all-gather 4x).

Acceptance gates checked here: D=1 sharded within 10% of the plain scan
engine, and D=1 bit-parity with it (the D>1 parity matrix is tier-1:
``tests/test_sharded_rounds.py``).

Writes ``BENCH_sharded_rounds.json`` (schema shared with
``BENCH_round_engine.json``: every rounds/sec figure pairs with a
``bytes_per_round`` dict).

Usage:  PYTHONPATH=src python -m benchmarks.sharded_rounds [--quick|--dry-run]
        (internal)  ... --worker D
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, Optional, Sequence

import numpy as np

from benchmarks.common import markdown_table, per_round_payload_bytes

OUT_PATH = "BENCH_sharded_rounds.json"
WORKER_MARK = "SHARDED_WORKER_JSON:"
STRATEGIES = ("bts", "random", "magnitude", "full")
CODECS = ("fp32", "int8")
MESH_SIZES = (1, 2, 4, 8)
REPEATS = 3


def make_data(users: int, items: int, density: float = 0.02, seed: int = 0):
    rng = np.random.default_rng(seed)
    train = (rng.random((users, items)) < density).astype(np.float32)
    test = (rng.random((users, items)) < density / 4).astype(np.float32)
    return train, test


def _scale(quick: bool) -> Dict:
    users, items = (500, 2000) if quick else (2000, 10_000)
    return {"users": users, "items": items, "k": 25, "theta": 100,
            "keep_fraction": 0.1, "rounds": 20 if quick else 40}


def collective_bytes_per_device(strategy: str, codec: str, d: int,
                                num_select: int, k: int) -> int:
    """Bytes each device RECEIVES per round from the engine's collectives.

    Mirrors the implementation's schedule (see ``server_round_step``):
      * 1 all-gather of the *encoded* Q* candidates (the int8 wire moves
        codes + per-row f32 scales — 4x less than fp32 rows),
      * 1 all-gather of the (M_s, K) f32 partial gradients (ordered psum),
      * (M_s, K) f32 row gathers of the tables the round touches: 3 for the
        Adam commit (m, v, params), +2 for the BTS reward buffers, +1 for
        the topk codec residual. Scatters are shard-local (0 bytes).
    Each all-gather of an (M_s, .) candidate delivers the other D-1 shards'
    copies.
    """
    if d <= 1:
        return 0
    fp_rows = num_select * k * 4
    down = per_round_payload_bytes(num_select, k, codec=codec)["down"]
    row_gathers = 3 + (2 if strategy == "bts" else 0) \
        + (1 if codec == "topk" else 0)
    return (d - 1) * (down + fp_rows * (1 + row_gathers))


# ------------------------------------------------------------------ #
# timing (runs inside the worker; needs the right device count)
# ------------------------------------------------------------------ #
def _make_sampler(train, test, cfg, rounds: int):
    """Compile one engine; return ``sample() -> rounds/sec`` (warmed up)."""
    import jax
    import jax.numpy as jnp

    from repro.federated.simulation import (
        _build, _make_round_fn, make_sharded_round_runner,
    )

    train_j = jnp.asarray(train, jnp.float32)
    setup = _build(train_j, jnp.asarray(test, jnp.float32), cfg)
    cohorts = np.resize(setup.cohorts, (rounds,) + setup.cohorts.shape[1:])

    if cfg.backend == "shard":
        run_chunk, state0 = make_sharded_round_runner(train_j, setup, cfg)
    else:
        round_fn = _make_round_fn(train_j, setup, cfg.cohort_shards)

        def scan_chunk(state, ch):
            def body(st, cohort):
                st, _ = round_fn(st, cohort)
                return st, None
            return jax.lax.scan(body, state, ch)

        compiled = jax.jit(scan_chunk)
        state0 = setup.state0

        def run_chunk(state, ch):
            return compiled(state, jnp.asarray(ch))

    def sample() -> float:
        t0 = time.perf_counter()
        state, _ = run_chunk(state0, cohorts)
        jax.block_until_ready(state.q)
        return rounds / (time.perf_counter() - t0)

    sample()                                       # warmup / compile
    return sample


def _time_engine(train, test, cfg, rounds: int) -> float:
    sample = _make_sampler(train, test, cfg, rounds)
    return max(sample() for _ in range(REPEATS))


def _worker(d: int, quick: bool) -> Dict:
    """Measure every strategy x codec at mesh size ``d`` (current process
    must already see exactly ``d`` devices)."""
    import jax

    from repro.federated.simulation import FLSimConfig

    assert len(jax.devices()) >= d, (
        f"worker expected {d} devices, found {len(jax.devices())} — "
        "launch via the parent (it sets XLA_FLAGS before jax init)")
    sc = _scale(quick)
    train, test = make_data(sc["users"], sc["items"])
    out: Dict = {"d": d, "sharded": {}, "scan_baseline": {}}
    for strategy in STRATEGIES:
        out["sharded"][strategy] = {}
        if d == 1:
            out["scan_baseline"][strategy] = {}
        for codec in CODECS:
            base = dict(strategy=strategy, codec=codec,
                        keep_fraction=sc["keep_fraction"], theta=sc["theta"],
                        num_factors=sc["k"], seed=0, rounds=sc["rounds"],
                        eval_every=10 * sc["rounds"])
            num_select = sc["items"] if strategy == "full" \
                else int(round(sc["keep_fraction"] * sc["items"]))
            bytes_pr = per_round_payload_bytes(
                num_select, sc["k"], codec=codec,
                theta=min(sc["theta"], sc["users"]))
            cfg = FLSimConfig(backend="shard", mesh_shards=d, **base)
            if d == 1:
                # the D=1-within-10%-of-scan gate: alternate samples of the
                # two engines so CPU drift hits both equally (best-of)
                shard_sample = _make_sampler(train, test, cfg, sc["rounds"])
                scan_sample = _make_sampler(train, test, FLSimConfig(**base),
                                            sc["rounds"])
                # the two D=1 programs are near-identical; the observed
                # spread is host noise, so take best-of over enough
                # alternating pairs for both bests to converge
                rps, rps_scan = 0.0, 0.0
                for _ in range(2 * REPEATS + 2):
                    rps_scan = max(rps_scan, scan_sample())
                    rps = max(rps, shard_sample())
                out["scan_baseline"][strategy][codec] = {
                    "rounds_per_sec": rps_scan,
                    "bytes_per_round": bytes_pr,
                }
            else:
                rps = _time_engine(train, test, cfg, sc["rounds"])
            out["sharded"][strategy][codec] = {
                "rounds_per_sec": rps,
                "bytes_per_round": bytes_pr,
                "collective_bytes_per_device_per_round":
                    collective_bytes_per_device(strategy, codec, d,
                                                num_select, sc["k"]),
            }
    return out


# ------------------------------------------------------------------ #
# orchestration (parent process)
# ------------------------------------------------------------------ #
def _spawn_worker(d: int, quick: bool) -> Dict:
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    from repro.launch.mesh import fake_cpu_devices_env

    env = fake_cpu_devices_env(d)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.sharded_rounds",
           "--worker", str(d)] + (["--quick"] if quick else [])
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=os.getcwd(), timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded_rounds worker D={d} failed:\n{proc.stderr[-4000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith(WORKER_MARK):
            return json.loads(line[len(WORKER_MARK):])
    raise RuntimeError(
        f"worker D={d} produced no result line:\n{proc.stdout[-2000:]}")


def run(quick: bool = False) -> Dict:
    sc = _scale(quick)
    out: Dict = {
        "scale": sc,
        "mesh_sizes": list(MESH_SIZES),
        "note": ("fake CPU devices share the host cores: D>1 rounds/sec "
                 "measures sharding overhead, not speedup; per-device "
                 "state is 1/D of every (M, K) table"),
        "sharded": {}, "scan_baseline": {},
    }
    for d in MESH_SIZES:
        res = _spawn_worker(d, quick)
        out["sharded"][str(d)] = res["sharded"]
        if d == 1:
            out["scan_baseline"] = res["scan_baseline"]
        print(f"  measured D={d}")

    # acceptance gate: D=1 sharded within 10% of the plain scan engine
    out["d1_vs_scan"] = {}
    worst = 1.0
    for strategy in STRATEGIES:
        for codec in CODECS:
            r_shard = out["sharded"]["1"][strategy][codec]["rounds_per_sec"]
            r_scan = out["scan_baseline"][strategy][codec]["rounds_per_sec"]
            ratio = r_shard / r_scan
            out["d1_vs_scan"][f"{strategy}/{codec}"] = ratio
            worst = min(worst, ratio)
    out["d1_min_ratio_vs_scan"] = worst

    print(f"\n## Sharded rounds — rounds/sec vs mesh size "
          f"(M={sc['items']}, K={sc['k']}, Theta={sc['theta']}, "
          f"{int((1 - sc['keep_fraction']) * 100)}% payload cut)\n")
    rows = []
    for strategy in STRATEGIES:
        for codec in CODECS:
            cells = [out["sharded"][str(d)][strategy][codec]
                     for d in MESH_SIZES]
            rows.append(
                (f"{strategy}/{codec}",
                 f"{out['scan_baseline'][strategy][codec]['rounds_per_sec']:.1f}",
                 *(f"{c['rounds_per_sec']:.1f}" for c in cells),
                 f"{cells[-1]['collective_bytes_per_device_per_round'] / 1e6:.2f}"))
    print(markdown_table(
        ("strategy/codec", "scan (r/s)",
         *(f"D={d} (r/s)" for d in MESH_SIZES),
         "D=8 coll. MB/dev/round"), rows))
    print(f"\nD=1 sharded vs scan: worst ratio {worst:.2f} "
          f"(target >= 0.90)")

    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {OUT_PATH}")
    return out


def dry_run() -> Dict:
    """Two sharded toy rounds on whatever devices exist (D=1 in CI) plus a
    bitwise check against the scan engine: the shard_map program must build,
    execute and agree."""
    from dataclasses import replace

    from repro.federated.simulation import FLSimConfig, run_fcf_simulation

    train, test = make_data(40, 64)
    cfg = FLSimConfig(strategy="bts", keep_fraction=0.25, theta=8,
                      num_factors=8, rounds=2, eval_every=20, seed=0,
                      record_selections=True)
    scan = run_fcf_simulation(train, test, cfg)
    shard = run_fcf_simulation(
        train, test, replace(cfg, backend="shard", mesh_shards=1))
    assert np.array_equal(scan.selections, shard.selections)
    assert np.array_equal(np.asarray(scan.server_state.q),
                          np.asarray(shard.server_state.q))
    print("[dry-run] sharded_rounds — 2-round toy shard_map scan OK, "
          "bitwise equal to the scan engine")
    return {"dry_run": True, "d1_bitwise_equal": True}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller scale for smoke runs")
    ap.add_argument("--dry-run", action="store_true",
                    help="toy shard rounds on current devices only")
    ap.add_argument("--worker", type=int, default=None,
                    help=argparse.SUPPRESS)   # internal: one mesh size
    args = ap.parse_args(argv)
    if args.worker is not None:
        res = _worker(args.worker, args.quick)
        print(WORKER_MARK + json.dumps(res))
        return res
    return dry_run() if args.dry_run else run(quick=args.quick)


if __name__ == "__main__":
    main()
