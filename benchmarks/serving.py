"""Serving read-path benchmark: fused compressed scoring vs dense fp32.

The deployment question behind ``repro.serve``: what does it cost to
answer top-N recommendation requests straight off the COMPRESSED model?
For each (M items x codec x batch bucket) cell this bench times the fused
dequant->score->top-N path (:func:`repro.kernels.wire_topn` over a
:class:`repro.serve.ServingModel` wire image) against the naive dense
baseline (fp32 table resident, ``lax.top_k(p @ q.T)`` with its full
(B, M) score matrix), reporting users/sec, p50/p99 latency per batch
bucket, and two memory figures:

  * ``resident_model_bytes`` — what the model itself occupies (wire image
    vs fp32 table; int8 is ~3.5x smaller at K=25, the per-row scales cost
    the rest of 4x),
  * ``peak_serving_bytes`` — resident + per-request scratch. The dense
    path materializes the (B, M) fp32 score matrix per request; the fused
    path's scratch is one decode block + one (B, block_m) score tile, so
    at M >= 100k the peak gap is where compressed serving wins big (the
    >= 4x headline, asserted).

On CPU the fused path runs the chunked jnp oracle (`kernels.ops` backend
convention — same math, no interpret-mode throttle); on TPU it is the
Pallas kernel. Results persist to ``BENCH_serving.json``.

Usage:  PYTHONPATH=src python -m benchmarks.serving [--quick] [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import CodecConfig
from repro.obs import LatencyHistogram
from repro.serve import ServingModel

from benchmarks.common import markdown_table

OUT_PATH = "BENCH_serving.json"

CODECS = ("fp32", "fp16", "int8", "int4")
BATCHES = (8, 64, 256)
ITEM_SCALES = (32_768, 131_072)
K = 25
TOP_N = 10
BLOCK_M = 4096


def _dense_topn(q: jax.Array):
    """The naive baseline: fp32 table resident, full (B, M) score matrix."""
    @jax.jit
    def fn(p):
        return jax.lax.top_k(p @ q.T, TOP_N)
    return fn


def _fused_topn(model: ServingModel, block_m: int):
    cfg, wire, dim = model.cfg, model.wire, model.dim

    @jax.jit
    def fn(p):
        from repro.kernels import wire_topn
        return wire_topn(cfg, wire, p, dim, TOP_N, block_m=block_m)
    return fn


def _time_call(fn, p, warmup: int = 2, iters: int = 10) -> np.ndarray:
    """Per-call wall-clock seconds (blocked), one entry per iteration."""
    for _ in range(warmup):
        jax.block_until_ready(fn(p))
    out = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(p))
        out.append(time.perf_counter() - t0)
    return np.asarray(out)


def _scratch_bytes(kind: str, b: int, m: int, block_m: int) -> int:
    """Per-request working-set bytes each path materializes beyond the model.

    dense: the (B, M) fp32 score matrix (what the fused path exists to
    avoid). fused: one (block_m, K) fp32 decode block + one (B, block_m)
    score tile + the (B, N) running top (vals + ids).
    """
    if kind == "dense":
        return b * m * 4
    return block_m * K * 4 + b * block_m * 4 + 2 * (b * TOP_N * 4)


def _measure_cell(kind: str, fn, b: int, m: int, resident: int,
                  block_m: int, iters: int) -> Dict:
    p = jax.random.normal(jax.random.PRNGKey(b), (b, K), jnp.float32)
    lat = _time_call(fn, p, iters=iters)
    med = float(np.median(lat))
    scratch = _scratch_bytes(kind, b, m, block_m)
    # one quantile definition repo-wide: same obs.hist bucketing as the
    # ServingEngine /metrics histograms and the serve_recs summary
    hist = LatencyHistogram.from_values(lat)
    return {
        "path": kind, "batch": b,
        "users_per_sec": b / med,
        "p50_ms": hist.quantile(0.50) * 1e3,
        "p99_ms": hist.quantile(0.99) * 1e3,
        "resident_model_bytes": resident,
        "request_scratch_bytes": scratch,
        "peak_serving_bytes": resident + scratch,
    }


def run(item_scales: Sequence[int] = ITEM_SCALES,
        codecs: Sequence[str] = CODECS,
        batches: Sequence[int] = BATCHES,
        block_m: int = BLOCK_M, iters: int = 10, seed: int = 0,
        out_path: Optional[str] = OUT_PATH) -> Dict:
    sections: List[Dict] = []
    for m in item_scales:
        q = 0.1 * jax.random.normal(jax.random.PRNGKey(seed), (m, K),
                                    jnp.float32)
        dense_fn = _dense_topn(q)
        dense_resident = m * K * 4
        cells: List[Dict] = []
        for b in batches:
            cells.append(_measure_cell("dense", dense_fn, b, m,
                                       dense_resident, block_m, iters))
        for codec in codecs:
            model = ServingModel.from_dense(CodecConfig(name=codec), q)
            fn = _fused_topn(model, block_m)
            resident = model.resident_bytes()
            for b in batches:
                cell = _measure_cell(f"fused-{codec}", fn, b, m, resident,
                                     block_m, iters)
                cells.append(cell)
        sections.append({"items": m, "cells": cells})

    # headline: the acceptance contract at the largest scale, biggest batch
    big = sections[-1]
    b_max = max(batches)

    def pick(kind):
        return next(c for c in big["cells"]
                    if c["path"] == kind and c["batch"] == b_max)

    dense_c, int8_c = pick("dense"), pick("fused-int8")
    headline = {
        "items": big["items"], "batch": b_max,
        "dense_fp32_users_per_sec": dense_c["users_per_sec"],
        "fused_int8_users_per_sec": int8_c["users_per_sec"],
        "users_per_sec_speedup":
            int8_c["users_per_sec"] / dense_c["users_per_sec"],
        "dense_fp32_peak_serving_bytes": dense_c["peak_serving_bytes"],
        "fused_int8_peak_serving_bytes": int8_c["peak_serving_bytes"],
        "peak_memory_ratio":
            dense_c["peak_serving_bytes"] / int8_c["peak_serving_bytes"],
        "resident_ratio":
            dense_c["resident_model_bytes"] / int8_c["resident_model_bytes"],
    }

    out = {
        "scale": {"factors": K, "top_n": TOP_N, "block_m": block_m,
                  "item_scales": list(item_scales),
                  "batches": list(batches),
                  "backend": jax.default_backend()},
        "headline": headline,
        "sections": sections,
    }

    for sec in sections:
        print(f"\n## Serving read path — M={sec['items']}, K={K}, "
              f"top_n={TOP_N} ({jax.default_backend()})\n")
        rows = [(c["path"], c["batch"],
                 f"{c['users_per_sec']:.0f}",
                 f"{c['p50_ms']:.2f}", f"{c['p99_ms']:.2f}",
                 f"{c['resident_model_bytes'] / 1e6:.2f}",
                 f"{c['peak_serving_bytes'] / 1e6:.2f}")
                for c in sec["cells"]]
        print(markdown_table(
            ("path", "batch", "users/s", "p50 ms", "p99 ms",
             "model MB", "peak MB"), rows))

    print(f"\nheadline at M={headline['items']}, B={headline['batch']}: "
          f"fused int8 {headline['users_per_sec_speedup']:.2f}x users/sec, "
          f"{headline['peak_memory_ratio']:.1f}x lower peak serving memory, "
          f"{headline['resident_ratio']:.2f}x lower resident model bytes "
          f"vs dense fp32")
    # the acceptance contract holds at deployment scale; tiny --quick grids
    # legitimately favor dense (the (B, M) matrix still fits in cache)
    if headline["items"] >= 100_000:
        assert headline["users_per_sec_speedup"] > 1.0, \
            "fused int8 must beat dense fp32 in users/sec at M>=100k"
        assert headline["peak_memory_ratio"] >= 4.0, \
            "fused int8 must serve in >= 4x less peak memory than dense fp32"
        assert headline["resident_ratio"] > 1.0, \
            "the int8 wire image must be smaller than the fp32 table"

    if out_path:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=1, default=float)
        print(f"\nwrote {out_path}")
    return out


def dry_run() -> Dict:
    """Accounting-only smoke: model + scratch byte math, no timing."""
    m, b = ITEM_SCALES[-1], max(BATCHES)
    rows = []
    dense_resident = m * K * 4
    rows.append(("dense", dense_resident,
                 _scratch_bytes("dense", b, m, BLOCK_M)))
    q = jnp.zeros((256, K), jnp.float32)   # tiny table, same per-row layout
    for codec in CODECS:
        model = ServingModel.from_dense(CodecConfig(name=codec), q)
        per_row = model.resident_bytes() / 256
        rows.append((f"fused-{codec}", int(per_row * m),
                     _scratch_bytes("fused", b, m, BLOCK_M)))
    print(f"\n[dry-run] serving — bytes at M={m}, K={K}, B={b}, "
          f"block_m={BLOCK_M}\n")
    print(markdown_table(("path", "model bytes", "request scratch B"),
                         [(p, mb, sb) for p, mb, sb in rows]))
    return {"dry_run": True,
            "cells_planned":
                len(ITEM_SCALES) * len(BATCHES) * (1 + len(CODECS))}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small grid, don't clobber the committed artifact")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the byte accounting, run nothing")
    args = ap.parse_args(argv)
    if args.dry_run:
        return dry_run()
    if args.quick:
        return run(item_scales=(8192,), batches=(8, 64), iters=5,
                   out_path=None)
    return run()


if __name__ == "__main__":
    main()
