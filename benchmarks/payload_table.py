"""Paper Table 1: FCF model payload vs number of items (exact formula).

payload_bytes = (#items x #factors x 64 bits) / 8.  Validates our
payload accounting helper against the paper's published numbers.
"""
from __future__ import annotations

from repro.core.payload import payload_bytes

from benchmarks.common import markdown_table

# (items, paper's approximate payload string)
PAPER_ROWS = [
    (3912, "625KB"), (10_000, "1.6 MB"), (100_000, "16 MB"),
    (500_000, "80 MB"), (1_000_000, "160 MB"), (10_000_000, "1.6 GB"),
]
K = 20          # paper Table 1 uses 20 factors


def _human(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if n < 1000:
            return f"{n:.3g} {unit}"
        n /= 1000
    return f"{n:.3g} TB"


def run() -> dict:
    rows = []
    out = {}
    for items, paper in PAPER_ROWS:
        b = payload_bytes(items, K, dtype_bits=64)
        rows.append((items, _human(b), paper))
        out[str(items)] = b
    print("\n## Paper Table 1 — payload vs #items (K=20, float64)\n")
    print(markdown_table(("#items", "ours", "paper"), rows))
    return out


if __name__ == "__main__":
    run()
