"""Paper Table 1: FCF model payload vs number of items (exact formula).

payload_bytes = (#items x #factors x 64 bits) / 8.  Validates our
payload accounting helper against the paper's published numbers, plus the
quantized-wire equivalents from the compression subsystem.

Usage:  PYTHONPATH=src python -m benchmarks.payload_table [--dry-run]
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence

from repro.compress import CodecConfig, wire_bytes
from repro.core.payload import payload_bytes

from benchmarks.common import markdown_table

# (items, paper's approximate payload string)
PAPER_ROWS = [
    (3912, "625KB"), (10_000, "1.6 MB"), (100_000, "16 MB"),
    (500_000, "80 MB"), (1_000_000, "160 MB"), (10_000_000, "1.6 GB"),
]
K = 20          # paper Table 1 uses 20 factors


def _human(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if n < 1000:
            return f"{n:.3g} {unit}"
        n /= 1000
    return f"{n:.3g} TB"


def run() -> dict:
    rows = []
    out = {}
    for items, paper in PAPER_ROWS:
        b = payload_bytes(items, K, dtype_bits=64)
        int8 = wire_bytes(CodecConfig(name="int8"), items, K)
        rows.append((items, _human(b), paper, _human(int8)))
        out[str(items)] = b
    print("\n## Paper Table 1 — payload vs #items (K=20, float64; "
          "int8 wire alongside)\n")
    print(markdown_table(("#items", "ours", "paper", "int8 wire"), rows))
    return out


def main(argv: Optional[Sequence[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="pure-arithmetic table; same as a full run")
    ap.parse_args(argv)
    # the table IS arithmetic — dry-run and full run coincide
    return run()


if __name__ == "__main__":
    main()
