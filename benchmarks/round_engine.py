"""Round-engine throughput: legacy loop vs per-round jitted step vs lax.scan.

Measures steady-state FL rounds/sec of one server round at MIND-like scale
(M = 10k items, K = 25 factors, Theta = 100 users/commit, 90% payload cut)
for three execution models:

  * ``legacy`` — the pre-refactor engine, reproduced faithfully: per-round
    Python through the mutable ``FCFServer`` / ``PayloadSelector`` objects
    (selection, Adam commit and reward updates run eagerly op-by-op; only
    the client solve is jitted) with the seed's original client math (naive
    (b,m,k,l) einsum normal equations + LU solve). This is how the seed
    reproduction drove every round, and it is the baseline the refactor's
    speedup claim is measured against.
  * ``python`` — the fused pure ``server_round_step`` jitted once and
    dispatched per round from Python (simulation ``backend="python"``).
  * ``scan``   — the same step compiled into one ``jax.lax.scan`` program
    (simulation ``backend="scan"``, the default engine).

Compilation is excluded (warmup call per engine); the headline number is
the legacy -> scan speedup, with a >= 5x acceptance bar for the bandit
strategy on CPU. Writes ``BENCH_round_engine.json`` in the cwd.

Usage:  PYTHONPATH=src python -m benchmarks.round_engine [--quick] [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Sequence

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.cf.local import item_gradients
from repro.cf.server import FCFServer, FCFServerConfig
from repro.core.payload import make_selector
from repro.federated.simulation import (
    FLSimConfig, _build, _make_round_fn, run_fcf_simulation,
)
from repro.obs import InMemorySink, ObsConfig

from benchmarks.common import markdown_table, per_round_payload_bytes

OUT_PATH = "BENCH_round_engine.json"
REPEATS = 3   # best-of repeats per engine (CPU benchmarks are noisy)


def make_data(users: int, items: int, density: float = 0.02, seed: int = 0):
    rng = np.random.default_rng(seed)
    train = (rng.random((users, items)) < density).astype(np.float32)
    test = (rng.random((users, items)) < density / 4).astype(np.float32)
    return train, test


def _setup(train, test, cfg: FLSimConfig):
    train_j = jnp.asarray(train, jnp.float32)
    setup = _build(train_j, jnp.asarray(test, jnp.float32), cfg)
    return train_j, setup, _make_round_fn(train_j, setup)


@partial(jax.jit, static_argnames=("l2", "alpha"))
def _seed_solve_user_factors(q, x, l2=1.0, alpha=4.0):
    """The seed's original Eq. 3 solve (pre hot-path optimization): naive
    per-user (b, m, k, l) einsum for the normal equations + batched LU."""
    k = q.shape[-1]
    gram = q.T @ q
    corr = jnp.einsum("bm,mk,ml->bkl", x, q, q)
    lhs = gram[None] + alpha * corr + l2 * jnp.eye(k, dtype=q.dtype)[None]
    rhs = (1.0 + alpha) * (x @ q)
    return jnp.linalg.solve(lhs, rhs[..., None])[..., 0]


def _seed_local_update(q, x, cf_cfg):
    p = _seed_solve_user_factors(q, x, l2=cf_cfg.l2, alpha=cf_cfg.alpha)
    g = item_gradients(q, p, x, l2=cf_cfg.l2, alpha=cf_cfg.alpha)
    return p, g


def time_legacy(train, test, cfg: FLSimConfig, rounds: int) -> float:
    """The seed's execution model: mutable objects, eager server math."""
    train_j, setup, _ = _setup(train, test, cfg)
    users = train.shape[0]
    selector = make_selector(
        cfg.strategy, num_arms=train.shape[1], dim=cfg.num_factors,
        keep_fraction=cfg.keep_fraction, seed=cfg.seed + 13)
    server = FCFServer(
        item_factors=setup.state0.q, selector=selector,
        config=FCFServerConfig(theta=cfg.theta))
    rng = np.random.default_rng(cfg.seed + 31)

    def one_round():
        q_star = server.begin_round()
        cohort = rng.choice(users, size=min(cfg.theta, users), replace=False)
        x_sub = train_j[jnp.asarray(cohort)][:, server.selected]
        _, grads = _seed_local_update(q_star, x_sub, setup.cf_cfg)
        server.receive(grads, num_users=len(cohort))

    for _ in range(3):                     # warmup / compile
        one_round()
    jax.block_until_ready(server.item_factors)
    best = 0.0
    for _ in range(REPEATS):               # best-of: least interference
        t0 = time.perf_counter()
        for _ in range(rounds):
            one_round()
        jax.block_until_ready(server.item_factors)
        best = max(best, rounds / (time.perf_counter() - t0))
    return best


def time_python(train, test, cfg: FLSimConfig, rounds: int) -> float:
    """Fused step, per-round dispatch (simulation backend="python")."""
    _, setup, round_fn = _setup(train, test, cfg)
    step = jax.jit(round_fn)
    cohorts = jnp.asarray(setup.cohorts)
    state, _ = step(setup.state0, cohorts[0])      # warmup / compile
    jax.block_until_ready(state.q)
    best = 0.0
    for _ in range(REPEATS):
        state = setup.state0
        t0 = time.perf_counter()
        for t in range(rounds):
            state, _ = step(state, cohorts[t % cohorts.shape[0]])
        jax.block_until_ready(state.q)
        best = max(best, rounds / (time.perf_counter() - t0))
    return best


def time_scan(train, test, cfg: FLSimConfig, rounds: int) -> float:
    """Whole-chunk lax.scan compilation (simulation backend="scan")."""
    _, setup, round_fn = _setup(train, test, cfg)

    def scan_chunk(state, cohorts):
        def body(st, cohort):
            st, _ = round_fn(st, cohort)
            return st, None
        return jax.lax.scan(body, state, cohorts)

    run_chunk = jax.jit(scan_chunk)
    cohorts = jnp.asarray(
        np.resize(setup.cohorts, (rounds,) + setup.cohorts.shape[1:]))
    state, _ = run_chunk(setup.state0, cohorts)    # warmup / compile
    jax.block_until_ready(state.q)
    best = 0.0
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        state, _ = run_chunk(setup.state0, cohorts)
        jax.block_until_ready(state.q)
        best = max(best, rounds / (time.perf_counter() - t0))
    return best


def regret_series(train, test, rounds: int, every: int = 10) -> Dict:
    """Cumulative pseudo-regret of the scan engine's bandit, via telemetry.

    Runs the default strategy with in-loop observability on
    (:class:`repro.obs.ObsConfig` + an in-memory sink) and reads the
    ``cum_regret`` series straight off the round-telemetry stream — the
    traced port of ``core/regret.RegretTracker`` that now computes inside
    the compiled scan. Subsampled to every ``every`` rounds (plus the
    final round) to keep the committed artifact small.
    """
    sink = InMemorySink()
    cfg = FLSimConfig(
        strategy="bts", keep_fraction=0.1, theta=100, num_factors=25,
        rounds=rounds, eval_every=rounds, seed=0,
        obs=ObsConfig(enabled=True, sink=sink))
    run_fcf_simulation(train, test, cfg)
    cum = [e["cum_regret"] for e in sink.events]
    idx = list(range(every - 1, len(cum), every))
    if not idx or idx[-1] != len(cum) - 1:
        idx.append(len(cum) - 1)
    return {
        "strategy": "bts",
        "rounds": rounds,
        "every": every,
        "round_ids": [i + 1 for i in idx],
        "cum_regret": [round(cum[i], 4) for i in idx],
        "final_cum_regret": round(cum[-1], 4),
    }


def run(quick: bool = False) -> Dict:
    # MIND-like scale (paper Table 2): 10k items, K=25, Theta=100, 90% cut
    users, items = (1000, 2000) if quick else (5000, 10_000)
    scan_rounds = 100 if quick else 200
    loop_rounds = 30 if quick else 60       # dispatch-bound: keep it short
    train, test = make_data(users, items)
    base = dict(keep_fraction=0.1, theta=100, num_factors=25, seed=0,
                rounds=scan_rounds, eval_every=10 * scan_rounds)

    out: Dict = {
        "scale": {"users": users, "items": items, "k": 25, "theta": 100,
                  "keep_fraction": 0.1},
        "strategies": {},
    }
    rows = []
    for strategy in ("bts", "random", "magnitude", "full"):
        cfg = FLSimConfig(strategy=strategy, **base)
        rps_legacy = time_legacy(train, test, cfg, loop_rounds)
        rps_py = time_python(train, test, cfg, loop_rounds)
        rps_scan = time_scan(train, test, cfg, scan_rounds)
        speedup = rps_scan / rps_legacy
        num_select = items if strategy == "full" \
            else int(round(cfg.keep_fraction * items))
        out["strategies"][strategy] = {
            "legacy_rounds_per_sec": rps_legacy,
            "python_rounds_per_sec": rps_py,
            "scan_rounds_per_sec": rps_scan,
            "speedup_scan_vs_legacy": speedup,
            "speedup_scan_vs_python": rps_scan / rps_py,
            # shared perf-trajectory schema with BENCH_sharded_rounds.json:
            # every rounds/sec figure pairs with the payload bytes one round
            # moves at this configuration (codec=fp32, theta uplink users)
            "bytes_per_round": per_round_payload_bytes(
                num_select, cfg.num_factors, codec=cfg.codec,
                theta=min(cfg.theta, users)),
        }
        rows.append((strategy, f"{rps_legacy:.1f}", f"{rps_py:.1f}",
                     f"{rps_scan:.1f}", f"{speedup:.1f}x"))

    out["regret"] = regret_series(train, test, rounds=scan_rounds)
    print("\n## Round engine — rounds/sec "
          f"(M={items}, K=25, Theta=100, 90% payload cut)\n")
    print(markdown_table(
        ("strategy", "legacy loop (r/s)", "fused step (r/s)",
         "lax.scan (r/s)", "scan vs legacy"), rows))
    print(f"\nbts cumulative regret after {scan_rounds} rounds: "
          f"{out['regret']['final_cum_regret']:.2f} "
          f"(telemetry series, every {out['regret']['every']} rounds)")

    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {OUT_PATH}")
    bts = out["strategies"]["bts"]["speedup_scan_vs_legacy"]
    print(f"bts scan-vs-legacy speedup: {bts:.1f}x (target >= 5x)")
    return out


def dry_run() -> Dict:
    """Two scan rounds at toy scale: the engine must build and execute.

    Also exercises the telemetry-backed regret series (4 toy rounds with
    observability on) so the obs wiring is covered by the CI smoke.
    """
    train, test = make_data(40, 60)
    cfg = FLSimConfig(strategy="bts", keep_fraction=0.25, theta=8,
                      num_factors=8, rounds=2, eval_every=20, seed=0)
    rps = time_scan(train, test, cfg, rounds=2)
    sink = InMemorySink()
    tiny = FLSimConfig(strategy="bts", keep_fraction=0.25, theta=8,
                       num_factors=8, rounds=4, eval_every=20, seed=0,
                       obs=ObsConfig(enabled=True, sink=sink))
    run_fcf_simulation(train, test, tiny)
    cum = [e["cum_regret"] for e in sink.events]
    assert len(cum) == 4 and all(b >= a for a, b in zip(cum, cum[1:])), \
        f"telemetry regret series not cumulative: {cum}"
    print(f"[dry-run] round_engine — 2-round toy scan OK "
          f"({rps:.0f} rounds/s); telemetry regret series OK "
          f"(cum_regret[-1]={cum[-1]:.3f})")
    return {"dry_run": True, "toy_rounds_per_sec": rps,
            "toy_cum_regret": cum[-1]}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller scale for smoke runs")
    ap.add_argument("--dry-run", action="store_true",
                    help="two toy rounds through the scan engine only")
    args = ap.parse_args(argv)
    return dry_run() if args.dry_run else run(quick=args.quick)


if __name__ == "__main__":
    main()
