"""Paper Figure 3: convergence analysis at 90% payload reduction.

Reads the F1 trajectories of FCF (full) and FCF-BTS from the experiment
grid and reports (i) the iteration at which each reaches 95% of its own
final plateau and (ii) the BTS/full slowdown ratio — the paper's claim is
~2x (400-450 vs 200-250 iterations) with eventual near-parity on sparse
datasets.
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Sequence

import numpy as np

from benchmarks.common import markdown_table
from benchmarks.fcf_experiments import (
    FULL, QUICK, GridScale, cell_key, ensure_cells,
)

KEEP = 0.10


def _mean_trajectory(cells: List[Dict], metric: str = "f1"):
    t = np.asarray(cells[0]["trajectory"]["t"])
    vals = np.mean([c["trajectory"][metric] for c in cells], axis=0)
    # paper Sec 6.2: trailing-10 smoothing at read-out
    smooth = np.convolve(vals, np.ones(min(10, len(vals))) /
                         min(10, len(vals)), mode="valid")
    return t[len(t) - len(smooth):], smooth


def _iters_to_plateau(t, vals, frac: float = 0.95) -> int:
    target = frac * vals[-1]
    idx = np.argmax(vals >= target)
    return int(t[idx])


def run(scale: GridScale = QUICK) -> Dict:
    out: Dict = {"scale": scale.name, "datasets": {}}
    rows = []
    for ds in scale.datasets:
        t_f, v_f = _mean_trajectory(ensure_cells(scale, ds, "full", 1.0))
        t_b, v_b = _mean_trajectory(ensure_cells(scale, ds, "bts", KEEP))
        it_f = _iters_to_plateau(t_f, v_f)
        it_b = _iters_to_plateau(t_b, v_b)
        ratio = it_b / max(it_f, 1)
        gap = 100.0 * (1.0 - v_b[-1] / max(v_f[-1], 1e-9))
        rows.append((ds, it_f, it_b, f"{ratio:.2f}x", f"{gap:.1f}%"))
        out["datasets"][ds] = {
            "iters_full": it_f, "iters_bts": it_b, "slowdown": ratio,
            "final_gap_pct": gap,
            "trajectory_full": {"t": t_f.tolist(), "f1": v_f.tolist()},
            "trajectory_bts": {"t": t_b.tolist(), "f1": v_b.tolist()},
        }
    print("\n## Figure 3 analogue — convergence at 90% payload reduction\n")
    print(markdown_table(
        ("dataset", "FCF iters to 95% plateau", "BTS iters", "slowdown",
         "final F1 gap"), rows))
    return out


def dry_run(scale: GridScale = QUICK) -> Dict:
    cells = [cell_key(scale, ds, s, k, 0) for ds in scale.datasets
             for s, k in (("full", 1.0), ("bts", KEEP))]
    print(f"[dry-run] convergence — would read {len(cells)} grid points "
          f"at scale '{scale.name}' (none executed)")
    return {"dry_run": True, "cells": cells}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick",
                    choices=("quick", "mid", "full"))
    ap.add_argument("--dry-run", action="store_true",
                    help="list the grid points, execute nothing")
    args = ap.parse_args(argv)
    from benchmarks.fcf_experiments import MID
    scale = {"quick": QUICK, "mid": MID, "full": FULL}[args.scale]
    return dry_run(scale) if args.dry_run else run(scale)


if __name__ == "__main__":
    main()
