"""Async cohort engine: rounds/sec + P@10 vs the staleness bound S.

Two axes, one artifact (``BENCH_async_cohorts.json``):

  * QUALITY — for S in {0, 1, 2, 4} x {bts, random} x {fp32, int8} run the
    ``backend="async"`` engine on movielens-mini (uniform staleness draws,
    the default FedAsync-style discount**s step damping with the repo
    default discount of 0.8 — recorded in the artifact's config block) and
    report P@10 / F1 / MAP. S=0 is the synchronous baseline by construction
    (bit-identical to ``backend="scan"``, tier-1 enforced), so the quality
    loss of staleness is read directly off the curve.
  * THROUGHPUT — two numbers per cell. ``engine_rounds_per_sec`` is the
    measured wall-clock rate of the compiled async scan (the ring buffer
    must be ~free: the snapshot ring costs S+1 payload-sized wire images);
    the paired ``scan_rounds_per_sec`` baseline is sampled *interleaved*
    with it (alternating best-of, the ``sharded_rounds`` D=1 discipline) so
    host drift hits both engines equally.
    ``modeled_commits_per_sec`` is the deployment-model rate: per-user
    report latencies are lognormal, a cohort lands when its slowest of
    Theta users reports, and a bounded-staleness server may run S rounds
    ahead of the cohort it is waiting on — the classic async-FL pipeline
    recurrence ``commit_t = max(commit_{t-1} + c, commit_{t-1-S} + L_t)``
    simulated over the sampled latencies. S=0 degenerates to the
    synchronous wait-for-your-cohort server; the S>0 speedup is the
    paper's motivation for asynchronous deployment made quantitative.

Usage:  PYTHONPATH=src python -m benchmarks.async_cohorts [--quick|--dry-run]
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace
from typing import Dict, List, Optional, Sequence

import numpy as np

from benchmarks.common import markdown_table, per_round_payload_bytes

OUT_PATH = "BENCH_async_cohorts.json"
STRATEGIES = ("bts", "random")
CODECS = ("fp32", "int8")
STALENESS_BOUNDS = (0, 1, 2, 4)

# deployment latency model: per-user report delay ~ lognormal(median 10s),
# heavy upper tail — the regime where synchronous cohorts crawl
LATENCY_MEDIAN_S = 10.0
LATENCY_SIGMA = 1.0


def modeled_commit_rate(s_max: int, theta: int, compute_s: float,
                        rounds: int = 2000, seed: int = 0) -> float:
    """Commits/sec of a bounded-staleness server under the latency model.

    ``L_t`` is the max over theta lognormal user delays (the cohort lands
    with its straggler); the server's t-th commit waits for the cohort
    dispatched against snapshot t-S: ``commit_t = max(commit_{t-1} + c,
    commit_{t-1-S} + L_t)``. S=0 is the synchronous server (every round
    eats a full cohort latency); S>0 hides up to S cohort latencies behind
    the pipeline, saturating at the compute rate 1/c.
    """
    rng = np.random.default_rng(seed)
    lat = rng.lognormal(np.log(LATENCY_MEDIAN_S), LATENCY_SIGMA,
                        size=(rounds, theta)).max(axis=1)
    commit = np.zeros(rounds + 1)
    for t in range(1, rounds + 1):
        dispatched = commit[max(t - 1 - s_max, 0)]
        commit[t] = max(commit[t - 1] + compute_s, dispatched + lat[t - 1])
    return rounds / commit[-1]


def _make_engine_sampler(train, test, cfg, rounds: int = 60):
    """Compile one engine (scan or async); return ``sample() -> rounds/sec``
    (warmed up). Keeping samplers alive lets the caller interleave samples
    of two engines so CPU host drift hits both equally."""
    import jax
    import jax.numpy as jnp

    from repro.federated.simulation import (
        _build, _make_async_round_fn, _make_round_fn,
    )

    train_j = jnp.asarray(train, jnp.float32)
    setup = _build(train_j, jnp.asarray(test, jnp.float32), cfg)
    cohorts = jnp.asarray(
        np.resize(setup.cohorts, (rounds,) + setup.cohorts.shape[1:]))

    if cfg.backend == "async":
        round_fn = _make_async_round_fn(train_j, setup,
                                        cfg.blocks_per_commit)
        stale = jnp.asarray(
            np.resize(setup.staleness, (rounds,)).astype(np.int32))

        def scan_chunk(state, ch, st_sched):
            def body(st, xs):
                cohort, s_t = xs
                st, _ = round_fn(st, cohort, s_t)
                return st, None
            return jax.lax.scan(body, state, (ch, st_sched))

        compiled = jax.jit(scan_chunk)

        def run_once():
            state, _ = compiled(setup.state0, cohorts, stale)
            jax.block_until_ready(state.q)
    else:
        round_fn = _make_round_fn(train_j, setup, cfg.cohort_shards)

        def scan_chunk(state, ch):
            def body(st, cohort):
                st, _ = round_fn(st, cohort)
                return st, None
            return jax.lax.scan(body, state, ch)

        compiled = jax.jit(scan_chunk)

        def run_once():
            state, _ = compiled(setup.state0, cohorts)
            jax.block_until_ready(state.q)

    def sample() -> float:
        t0 = time.perf_counter()
        run_once()
        return rounds / (time.perf_counter() - t0)

    sample()                                       # warmup / compile
    return sample


def run(dataset: str = "movielens-mini", rounds: int = 200, theta: int = 50,
        staleness_bounds: Sequence[int] = STALENESS_BOUNDS,
        strategies: Sequence[str] = STRATEGIES,
        codecs: Sequence[str] = CODECS,
        keep: float = 0.1, time_rounds: int = 60, seed: int = 0,
        out_path: Optional[str] = OUT_PATH) -> Dict:
    from repro.data.synthetic import load_dataset
    from repro.federated.simulation import FLSimConfig, run_fcf_simulation

    if not staleness_bounds or staleness_bounds[0] != 0:
        raise ValueError("staleness_bounds must start with 0 (the "
                         "synchronous baseline the curves are relative to)")
    spec, train, test = load_dataset(dataset, seed=seed)
    num_items = train.shape[1]
    base = FLSimConfig(rounds=rounds, theta=theta, keep_fraction=keep,
                       eval_every=max(rounds // 8, 1),
                       eval_users=min(256, train.shape[0]), seed=seed)

    cells: List[Dict] = []
    sync_p10: Dict = {}
    for strategy in strategies:
        for codec in codecs:
            num_select = num_items if strategy == "full" \
                else max(1, int(round(keep * num_items)))
            bytes_pr = per_round_payload_bytes(
                num_select, base.num_factors, codec=codec,
                theta=min(theta, train.shape[0]))
            scan_sample = _make_engine_sampler(
                train, test, replace(base, strategy=strategy, codec=codec),
                rounds=time_rounds)
            for s_max in staleness_bounds:
                cfg = replace(base, strategy=strategy, codec=codec,
                              backend="async", max_staleness=s_max)
                t0 = time.time()
                res = run_fcf_simulation(train, test, cfg)
                secs = time.time() - t0
                # alternating best-of against the scan baseline: the two
                # programs are near-identical, so any spread is host noise
                # and must hit both engines equally
                async_sample = _make_engine_sampler(train, test, cfg,
                                                    rounds=time_rounds)
                rps, scan_rps = 0.0, 0.0
                for _ in range(6):
                    scan_rps = max(scan_rps, scan_sample())
                    rps = max(rps, async_sample())
                modeled = modeled_commit_rate(s_max, min(theta,
                                                         train.shape[0]),
                                              compute_s=1.0 / rps)
                if s_max == 0:
                    sync_p10[(strategy, codec)] = res.final["precision"]
                    sync_modeled = modeled
                p10 = res.final["precision"]
                p10_sync = sync_p10[(strategy, codec)]
                cells.append({
                    "strategy": strategy, "codec": codec, "max_staleness":
                        s_max,
                    "precision_at_10": p10, "f1": res.final["f1"],
                    "map": res.final["map"],
                    "engine_rounds_per_sec": rps,
                    "scan_rounds_per_sec": scan_rps,
                    "modeled_commits_per_sec": modeled,
                    "modeled_speedup_vs_sync": modeled / sync_modeled,
                    "p10_drop_pct_vs_sync": 100.0 * (
                        1.0 - p10 / max(p10_sync, 1e-9)),
                    "bytes_per_round": bytes_pr,
                    "sim_seconds": secs,
                })

    def cell(strategy, codec, s):
        for c in cells:
            key = (c["strategy"], c["codec"], c["max_staleness"])
            if key == (strategy, codec, s):
                return c
        return None

    s_top = max(staleness_bounds)
    bts_top = cell("bts", "int8", s_top)
    headline = {
        "latency_model": {
            "kind": "lognormal-max-of-theta", "median_s": LATENCY_MEDIAN_S,
            "sigma": LATENCY_SIGMA,
        },
        "bts_int8_modeled_speedup_at_max_s":
            bts_top["modeled_speedup_vs_sync"] if bts_top else None,
        "bts_int8_p10_drop_pct_at_max_s":
            bts_top["p10_drop_pct_vs_sync"] if bts_top else None,
        "worst_engine_overhead_vs_scan": min(
            c["engine_rounds_per_sec"] / c["scan_rounds_per_sec"]
            for c in cells),
    }

    out = {
        "dataset": {"name": spec.name, "users": int(train.shape[0]),
                    "items": int(num_items)},
        "config": {"rounds": rounds, "theta": theta, "keep_fraction": keep,
                   "num_factors": base.num_factors, "seed": seed,
                   "staleness_mode": "uniform",
                   "staleness_discount": base.staleness_discount},
        "headline": headline,
        "cells": cells,
    }

    print(f"\n## Async cohorts — P@10 and commit rate vs staleness bound "
          f"({spec.name}: M={num_items}, Theta={theta}, keep={keep}, "
          f"{rounds} rounds)\n")
    rows = []
    for c in cells:
        rows.append((
            f"{c['strategy']}/{c['codec']}", c["max_staleness"],
            f"{c['precision_at_10']:.4f}",
            f"{c['p10_drop_pct_vs_sync']:+.1f}%",
            f"{c['engine_rounds_per_sec']:.0f}",
            f"{c['modeled_commits_per_sec']:.4f}",
            f"{c['modeled_speedup_vs_sync']:.2f}x",
        ))
    print(markdown_table(
        ("strategy/codec", "S", "P@10", "P@10 drop", "engine r/s",
         "modeled commits/s", "vs sync"), rows))
    if bts_top:
        print(f"\nbts/int8 at S={s_top}: modeled "
              f"{bts_top['modeled_speedup_vs_sync']:.2f}x more commits/sec "
              f"than the synchronous server at "
              f"{bts_top['p10_drop_pct_vs_sync']:+.1f}% P@10")
        assert bts_top["modeled_speedup_vs_sync"] >= 2.0, \
            "bounded-staleness pipeline must beat sync by >= 2x at S=4"

    if out_path:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=1, default=float)
        print(f"\nwrote {out_path}")
    return out


def run_quick(dataset: str = "movielens-mini") -> Dict:
    """The one quick-smoke grid (CLI --quick and benchmarks.run both use
    this, so the two can't drift): bts x int8 at S in {0, 2}, no artifact."""
    return run(dataset=dataset, rounds=40, theta=20,
               staleness_bounds=(0, 2), strategies=("bts",),
               codecs=("int8",), time_rounds=20, out_path=None)


def dry_run() -> Dict:
    """No simulations: the latency-pipeline model + byte math only."""
    rates = {s: modeled_commit_rate(s, theta=50, compute_s=0.01, rounds=400)
             for s in STALENESS_BOUNDS}
    assert rates[4] > 2.0 * rates[0], \
        "bounded-staleness pipeline model must beat sync"
    rows = [(s, f"{r:.4f}", f"{r / rates[0]:.2f}x")
            for s, r in rates.items()]
    print("\n[dry-run] async_cohorts — modeled commits/sec under the "
          f"lognormal straggler model (median {LATENCY_MEDIAN_S}s, "
          f"Theta=50)\n")
    print(markdown_table(("S", "commits/s", "vs sync"), rows))
    b = per_round_payload_bytes(30, 25, codec="int8", theta=50)
    print(f"ring cost at S=4, M_s=30, K=25, int8: "
          f"{5 * b['down']} bytes (5 wire images)")
    return {"dry_run": True, "modeled_rates": rates}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="movielens-mini")
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--quick", action="store_true",
                    help="fewer cells / rounds for smoke runs")
    ap.add_argument("--dry-run", action="store_true",
                    help="latency model + byte math only, run nothing")
    args = ap.parse_args(argv)
    if args.dry_run:
        return dry_run()
    if args.quick:
        return run_quick(dataset=args.dataset)
    return run(dataset=args.dataset, rounds=args.rounds)


if __name__ == "__main__":
    main()
