"""Paper Table 4: detailed 90% payload-reduction analysis.

mean±std across rebuilds for FCF / FCF-BTS / FCF-Random / TopList, plus the
paper's two summary statistics:
  Diff%  = |BTS - FCF| / FCF          (cost of the payload cut)
  Impr%  = |BTS - baseline| / baseline (gain over Random / TopList)
"""
from __future__ import annotations

import argparse
from typing import Dict, Optional, Sequence

from benchmarks.common import markdown_table
from benchmarks.fcf_experiments import (
    FULL, METRICS, QUICK, GridScale, cell_key, ensure_cells, grid_mean,
    toplist_baseline,
)

KEEP = 0.10     # 90% payload reduction


def run(scale: GridScale = QUICK) -> Dict:
    out: Dict = {"scale": scale.name, "datasets": {}}
    for ds in scale.datasets:
        full = grid_mean(ensure_cells(scale, ds, "full", 1.0))
        bts = grid_mean(ensure_cells(scale, ds, "bts", KEEP))
        rnd = grid_mean(ensure_cells(scale, ds, "random", KEEP))
        top = toplist_baseline(scale, ds, seed=0)["final"]

        def pct(a, b):
            return abs(a - b) / max(abs(b), 1e-9) * 100.0

        rows = []
        for name, stats in (("FCF", full), ("FCF-BTS", bts),
                            ("FCF-Random", rnd)):
            rows.append([name] + [f"{stats[m][0]:.4f}±{stats[m][1]:.4f}"
                                  for m in METRICS])
        rows.append(["TopList"] + [f"{top[m]:.4f}" for m in METRICS])
        rows.append(["BTS vs FCF (Diff%)"]
                    + [f"{pct(bts[m][0], full[m][0]):.2f}" for m in METRICS])
        rows.append(["BTS vs Random (Impr%)"]
                    + [f"{pct(bts[m][0], rnd[m][0]):.2f}" for m in METRICS])
        rows.append(["BTS vs TopList (Impr%)"]
                    + [f"{pct(bts[m][0], top[m]):.2f}" for m in METRICS])

        print(f"\n## Table 4 analogue — {ds} (90% payload reduction)\n")
        print(markdown_table(["method"] + [m.upper() for m in METRICS], rows))
        out["datasets"][ds] = {
            "full": full, "bts": bts, "random": rnd, "toplist": top,
            "diff_pct": {m: pct(bts[m][0], full[m][0]) for m in METRICS},
            "impr_random_pct": {m: pct(bts[m][0], rnd[m][0]) for m in METRICS},
            "impr_toplist_pct": {m: pct(bts[m][0], top[m]) for m in METRICS},
        }
    return out


def dry_run(scale: GridScale = QUICK) -> Dict:
    cells = [cell_key(scale, ds, s, k, 0) for ds in scale.datasets
             for s, k in (("full", 1.0), ("bts", KEEP), ("random", KEEP))]
    print(f"[dry-run] table4 — would read {len(cells)} grid points at "
          f"scale '{scale.name}' (none executed)")
    return {"dry_run": True, "cells": cells}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick",
                    choices=("quick", "mid", "full"))
    ap.add_argument("--dry-run", action="store_true",
                    help="list the grid points, execute nothing")
    args = ap.parse_args(argv)
    from benchmarks.fcf_experiments import MID
    scale = {"quick": QUICK, "mid": MID, "full": FULL}[args.scale]
    return dry_run(scale) if args.dry_run else run(scale)


if __name__ == "__main__":
    main()
