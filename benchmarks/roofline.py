"""Roofline report (deliverable g): reads the dry-run artifacts under
results/dryrun/ and emits the per-(arch x shape x mesh) three-term table.

  compute term    = corrected HLO FLOPs / (peak 197 TF/s bf16 per chip)
  memory term     = corrected HLO bytes / (819 GB/s HBM per chip)
  collective term = corrected collective bytes / (50 GB/s ICI per chip)

"corrected" = while-body trip-count correction (launch/dryrun.py): XLA's
cost analysis visits scan bodies once; two unrolled shallow probes recover
the exact per-period cost. MODEL_FLOPS = 6·N(_active)·D for train,
2·N·D for prefill, 2·N·B for a decode step.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import markdown_table

HBM_PER_CHIP = 16e9      # TPU v5e

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(dryrun_dir: str = "results/dryrun_final", mesh: str = "pod16x16",
                 tag: str = "") -> List[Dict]:
    recs = []
    suffix = f"__{tag}.json" if tag else ".json"
    for path in sorted(glob.glob(os.path.join(dryrun_dir, mesh, "*.json"))):
        base = os.path.basename(path)
        if tag:
            if not base.endswith(suffix):
                continue
        elif base.count("__") != 1:
            continue
        with open(path) as f:
            recs.append(json.load(f))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    return recs


def _fit(rec: Dict) -> str:
    ma = rec.get("memory_analysis", {})
    need = (ma.get("argument_size_in_bytes", 0)
            + ma.get("temp_size_in_bytes", 0)
            + ma.get("output_size_in_bytes", 0))
    return f"{need / 1e9:.1f}GB {'OK' if need <= HBM_PER_CHIP else 'OVER'}"


def table(recs: List[Dict]) -> str:
    rows = []
    for r in recs:
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], "—", "—", "—", "SKIP",
                         "—", "—"))
            continue
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        rows.append((
            r["arch"], r["shape"],
            f"{t['compute_s']:.2e}", f"{t['memory_s']:.2e}",
            f"{t['collective_s']:.2e}", t["bottleneck"],
            f"{ratio:.2f}" if ratio else "—", _fit(r)))
    return markdown_table(
        ("arch", "shape", "compute_s", "memory_s", "collective_s",
         "bottleneck", "useful/HLO", "mem/chip"), rows)


def summarize(recs: List[Dict]) -> Dict:
    ok = [r for r in recs if r["status"] == "ok"]
    bn: Dict[str, int] = {}
    for r in ok:
        bn[r["roofline"]["bottleneck"]] = bn.get(r["roofline"]["bottleneck"], 0) + 1
    worst = max(ok, key=lambda r: (r["roofline"]["step_time_s"]
                                   / max(r["roofline"]["compute_s"], 1e-12)),
                default=None)
    most_coll = max(ok, key=lambda r: r["roofline"]["collective_s"],
                    default=None)
    return {
        "n_ok": len(ok), "n_skip": len(recs) - len(ok),
        "bottlenecks": bn,
        "worst_roofline_fraction": (worst["arch"], worst["shape"])
        if worst else None,
        "most_collective_bound": (most_coll["arch"], most_coll["shape"])
        if most_coll else None,
    }


def run(dryrun_dir: str = "results/dryrun_final", mesh: str = "pod16x16") -> Dict:
    recs = load_records(dryrun_dir, mesh)
    if not recs:
        print(f"(no dry-run artifacts under {dryrun_dir}/{mesh} — "
              "run `python -m repro.launch.dryrun --all` first)")
        return {}
    print(f"\n## Roofline — {mesh} ({len(recs)} pairs)\n")
    print(table(recs))
    s = summarize(recs)
    print(f"\nbottleneck distribution: {s['bottlenecks']}; "
          f"worst roofline fraction: {s['worst_roofline_fraction']}; "
          f"most collective-bound: {s['most_collective_bound']}")
    return s


def main(argv: Optional[List[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16",
                    choices=("pod16x16", "pod2x16x16"))
    ap.add_argument("--dir", default="results/dryrun_final")
    ap.add_argument("--dry-run", action="store_true",
                    help="report which artifacts would be read, no tables")
    args = ap.parse_args(argv)
    if args.dry_run:
        recs = load_records(args.dir, args.mesh)
        print(f"[dry-run] roofline — {len(recs)} dry-run artifacts under "
              f"{args.dir}/{args.mesh}")
        return {"dry_run": True, "n_artifacts": len(recs)}
    return run(args.dir, args.mesh)


if __name__ == "__main__":
    main()
