"""Shared benchmark plumbing: result caching, tables, timing."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

RESULTS_DIR = os.environ.get("REPRO_RESULTS_DIR", "results")


def results_path(*parts: str) -> str:
    path = os.path.join(RESULTS_DIR, *parts)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    return path


def write_json(path: str, obj: Dict) -> None:
    """Atomically persist a result dict in the shared cache-file format."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path + ".tmp", "w") as f:
        json.dump(obj, f, indent=1, default=float)
    os.replace(path + ".tmp", path)


def cached(path: str, fn: Callable[[], Dict], force: bool = False) -> Dict:
    """Run ``fn`` once; memoize its JSON-serializable result at ``path``."""
    if not force and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    out = fn()
    write_json(path, out)
    return out


def markdown_table(headers: Sequence[str], rows: Sequence[Sequence[Any]]) -> str:
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)

    lines = ["| " + " | ".join(headers) + " |",
             "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        lines.append("| " + " | ".join(fmt(v) for v in r) + " |")
    return "\n".join(lines)


def per_round_payload_bytes(num_select: int, k: int, codec: str = "fp32",
                            theta: int = 1) -> Dict[str, int]:
    """One FL round's payload bytes — the schema shared by the perf benches.

    ``{"down": <server->cohort bytes>, "up": <cohort->server bytes>}`` with
    both directions priced by ``repro.compress.wire_bytes`` (the same
    function the traced in-state counters use), the uplink multiplied by the
    ``theta`` users whose updates trigger a commit. ``BENCH_round_engine.json``
    and ``BENCH_sharded_rounds.json`` both embed this dict per measured
    configuration so the perf trajectory can be read as (rounds/sec,
    bytes/round) pairs across files.
    """
    from repro.compress import CodecConfig, direction_configs, wire_bytes

    down_cfg, up_cfg = direction_configs(CodecConfig(name=codec))
    return {
        "down": wire_bytes(down_cfg, num_select, k),
        "up": wire_bytes(up_cfg, num_select, k) * theta,
    }


# ------------------------------------------------------------------ #
# committed-artifact schema (the BENCH_*.json CI guard)
# ------------------------------------------------------------------ #
# every committed artifact must name its experimental context at top level
BENCH_CONTEXT_KEYS = ("scale", "dataset")
# throughput figures: any key ending with this suffix is a rate and must be
# a finite positive number (rounds_per_sec, modeled_commits_per_sec, ...)
BENCH_RATE_SUFFIX = "per_sec"
# bytes_per_round dicts must price both wire directions (extras allowed)
BENCH_BYTES_KEYS = ("down", "up")
# cum_regret series (telemetry-derived) must be cumulative: finite,
# non-negative and non-decreasing — anything else means the traced regret
# port diverged from core/regret.RegretTracker
BENCH_REGRET_KEY = "cum_regret"


def validate_bench_artifact(obj: Any, name: str = "artifact") -> List[str]:
    """Schema errors for one committed ``BENCH_*.json`` payload ([] = valid).

    The committed artifacts have heterogeneous shapes (Pareto cells, mesh
    grids, staleness curves), so the contract is structural, matching what
    every perf bench emits through this module:

      * top level is a dict naming its context (``scale`` or ``dataset``),
      * every ``*per_sec`` rate anywhere in the tree is a finite positive
        number (a zero/NaN rate means a benchmark silently broke),
      * every ``bytes_per_round`` is a dict pricing both wire directions
        with positive integers (:func:`per_round_payload_bytes`'s shape),
      * every ``cum_regret`` list is a cumulative series: finite,
        non-negative, non-decreasing numbers (the telemetry-derived regret
        sections written by benchmarks/round_engine.py),
      * at least one rate figure exists (an artifact with no measurements
        is not a benchmark result).

    ``tests/test_bench_schema.py`` runs this over every committed artifact
    so stale or hand-edited files fail CI.
    """
    import math

    errors: List[str] = []
    if not isinstance(obj, dict):
        return [f"{name}: top level must be a dict, got {type(obj).__name__}"]
    if not any(k in obj for k in BENCH_CONTEXT_KEYS):
        errors.append(f"{name}: top level must name its context via one of "
                      f"{BENCH_CONTEXT_KEYS}")
    rates = 0

    def walk(node: Any, path: str) -> None:
        nonlocal rates
        if isinstance(node, dict):
            for key, val in node.items():
                here = f"{path}.{key}"
                if isinstance(key, str) and key.endswith(BENCH_RATE_SUFFIX):
                    rates += 1
                    if not isinstance(val, (int, float)) \
                            or isinstance(val, bool) \
                            or not math.isfinite(val) or val <= 0:
                        errors.append(f"{name}: {here} must be a finite "
                                      f"positive rate, got {val!r}")
                elif key == "bytes_per_round":
                    if not isinstance(val, dict):
                        errors.append(f"{name}: {here} must be a dict")
                        continue
                    for d in BENCH_BYTES_KEYS:
                        b = val.get(d)
                        if not isinstance(b, int) or isinstance(b, bool) \
                                or b <= 0:
                            errors.append(
                                f"{name}: {here}[{d!r}] must be a positive "
                                f"int byte count, got {b!r}")
                elif key == BENCH_REGRET_KEY and isinstance(val, list):
                    bad = [v for v in val
                           if not isinstance(v, (int, float))
                           or isinstance(v, bool)
                           or not math.isfinite(v) or v < 0]
                    if bad:
                        errors.append(
                            f"{name}: {here} must hold finite non-negative "
                            f"numbers, got {bad[:3]!r}")
                    elif any(b < a for a, b in zip(val, val[1:])):
                        errors.append(
                            f"{name}: {here} must be non-decreasing "
                            "(cumulative regret cannot shrink)")
                else:
                    walk(val, here)
        elif isinstance(node, list):
            for i, val in enumerate(node):
                walk(val, f"{path}[{i}]")

    walk(obj, name)
    if rates == 0:
        errors.append(f"{name}: no '*{BENCH_RATE_SUFFIX}' rate found — an "
                      "artifact with no measurements is not a bench result")
    return errors


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (blocks on jax arrays)."""
    import jax

    def call():
        out = fn(*args)
        jax.block_until_ready(out)
        return out

    for _ in range(warmup):
        call()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        call()
        times.append(time.perf_counter() - t0)
    times.sort()
    return 1e6 * times[len(times) // 2]
