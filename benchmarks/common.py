"""Shared benchmark plumbing: result caching, tables, timing."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

RESULTS_DIR = os.environ.get("REPRO_RESULTS_DIR", "results")


def results_path(*parts: str) -> str:
    path = os.path.join(RESULTS_DIR, *parts)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    return path


def write_json(path: str, obj: Dict) -> None:
    """Atomically persist a result dict in the shared cache-file format."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path + ".tmp", "w") as f:
        json.dump(obj, f, indent=1, default=float)
    os.replace(path + ".tmp", path)


def cached(path: str, fn: Callable[[], Dict], force: bool = False) -> Dict:
    """Run ``fn`` once; memoize its JSON-serializable result at ``path``."""
    if not force and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    out = fn()
    write_json(path, out)
    return out


def markdown_table(headers: Sequence[str], rows: Sequence[Sequence[Any]]) -> str:
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)

    lines = ["| " + " | ".join(headers) + " |",
             "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        lines.append("| " + " | ".join(fmt(v) for v in r) + " |")
    return "\n".join(lines)


def per_round_payload_bytes(num_select: int, k: int, codec: str = "fp32",
                            theta: int = 1) -> Dict[str, int]:
    """One FL round's payload bytes — the schema shared by the perf benches.

    ``{"down": <server->cohort bytes>, "up": <cohort->server bytes>}`` with
    both directions priced by ``repro.compress.wire_bytes`` (the same
    function the traced in-state counters use), the uplink multiplied by the
    ``theta`` users whose updates trigger a commit. ``BENCH_round_engine.json``
    and ``BENCH_sharded_rounds.json`` both embed this dict per measured
    configuration so the perf trajectory can be read as (rounds/sec,
    bytes/round) pairs across files.
    """
    from repro.compress import CodecConfig, direction_configs, wire_bytes

    down_cfg, up_cfg = direction_configs(CodecConfig(name=codec))
    return {
        "down": wire_bytes(down_cfg, num_select, k),
        "up": wire_bytes(up_cfg, num_select, k) * theta,
    }


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (blocks on jax arrays)."""
    import jax

    def call():
        out = fn(*args)
        jax.block_until_ready(out)
        return out

    for _ in range(warmup):
        call()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        call()
        times.append(time.perf_counter() - t0)
    times.sort()
    return 1e6 * times[len(times) // 2]
