"""The FCF-BTS experiment grid (Sec. 6 of the paper), cached per cell.

A *cell* is one (dataset, strategy, keep_fraction, rebuild-seed) simulation.
``reduction_sweep`` / ``table4`` / ``convergence`` are views over the grid;
missing cells run on demand and persist under results/fcf/.

Two scales:
  quick — mini datasets (same generator, smaller N/M), fewer rounds; the
          scale ``python -m benchmarks.run`` exercises end-to-end.
  full  — paper-sized synthetic datasets (Table 2 stats) and 1000 rounds;
          produces the EXPERIMENTS.md headline numbers (hours of CPU).
Usage:  PYTHONPATH=src python -m benchmarks.fcf_experiments --dry-run
        (the grid itself is driven by the view modules / benchmarks.run)
"""
from __future__ import annotations

import argparse
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.cf.toplist import evaluate_toplist
from repro.data.synthetic import load_dataset
from repro.federated.simulation import (
    FLSimConfig, SimResult, run_fcf_simulation, run_seed_sweep,
)

from benchmarks.common import cached, results_path, write_json


@dataclass(frozen=True)
class GridScale:
    name: str
    datasets: Tuple[str, ...]
    rounds: int
    theta: int
    eval_every: int
    eval_users: int
    rebuilds: int = 3


QUICK = GridScale("quick", ("movielens-mini", "lastfm-mini", "mind-mini"),
                  rounds=200, theta=50, eval_every=25, eval_users=256,
                  rebuilds=2)
FULL = GridScale("full", ("movielens", "lastfm", "mind"),
                 rounds=1000, theta=100, eval_every=25, eval_users=512,
                 rebuilds=3)
# paper-sized datasets at CPU-tractable rounds: the EXPERIMENTS.md headline
MID = GridScale("mid", ("movielens", "lastfm", "mind"),
                rounds=500, theta=100, eval_every=50, eval_users=256,
                rebuilds=2)
# paper Sec 6.1: theta is dataset-dependent at full scale
FULL_THETA = {"movielens": 100, "lastfm": 100, "mind": 500}

METRICS = ("precision", "recall", "f1", "map")


def cell_key(scale: GridScale, dataset: str, strategy: str,
             keep: float, seed: int) -> str:
    return (f"{scale.name}__{dataset}__{strategy}"
            f"__k{int(round(100 * keep)):03d}__s{seed}")


def _cell_config(scale: GridScale, dataset: str, strategy: str, keep: float,
                 seed: int) -> FLSimConfig:
    return FLSimConfig(
        strategy=strategy, keep_fraction=keep, rounds=scale.rounds,
        theta=FULL_THETA.get(dataset, scale.theta),
        eval_every=scale.eval_every, eval_users=scale.eval_users, seed=seed)


def _cell_payload(scale: GridScale, dataset: str, strategy: str, keep: float,
                  seed: int, res: SimResult, seconds: float) -> Dict:
    return {
        "dataset": dataset, "strategy": strategy, "keep": keep,
        "seed": seed, "rounds": scale.rounds,
        "final": res.final,
        "trajectory": {
            "t": [r["step"] for r in res.history.rows],
            **{m: res.history.series(m) for m in METRICS}},
        "bytes_down": res.bytes_down, "bytes_up": res.bytes_up,
        "seconds": seconds,
    }


def run_cell(scale: GridScale, dataset: str, strategy: str, keep: float,
             seed: int, force: bool = False) -> Dict:
    """One simulation cell -> {final metrics, trajectory, bytes, seconds}."""
    def compute():
        _, train, test = load_dataset(dataset, seed=seed)
        t0 = time.time()
        res = run_fcf_simulation(
            train, test, _cell_config(scale, dataset, strategy, keep, seed))
        return _cell_payload(scale, dataset, strategy, keep, seed, res,
                             time.time() - t0)

    path = results_path("fcf", cell_key(scale, dataset, strategy, keep, seed)
                        + ".json")
    return cached(path, compute, force=force)


def toplist_baseline(scale: GridScale, dataset: str, seed: int) -> Dict:
    """TopList metrics, normalized by the theoretical best (Sec. 6.2)."""
    def compute():
        _, train, test = load_dataset(dataset, seed=seed)
        train_j, test_j = jnp.asarray(train), jnp.asarray(test)
        counts = train_j.sum(axis=0)
        # evaluate_toplist -> ranked_metrics, already normalized by the
        # per-user theoretical best (Sec. 6.2)
        m = evaluate_toplist(counts, train_j, test_j)
        final = m.as_dict()
        return {"dataset": dataset, "strategy": "toplist", "seed": seed,
                "final": final}

    path = results_path("fcf", f"{scale.name}__{dataset}__toplist__s{seed}.json")
    return cached(path, compute)


def grid_mean(cells: Sequence[Dict]) -> Dict[str, Tuple[float, float]]:
    """mean +/- std of final metrics across rebuild seeds."""
    out = {}
    for m in METRICS:
        vals = [c["final"][m] for c in cells]
        out[m] = (float(np.mean(vals)), float(np.std(vals)))
    return out


def ensure_cells(scale: GridScale, dataset: str, strategy: str,
                 keep: float) -> List[Dict]:
    """All rebuild-seed cells for one (dataset, strategy, keep) point.

    Missing seeds are computed together through the vmapped scan engine
    (:func:`run_seed_sweep`) — one compile + one device program for the whole
    rebuild axis — and persisted to the same per-seed JSON cache files that
    :func:`run_cell` writes, so views over the grid are oblivious to which
    path produced a cell.
    """
    seeds = list(range(scale.rebuilds))
    paths = {
        s: results_path("fcf", cell_key(scale, dataset, strategy, keep, s)
                        + ".json")
        for s in seeds
    }
    missing = [s for s in seeds if not os.path.exists(paths[s])]
    if len(missing) > 1:
        # rebuild seeds regenerate the dataset: stack per-seed matrices
        data = [load_dataset(dataset, seed=s)[1:] for s in missing]
        train = np.stack([d[0] for d in data])
        test = np.stack([d[1] for d in data])
        cfg = _cell_config(scale, dataset, strategy, keep, missing[0])
        t0 = time.time()
        sweep = run_seed_sweep(train, test, cfg, seeds=missing)
        seconds = (time.time() - t0) / max(len(missing), 1)
        for s, res in zip(missing, sweep):
            write_json(paths[s], _cell_payload(scale, dataset, strategy,
                                               keep, s, res, seconds))
    return [run_cell(scale, dataset, strategy, keep, seed) for seed in seeds]


def dry_run(scale: GridScale = QUICK) -> Dict:
    """Enumerate the grid without running a cell: configs must construct
    and cache paths must resolve (catches config/IO rot cheaply)."""
    planned = []
    for ds in scale.datasets:
        for strategy, keep in (("full", 1.0), ("bts", 0.1), ("random", 0.1)):
            for seed in range(scale.rebuilds):
                _cell_config(scale, ds, strategy, keep, seed)   # validates
                planned.append(
                    results_path("fcf", cell_key(scale, ds, strategy, keep,
                                                 seed) + ".json"))
    print(f"[dry-run] fcf_experiments — {len(planned)} cells planned at "
          f"scale '{scale.name}' (none executed)")
    return {"dry_run": True, "cells_planned": len(planned)}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick",
                    choices=("quick", "mid", "full"))
    ap.add_argument("--dry-run", action="store_true",
                    help="enumerate grid cells, execute nothing")
    args = ap.parse_args(argv)
    scale = {"quick": QUICK, "mid": MID, "full": FULL}[args.scale]
    if args.dry_run:
        return dry_run(scale)
    out: Dict = {}
    for ds in scale.datasets:
        out[ds] = grid_mean(ensure_cells(scale, ds, "bts", 0.1))
    return out


if __name__ == "__main__":
    main()
