"""Optimizer-state compression: resident footprint, throughput, parity.

The paper shrinks the wire; :mod:`repro.optim.state_compress` shrinks what
stays resident. Three sections, one artifact (``BENCH_optimizer_state.json``):

  * FOOTPRINT + THROUGHPUT AT SCALE — for M in {10^5, 10^6, 10^7} rows
    (K=16), allocate the per-row AdamState under each moment config and
    drive the REAL commit path (``adam_update_rows_scattered`` — the same
    function every round engine calls) with synthetic payload gradients.
    Reports measured resident state bytes (leaf ``nbytes``, cross-checked
    against the static ``state_nbytes`` accounting) and commits/sec. At
    M=10^7 the section enforces a resident-state BUDGET equal to the model
    table's own bytes (4*M*K): fp32 moments are 2x that budget — they
    cannot fit — so only configs under budget run, and the bench asserts
    in-code that fp32 exceeds the budget while the compressed configs
    clear it.
  * CONVERGENCE PARITY — movielens-mini, all four selection strategies
    (bts / random / full / magnitude), fp32 Adam vs each compressed
    moment config at equal seeds. Emits the full P@10 eval curves and
    final (trailing-10) metrics; asserts P@10 for bts and random stays
    within 2% of fp32 Adam for every compressed config.
  * FROZEN fp32 CONTRACT — across all four backends (scan / python /
    shard / async) the default run and a run with an explicit all-fp32
    ``MomentCodecConfig`` must produce bit-identical final Q tables: the
    fp32 path is not routed through the compression module at all.

Usage:  PYTHONPATH=src python -m benchmarks.optimizer_state [--quick|--dry-run]
"""
from __future__ import annotations

import argparse
import functools
import json
import time
from dataclasses import replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from benchmarks.common import markdown_table

OUT_PATH = "BENCH_optimizer_state.json"
K_DIM = 16
M_SCALES = (100_000, 1_000_000, 10_000_000)
NUM_SELECT = 512
# (m_dtype, v_dtype) — None is the frozen fp32 baseline
MOMENT_CONFIGS: Tuple[Optional[Tuple[str, str]], ...] = (
    None, ("bf16", "bf16"), ("int8", "int8"), ("int8", "factored"),
)
STRATEGIES = ("bts", "random", "full", "magnitude")
# strategies the 2%-of-fp32 parity assertion covers (the paper's two)
ASSERT_STRATEGIES = ("bts", "random")
PARITY_TOLERANCE = 0.02


def _cfg_tag(mom: Optional[Tuple[str, str]]) -> str:
    return "fp32" if mom is None else f"{mom[0]}+{mom[1]}"


def _moment(mom: Optional[Tuple[str, str]]):
    from repro.optim.state_compress import MomentCodecConfig

    if mom is None:
        return None
    return MomentCodecConfig(m_dtype=mom[0], v_dtype=mom[1])


# ------------------------------------------------------------------ #
# footprint + throughput: the real commit update at table scale
# ------------------------------------------------------------------ #
def _measured_state_bytes(state) -> int:
    import jax

    return int(sum(np.asarray(leaf).nbytes if leaf.ndim == 0 else leaf.nbytes
                   for leaf in jax.tree.leaves(state)))


def footprint_cells(
    scales: Sequence[int] = M_SCALES,
    configs: Sequence[Optional[Tuple[str, str]]] = MOMENT_CONFIGS,
    num_select: int = NUM_SELECT,
    iters: int = 20,
) -> List[Dict]:
    """One cell per (M, moment config): resident bytes + commits/sec.

    The budget at each scale is the model table's own size (4*M*K bytes).
    Configs over budget are recorded (static accounting) but NOT run —
    at M=10^7 that is fp32 (2x budget) and bf16+bf16 (1.06x): the point
    of the section is that compressed state trains tables fp32 moments
    cannot, so the bench refuses to allocate over-budget states at the
    largest scale rather than quietly relying on a 125 GB host.
    """
    import jax
    import jax.numpy as jnp

    from repro.optim.adam import AdamConfig, adam_init, \
        adam_update_rows_scattered
    from repro.optim.state_compress import state_nbytes

    @functools.partial(jax.jit, donate_argnums=(2, 3),
                       static_argnames=("moment",))
    def step(grads, idx, state, table, key, moment):
        return adam_update_rows_scattered(
            grads, idx, state, table, AdamConfig(), moment=moment,
            moment_key=key)

    cells: List[Dict] = []
    largest = max(scales)
    for m in scales:
        budget = 4 * m * K_DIM                    # the model's own bytes
        for mom in configs:
            mc = _moment(mom)
            static_bytes = state_nbytes(mc, m, K_DIM)
            fits = static_bytes <= budget
            cell = {
                "num_rows": m, "dim": K_DIM, "moment": _cfg_tag(mom),
                "state_bytes": static_bytes,
                "budget_bytes": budget,
                "bytes_vs_fp32": static_bytes / state_nbytes(None, m, K_DIM),
                "fits_budget": fits,
            }
            if not fits and m == largest:
                # over-budget at the headline scale: accounted, never run
                cells.append(cell)
                continue
            key = jax.random.PRNGKey(0)
            table = jnp.zeros((m, K_DIM), jnp.float32)
            state = adam_init(table, per_row=True, moment=mc)
            cell["measured_state_bytes"] = _measured_state_bytes(state)
            assert cell["measured_state_bytes"] == static_bytes, (
                f"{_cfg_tag(mom)} at M={m}: measured "
                f"{cell['measured_state_bytes']} != static accounting "
                f"{static_bytes}")
            grads = jax.random.normal(key, (num_select, K_DIM), jnp.float32)
            idx = jnp.arange(num_select, dtype=jnp.int32) * (m // num_select)
            # warmup (compile) then timed committed updates
            table2, state = step(grads, idx, state, table, key, mc)
            jax.block_until_ready(table2)
            t0 = time.perf_counter()
            for i in range(iters):
                table2, state = step(grads, idx, state, table2,
                                     jax.random.fold_in(key, i), mc)
            jax.block_until_ready(table2)
            secs = time.perf_counter() - t0
            cell["commits_per_sec"] = iters / secs
            cells.append(cell)
            del table, table2, state

    # the headline budget assertions: at the largest scale fp32 cannot fit
    # and every config that ran came in under budget
    big = [c for c in cells if c["num_rows"] == largest]
    fp32 = next(c for c in big if c["moment"] == "fp32")
    assert not fp32["fits_budget"], (
        "fp32 moments fit the model-sized budget at the largest scale — "
        "the bench's premise is broken (did K or the budget change?)")
    ran = [c for c in big if "commits_per_sec" in c]
    assert ran and all(c["state_bytes"] <= c["budget_bytes"] for c in ran), \
        "a config ran at the largest scale while over the resident budget"
    return cells


# ------------------------------------------------------------------ #
# convergence parity: P@10 curves vs fp32 Adam, all four strategies
# ------------------------------------------------------------------ #
def parity_cells(
    dataset: str = "movielens-mini",
    rounds: int = 200,
    theta: int = 40,
    strategies: Sequence[str] = STRATEGIES,
    configs: Sequence[Optional[Tuple[str, str]]] = MOMENT_CONFIGS,
    seed: int = 0,
    assert_parity: bool = True,
) -> Tuple[Dict, List[Dict]]:
    from repro.data.synthetic import load_dataset
    from repro.federated.simulation import FLSimConfig, run_fcf_simulation

    spec, train, test = load_dataset(dataset, seed=seed)
    base = FLSimConfig(rounds=rounds, theta=theta, keep_fraction=0.1,
                       eval_every=max(rounds // 8, 1),
                       eval_users=min(256, train.shape[0]), seed=seed)
    cells: List[Dict] = []
    fp32_p10: Dict[str, float] = {}
    for strategy in strategies:
        for mom in configs:
            cfg = replace(
                base, strategy=strategy,
                moment_m_dtype="fp32" if mom is None else mom[0],
                moment_v_dtype="fp32" if mom is None else mom[1])
            t0 = time.perf_counter()
            res = run_fcf_simulation(train, test, cfg)
            secs = time.perf_counter() - t0
            p10 = res.smoothed("precision")
            if mom is None:
                fp32_p10[strategy] = p10
            cells.append({
                "strategy": strategy, "moment": _cfg_tag(mom),
                "precision_at_10": p10,
                "p10_vs_fp32": p10 / max(fp32_p10[strategy], 1e-9),
                "f1": res.final["f1"], "map": res.final["map"],
                "p10_curve": [float(v)
                              for v in res.history.series("precision")],
                "rounds_per_sec": rounds / secs,
            })
    # the parity contract: bts and random stay within tolerance of fp32
    # (only enforced at full round counts — short smoke runs are noisier)
    for c in cells if assert_parity else []:
        if c["strategy"] in ASSERT_STRATEGIES and c["moment"] != "fp32":
            assert c["p10_vs_fp32"] >= 1.0 - PARITY_TOLERANCE, (
                f"{c['strategy']}/{c['moment']}: P@10 ratio "
                f"{c['p10_vs_fp32']:.4f} below the "
                f"{1.0 - PARITY_TOLERANCE:.2f} parity floor vs fp32 Adam")
    meta = {"name": spec.name, "users": int(train.shape[0]),
            "items": int(train.shape[1]), "rounds": rounds, "theta": theta}
    return meta, cells


# ------------------------------------------------------------------ #
# frozen fp32 contract: default == explicit-fp32, every backend, bitwise
# ------------------------------------------------------------------ #
def frozen_cells(dataset: str = "movielens-mini", rounds: int = 12,
                 seed: int = 0) -> List[Dict]:
    import jax

    from repro.data.synthetic import load_dataset
    from repro.federated.simulation import FLSimConfig, run_fcf_simulation

    _, train, test = load_dataset(dataset, seed=seed)
    backends = ["scan", "python", "async"]
    if len(jax.devices()) > 1:
        backends.append("shard")
    cells: List[Dict] = []
    for backend in backends:
        base = FLSimConfig(rounds=rounds, theta=20, keep_fraction=0.1,
                           eval_every=rounds, eval_users=64, seed=seed,
                           backend=backend,
                           max_staleness=2 if backend == "async" else 0)
        a = run_fcf_simulation(train, test, base)
        # moment_*_dtype="fp32" explicitly: must not change one bit
        b = run_fcf_simulation(train, test, replace(
            base, moment_m_dtype="fp32", moment_v_dtype="fp32"))
        identical = bool(np.array_equal(np.asarray(a.server_state.q),
                                        np.asarray(b.server_state.q)))
        assert identical, (
            f"backend={backend}: explicit fp32 moment config changed the "
            "trajectory — the frozen contract is broken")
        cells.append({"backend": backend, "rounds": rounds,
                      "bit_identical": identical})
    return cells


# ------------------------------------------------------------------ #
def run(out_path: Optional[str] = OUT_PATH, rounds: int = 200,
        scales: Sequence[int] = M_SCALES,
        strategies: Sequence[str] = STRATEGIES,
        assert_parity: bool = True) -> Dict:
    foot = footprint_cells(scales=scales)
    ds_meta, parity = parity_cells(rounds=rounds, strategies=strategies,
                                   assert_parity=assert_parity)
    frozen = frozen_cells()

    headline = {
        "largest_table_rows": max(scales),
        "best_bytes_vs_fp32": min(c["bytes_vs_fp32"] for c in foot),
        "fp32_fits_largest": next(
            c["fits_budget"] for c in foot
            if c["num_rows"] == max(scales) and c["moment"] == "fp32"),
        "worst_assert_p10_ratio": min(
            c["p10_vs_fp32"] for c in parity
            if c["strategy"] in ASSERT_STRATEGIES and c["moment"] != "fp32"),
        "frozen_fp32_bit_identical": all(
            c["bit_identical"] for c in frozen),
    }
    out = {
        "scale": {"dim": K_DIM, "num_select": NUM_SELECT,
                  "table_rows": list(scales)},
        "dataset": ds_meta,
        "headline": headline,
        "footprint_cells": foot,
        "parity_cells": parity,
        "frozen_cells": frozen,
    }

    print("\n## Optimizer state — resident footprint + commits/sec "
          f"(K={K_DIM}, M_s={NUM_SELECT})\n")
    rows = [(f"{c['num_rows']:.0e}", c["moment"],
             f"{c['state_bytes'] / 1e6:.1f} MB",
             f"{c['bytes_vs_fp32']:.2f}x",
             "yes" if c["fits_budget"] else "NO",
             f"{c['commits_per_sec']:.1f}" if "commits_per_sec" in c
             else "(over budget)") for c in foot]
    print(markdown_table(("rows", "moments", "state bytes", "vs fp32",
                          "fits budget", "commits/s"), rows))
    print(f"\n## Convergence parity — P@10 vs fp32 Adam "
          f"({ds_meta['name']}, {rounds} rounds)\n")
    rows = [(c["strategy"], c["moment"], f"{c['precision_at_10']:.4f}",
             f"{100.0 * (c['p10_vs_fp32'] - 1.0):+.1f}%",
             f"{c['rounds_per_sec']:.0f}") for c in parity]
    print(markdown_table(("strategy", "moments", "P@10", "vs fp32",
                          "rounds/s"), rows))
    print("\nfrozen fp32 contract: " + ", ".join(
        f"{c['backend']}={'OK' if c['bit_identical'] else 'BROKEN'}"
        for c in frozen))

    if out_path:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=1, default=float)
        print(f"\nwrote {out_path}")
    return out


def run_quick() -> Dict:
    """Smoke grid: smallest two scales, two strategies, no artifact."""
    return run(out_path=None, rounds=40, scales=M_SCALES[:2],
               strategies=("bts", "random"), assert_parity=False)


def dry_run() -> Dict:
    """No table allocations beyond M=10^6: static byte accounting, the
    M=10^6 compressed-vs-fp32 footprint assertion, and one real committed
    update per config at small M (the CI bench-smoke path)."""
    import jax
    import jax.numpy as jnp

    from repro.optim.adam import AdamConfig, adam_init, \
        adam_update_rows_scattered
    from repro.optim.state_compress import state_nbytes

    m_check = 1_000_000
    fp32_bytes = state_nbytes(None, m_check, K_DIM)
    rows = []
    for mom in MOMENT_CONFIGS:
        mc = _moment(mom)
        b = state_nbytes(mc, m_check, K_DIM)
        if mom is not None:
            assert b < fp32_bytes, (
                f"{_cfg_tag(mom)}: compressed resident state "
                f"({b} B) not below fp32 ({fp32_bytes} B) at M={m_check}")
        rows.append((_cfg_tag(mom), f"{b / 1e6:.1f} MB",
                     f"{b / fp32_bytes:.2f}x"))
        # one real committed update per config (tiny table): the compressed
        # paths must execute, not just account
        q = jnp.zeros((64, K_DIM), jnp.float32)
        st = adam_init(q, per_row=True, moment=mc)
        g = jnp.ones((8, K_DIM), jnp.float32)
        idx = jnp.arange(8, dtype=jnp.int32)
        q2, st2 = adam_update_rows_scattered(
            g, idx, st, q, AdamConfig(), moment=mc,
            moment_key=jax.random.PRNGKey(0))
        assert bool(jnp.any(q2 != q)), f"{_cfg_tag(mom)}: update was a no-op"
    print(f"\n[dry-run] optimizer_state — resident AdamState at M={m_check:.0e},"
          f" K={K_DIM} (compressed must undercut fp32)\n")
    print(markdown_table(("moments", "state bytes", "vs fp32"), rows))
    return {"dry_run": True, "fp32_bytes_at_1e6": fp32_bytes}


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--quick", action="store_true",
                    help="smaller scales / fewer strategies, no artifact")
    ap.add_argument("--dry-run", action="store_true",
                    help="byte accounting + tiny updates only")
    args = ap.parse_args(argv)
    if args.dry_run:
        return dry_run()
    if args.quick:
        return run_quick()
    return run(rounds=args.rounds)


if __name__ == "__main__":
    main()
