"""Per-row wire-payload integrity checksums.

Each encoded wire row gets a 4-byte position-weighted wrap-sum over its
raw bit words: every element of every leaf is bitcast/widened to int32
and summed as ``sum_j word_j * (2*j + 1)`` in wrapping int32 arithmetic.
Because every position weight is odd, a single flipped bit at word j
changes the sum by ``±2^k * (2*j + 1) != 0 (mod 2^32)`` — so *any*
single-bit corruption of a row is detected with certainty (multi-bit
damage is detected with probability ~1 - 2^-32, the usual checksum
regime).

The checksum travels as a *parallel* ``(rows,) int32`` array, not as a
wire leaf: the ``wire_bytes == wire nbytes`` contract of
:func:`repro.compress.wire_bytes` stays exact, and the +4 bytes/row
overhead is accounted explicitly by the round step when integrity
checking is active (``CHECKSUM_BYTES_PER_ROW``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

CHECKSUM_BYTES_PER_ROW = 4


def _leaf_words(leaf: jax.Array) -> jax.Array:
    """View one wire leaf as (rows, words) int32 — injectively per word."""
    rows = leaf.shape[0]
    flat = leaf.reshape(rows, -1)
    if flat.dtype == jnp.float32:
        return jax.lax.bitcast_convert_type(flat, jnp.int32)
    if flat.dtype == jnp.float16:
        w16 = jax.lax.bitcast_convert_type(flat, jnp.int16)
        return w16.astype(jnp.int32)
    if flat.dtype == jnp.int32:
        return flat
    # int8 / uint8 (quantized values, packed int4 nibbles): sign/zero
    # extension is injective per byte
    return flat.astype(jnp.int32)


def row_checksums(wire: Any) -> jax.Array:
    """(rows,) int32 position-weighted wrap-sum over a wire pytree."""
    words = jnp.concatenate(
        [_leaf_words(leaf) for leaf in jax.tree_util.tree_leaves(wire)],
        axis=1)
    weights = 2 * jnp.arange(words.shape[1], dtype=jnp.int32) + 1
    return jnp.sum(words * weights, axis=1, dtype=jnp.int32)


def verify_rows(wire: Any, checksums: jax.Array) -> jax.Array:
    """(rows,) bool — True where the received row matches its checksum."""
    return row_checksums(wire) == checksums
