"""Payload compression subsystem: quantized wire formats for FL payloads.

See :mod:`repro.compress.codecs` for the codec registry and
:mod:`repro.kernels.payload_quant` for the fused server-side kernels.
"""
from repro.compress.checksum import (
    CHECKSUM_BYTES_PER_ROW,
    row_checksums,
    verify_rows,
)
from repro.compress.codecs import (
    CODECS,
    CodecConfig,
    CodecState,
    DenseWire,
    QuantWire,
    TopKWire,
    Wire,
    codec_state_init,
    compression_ratio,
    decode,
    decode_row_block,
    dense_bytes,
    dequantize_rows,
    direction_configs,
    encode,
    encode_with_residual,
    is_stateful,
    pack_int4,
    quantize_rows,
    quantize_rows_stochastic,
    roundtrip,
    slice_rows,
    topk_k,
    unpack_int4,
    validate_config,
    wire_bytes,
    wire_resident_bytes,
)

__all__ = [
    "CHECKSUM_BYTES_PER_ROW", "row_checksums", "verify_rows",
    "CODECS", "CodecConfig", "CodecState", "DenseWire", "QuantWire",
    "TopKWire", "Wire", "codec_state_init", "compression_ratio", "decode",
    "decode_row_block", "dense_bytes", "dequantize_rows",
    "direction_configs", "encode", "encode_with_residual",
    "is_stateful", "pack_int4", "quantize_rows",
    "quantize_rows_stochastic", "roundtrip", "slice_rows",
    "topk_k", "unpack_int4", "validate_config", "wire_bytes",
    "wire_resident_bytes",
]
