"""Pure payload codecs — the bits-per-row axis of payload optimization.

The paper cuts payload along one axis: *which* rows move (bandit selection).
This module adds the second axis: *how many bits* each transmitted row
costs. A codec maps a dense (rows, dim) float32 payload block to a wire
pytree (what would actually cross the network) and back:

    wire          = encode(cfg, rows)
    rows_hat      = decode(cfg, wire)
    bytes_on_wire = wire_bytes(cfg, num_rows, dim)     # static Python int

Wire formats (registry ``CODECS``):

  * ``fp32`` — passthrough (the repo's historical format; exact).
  * ``fp16`` — IEEE half precision, 2 bytes/value.
  * ``int8`` — uniform per-row-scale quantization, 1 byte/value + one
               float32 scale per row. Backed by the fused Pallas
               gather+quantize / dequantize+scatter kernels on the
               server hot path (:mod:`repro.kernels.payload_quant`).
  * ``int4`` — 15-level symmetric quantization, two values packed per
               byte + one float32 scale per row.
  * ``topk`` — magnitude sparsification: the ``topk_fraction`` largest-
               magnitude entries per row as (float32 value, int32 index)
               pairs. Stateful: the dropped mass is carried as an
               error-feedback residual (a pytree living in
               ``ServerState.codec``) and re-injected next time the row
               is transmitted, so the *cumulative* update converges even
               though each round's wire image is sparse.

Every function here is pure jnp with static shapes, so codecs trace inside
``jit``/``lax.scan``/``vmap`` (the round engine carries the codec state as
part of the scan carry). Dispatch on ``cfg.name`` happens in Python at
trace time, exactly like strategy dispatch in :mod:`repro.core.selector`.

Byte accounting everywhere in the repo routes through :func:`wire_bytes` /
:func:`dense_bytes` so the simulation, the LLM driver and the paper-table
formulas can never disagree. ``wire_bytes`` equals the sum of the actual
wire arrays' ``nbytes`` exactly — enforced by a property test.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

CODECS = ("fp32", "fp16", "int8", "int4", "topk")

# quantization grids: symmetric int8 uses the full [-127, 127] range;
# int4 uses the 15-level symmetric grid [-7, 7] (the -8 code is unused so
# that 0.0 encodes exactly and dequantization is a pure scale multiply)
_QMAX = {8: 127.0, 4: 7.0}


class CodecConfig(NamedTuple):
    """Static (hashable) codec hyper-parameters, fixed for a whole run."""

    name: str = "fp32"
    topk_fraction: float = 0.25   # fraction of dim kept per row (topk only)
    error_feedback: bool = True   # topk: carry dropped mass as a residual
    # int4: carry the quantization error as an uplink EF residual too. Opt-in
    # (unlike topk's default-on flag) so existing int4 trajectories and the
    # ServerState.codec pytree shape stay unchanged unless asked for.
    int4_error_feedback: bool = False


class DenseWire(NamedTuple):
    """fp32 / fp16: the payload block itself (possibly narrowed)."""

    values: jax.Array             # (rows, dim) float32 or float16


class QuantWire(NamedTuple):
    """int8 / int4: quantized codes + one float32 scale per row."""

    values: jax.Array             # int8 (rows, dim) | uint8 (rows, ceil(dim/2))
    scales: jax.Array             # (rows, 1) float32


class TopKWire(NamedTuple):
    """topk: per-row (value, index) pairs for the surviving entries."""

    values: jax.Array             # (rows, k) float32
    indices: jax.Array            # (rows, k) int32


Wire = Union[DenseWire, QuantWire, TopKWire]

# stateless codecs carry an empty pytree; topk+error_feedback carries the
# full-table residual (scan/vmap axis like every other ServerState leaf)
CodecState = Any


def validate_config(cfg: CodecConfig) -> None:
    if cfg.name not in CODECS:
        raise ValueError(f"codec must be one of {CODECS}, got {cfg.name!r}")
    if cfg.name == "topk" and not (0.0 < cfg.topk_fraction <= 1.0):
        raise ValueError(
            f"topk_fraction must be in (0, 1], got {cfg.topk_fraction}")


def topk_k(cfg: CodecConfig, dim: int) -> int:
    """Static per-row survivor count for the topk codec."""
    return max(1, min(dim, int(round(cfg.topk_fraction * dim))))


def is_stateful(cfg: CodecConfig) -> bool:
    """True when the codec carries cross-round state (the EF residual).

    topk carries it by default (sparsification drops whole coordinates,
    so EF is what makes the cumulative update converge); int4 carries it
    only when ``int4_error_feedback`` is set (the 15-level grid's rounding
    error is small but systematic — EF turns it into unbiased dither).
    """
    if cfg.name == "topk":
        return cfg.error_feedback
    return cfg.name == "int4" and cfg.int4_error_feedback


def direction_configs(cfg: CodecConfig) -> Tuple[CodecConfig, CodecConfig]:
    """Resolve ``cfg`` into per-direction configs ``(downlink, uplink)``.

    Dense codecs (fp32/fp16/int8/int4) compress both directions. ``topk``
    is a *gradient* codec: per-round updates concentrate mass in few
    coordinates, so magnitude sparsification + error feedback is sound on
    the uplink, while model rows are dense and ship fp32 on the downlink.
    Every byte-accounting call site uses this split so the two directions
    can never be costed inconsistently.
    """
    validate_config(cfg)
    if cfg.name == "topk":
        return CodecConfig(name="fp32"), cfg
    return cfg, cfg


def codec_state_init(cfg: CodecConfig, num_rows: int, dim: int,
                     force_residual: bool = False) -> CodecState:
    """Fresh codec state: EF residual table for topk, empty pytree else.

    ``force_residual=True`` allocates the residual for *every* codec —
    the corruption-degradation mode (repro.faults) rejects checksum-failed
    wire rows and needs somewhere to retain them for retransmit, even for
    codecs that are stateless in the clean world.
    """
    validate_config(cfg)
    if is_stateful(cfg) or force_residual:
        return jnp.zeros((num_rows, dim), jnp.float32)
    return ()


# ===================================================================== #
# quantization math (canonical: kernels/ref.py and the Pallas kernels
# must reproduce these exact op sequences bit-for-bit)
# ===================================================================== #
def quantize_rows(rows: jax.Array, nbits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """Uniform symmetric per-row quantization.

    Returns ``(codes int8 (rows, dim), scales float32 (rows, 1))`` with
    ``codes = round(rows / scale)``, ``scale = rowmax(|rows|) / qmax``.
    All-zero rows get scale 0 and codes 0 (decode restores exact zeros).
    """
    qmax = _QMAX[nbits]
    rows = rows.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(rows), axis=-1, keepdims=True)    # (rows, 1)
    # multiply by the reciprocal rather than divide: XLA const-folds
    # x / const into x * (1/const) under jit but not in eager refs, and the
    # kernel bit-exactness contract needs one canonical op sequence
    scales = absmax * (1.0 / qmax)
    inv = jnp.where(scales > 0, 1.0 / scales, 0.0)
    codes = jnp.clip(jnp.round(rows * inv), -qmax, qmax).astype(jnp.int8)
    return codes, scales


def quantize_rows_stochastic(
    rows: jax.Array, noise: jax.Array, nbits: int = 8
) -> Tuple[jax.Array, jax.Array]:
    """:func:`quantize_rows` with stochastic instead of nearest rounding.

    ``noise`` is U[0, 1) per value (same shape as ``rows``); the code is
    ``floor(rows / scale + u)``, so ``E[code * scale] = rows`` exactly —
    the unbiasedness that lets low-bit optimizer moments accumulate
    sub-quantum updates instead of rounding them away every step
    (:mod:`repro.optim.state_compress`). Scales are IDENTICAL to the
    deterministic path (absmax is rounding-free), so the wire/resident
    layout and the all-zero-row behaviour are unchanged. The absmax
    element itself always maps onto the end of the grid (``floor(±qmax+u)``
    is ``±qmax`` for any u in [0, 1)), so a stochastic encode still
    saturates the code range and re-encoding a decoded block keeps its
    scale bit-for-bit.
    """
    qmax = _QMAX[nbits]
    rows = rows.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(rows), axis=-1, keepdims=True)    # (rows, 1)
    scales = absmax * (1.0 / qmax)
    inv = jnp.where(scales > 0, 1.0 / scales, 0.0)
    codes = jnp.clip(jnp.floor(rows * inv + noise.astype(jnp.float32)),
                     -qmax, qmax).astype(jnp.int8)
    return codes, scales


def dequantize_rows(codes: jax.Array, scales: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_rows`: ``codes * scale`` as float32."""
    return codes.astype(jnp.float32) * scales.astype(jnp.float32)


def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack int8 codes in [-7, 7] into uint8 nibble pairs (dim/2 bytes).

    Column 2i lands in the low nibble, 2i+1 in the high nibble; odd dims
    are zero-padded (the pad nibble decodes to 0 and is sliced off).
    """
    rows, dim = codes.shape
    if dim % 2:
        codes = jnp.pad(codes, ((0, 0), (0, 1)))
    u = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint8)      # two's compl.
    lo, hi = u[:, 0::2], u[:, 1::2]
    return lo | (hi << 4)


def unpack_int4(packed: jax.Array, dim: int) -> jax.Array:
    """Inverse of :func:`pack_int4` -> int8 codes (rows, dim) in [-7, 7]."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    # sign-extend the 4-bit two's complement nibble
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    codes = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    return codes[:, :dim]


# ===================================================================== #
# encode / decode
# ===================================================================== #
def encode(cfg: CodecConfig, rows: jax.Array) -> Wire:
    """Dense (rows, dim) float payload -> wire pytree (static shapes)."""
    validate_config(cfg)
    rows = rows.astype(jnp.float32)
    if cfg.name == "fp32":
        return DenseWire(values=rows)
    if cfg.name == "fp16":
        return DenseWire(values=rows.astype(jnp.float16))
    if cfg.name == "int8":
        return QuantWire(*quantize_rows(rows, nbits=8))
    if cfg.name == "int4":
        codes, scales = quantize_rows(rows, nbits=4)
        return QuantWire(values=pack_int4(codes), scales=scales)
    # topk: largest-|value| entries per row, index-sorted for locality
    k = topk_k(cfg, rows.shape[-1])
    _, idx = jax.lax.top_k(jnp.abs(rows), k)                   # (rows, k)
    idx = jnp.sort(idx, axis=-1).astype(jnp.int32)
    vals = jnp.take_along_axis(rows, idx, axis=-1)
    return TopKWire(values=vals, indices=idx)


def decode(cfg: CodecConfig, wire: Wire, dim: int) -> jax.Array:
    """Wire pytree -> dense float32 (rows, dim) as the receiver sees it."""
    validate_config(cfg)
    if cfg.name == "fp32":
        return wire.values
    if cfg.name == "fp16":
        return wire.values.astype(jnp.float32)
    if cfg.name == "int8":
        return dequantize_rows(wire.values, wire.scales)
    if cfg.name == "int4":
        return dequantize_rows(unpack_int4(wire.values, dim), wire.scales)
    num_rows = wire.values.shape[0]
    dense = jnp.zeros((num_rows, dim), jnp.float32)
    return dense.at[jnp.arange(num_rows)[:, None], wire.indices].set(
        wire.values)


def roundtrip(cfg: CodecConfig, rows: jax.Array) -> jax.Array:
    """decode(encode(rows)) — the receiver's view of a stateless transmit."""
    return decode(cfg, encode(cfg, rows), rows.shape[-1])


# ===================================================================== #
# block access — the decode-free scoring contract
# ===================================================================== #
# Every wire format keeps the row axis leading on every leaf (codes,
# scales, topk values/indices all carry one entry per row), so a consumer
# can slice a row block straight out of the wire pytree and decode ONLY
# that block — the serving engine's fused dequant->score->top-N path and
# the chunked evaluator never materialize the dense fp32 table. Encoding
# is strictly per-row (per-row scales, per-row topk), which makes block
# decode exact: decode_row_block(w, s, n) == decode(w)[s:s+n] bit-for-bit.
def slice_rows(wire: Wire, start, size: int) -> Wire:
    """Rows ``[start, start+size)`` of a wire pytree (``start`` may be
    traced; out-of-range slices clamp like ``lax.dynamic_slice``)."""
    return jax.tree.map(
        lambda leaf: jax.lax.dynamic_slice_in_dim(leaf, start, size, axis=0),
        wire)


def decode_row_block(
    cfg: CodecConfig, wire: Wire, dim: int, start, size: int
) -> jax.Array:
    """Dense float32 (size, dim) view of one row block of the wire image.

    The per-row encoding guarantee makes this bit-identical to slicing the
    full decode — property-tested in ``tests/test_serving.py``.
    """
    return decode(cfg, slice_rows(wire, start, size), dim)


def wire_resident_bytes(wire: Wire) -> int:
    """Actual bytes a wire pytree keeps resident (sum of leaf nbytes).

    For a full-table wire image this is the serving model's memory
    footprint; equals :func:`wire_bytes` for freshly encoded blocks
    (property-tested) but works on any concrete wire, e.g. a snapshot ring
    slot or a padded serving table.
    """
    return int(sum(leaf.nbytes for leaf in jax.tree.leaves(wire)))


def encode_with_residual(
    cfg: CodecConfig, rows: jax.Array, residual_rows: jax.Array
) -> Tuple[Wire, jax.Array, jax.Array]:
    """Error-feedback encode: compress ``rows + residual``, keep the error.

    Returns ``(wire, decoded_rows, new_residual_rows)`` with
    ``new_residual = (rows + residual) - decoded`` — the classic EF-SGD
    memory (Karimireddy et al.) specialized to per-row payloads: whatever
    this round's wire image dropped is re-injected the next time the same
    row is selected for transmission.
    """
    eff = rows.astype(jnp.float32) + residual_rows
    wire = encode(cfg, eff)
    decoded = decode(cfg, wire, rows.shape[-1])
    return wire, decoded, eff - decoded


# ===================================================================== #
# byte accounting — the single source of truth for the whole repo
# ===================================================================== #
def dense_bytes(num_rows: int, dim: int, bits: int = 32) -> int:
    """Dense payload bytes: (#values x bits) / 8 (paper Table 1 formula)."""
    return (num_rows * dim * bits) // 8


def wire_bytes(cfg: CodecConfig, num_rows: int, dim: int) -> int:
    """Exact bytes on the wire for one (num_rows, dim) payload block.

    Matches ``sum(leaf.nbytes for leaf in encode(cfg, rows))`` exactly —
    scales are float32, topk indices int32, int4 packs two codes per byte.
    """
    validate_config(cfg)
    if cfg.name == "fp32":
        return dense_bytes(num_rows, dim, 32)
    if cfg.name == "fp16":
        return dense_bytes(num_rows, dim, 16)
    if cfg.name == "int8":
        return num_rows * dim + num_rows * 4
    if cfg.name == "int4":
        return num_rows * ((dim + 1) // 2) + num_rows * 4
    k = topk_k(cfg, dim)
    return num_rows * k * (4 + 4)          # float32 value + int32 index


def compression_ratio(cfg: CodecConfig, num_rows: int, dim: int) -> float:
    """Dense-fp32 bytes over wire bytes (>1 means smaller on the wire)."""
    return dense_bytes(num_rows, dim, 32) / wire_bytes(cfg, num_rows, dim)
