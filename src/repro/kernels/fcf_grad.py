"""Fused FCF item-gradient Pallas kernel.

The CF compute hot spot (Eqs. 5-6): for a cohort of B users and a payload of
M items, the server-side naive formulation materializes the (B, M) residual
and confidence matrices in HBM (for production M up to 10^7 that is GBs per
cohort). This kernel blocks over items, fusing residual computation,
confidence weighting and the gradient matmul inside VMEM, so HBM traffic is
O(B*K + M*K) instead of O(B*M).

TPU mapping:
  * grid = (ceil(M / block_m),) — one program per item block,
  * per block: x_blk (B, bm) and q_blk (bm, K) stream through VMEM, p (B, K)
    is resident (small: cohort x factors),
  * the two MXU contractions per block are (B,K)x(K,bm) and (bm,B)x(B,K);
    choose block_m a multiple of 128 (lane dim) and pad K to 128 at the
    wrapper for MXU alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fcf_grad_kernel(p_ref, q_ref, x_ref, out_ref, *, alpha: float, l2: float,
                     batch: int):
    """One item block: out = -2 (c . e)^T P + 2 l2 B q."""
    p = p_ref[...].astype(jnp.float32)          # (B, K)
    q = q_ref[...].astype(jnp.float32)          # (bm, K)
    x = x_ref[...].astype(jnp.float32)          # (B, bm)

    pred = jax.lax.dot_general(                  # (B, bm) = P @ q_blk^T
        p, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    err = x - pred
    weighted = (1.0 + alpha * x) * err           # confidence-weighted residual
    grad = jax.lax.dot_general(                  # (bm, K) = weighted^T @ P
        weighted, p, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    out_ref[...] = (-2.0 * grad + (2.0 * l2 * batch) * q).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("alpha", "l2", "block_m", "interpret"))
def fcf_grad(
    q: jax.Array,            # (M, K)
    p: jax.Array,            # (B, K)
    x: jax.Array,            # (B, M)
    *,
    alpha: float = 4.0,
    l2: float = 1.0,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Blocked fused item gradient. Pads M to a block multiple internally."""
    m, k = q.shape
    b = p.shape[0]
    m_pad = (m + block_m - 1) // block_m * block_m
    if m_pad != m:
        q = jnp.pad(q, ((0, m_pad - m), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, m_pad - m)))

    grid = (m_pad // block_m,)
    out = pl.pallas_call(
        functools.partial(_fcf_grad_kernel, alpha=alpha, l2=l2, batch=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),          # p resident
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),    # q block
            pl.BlockSpec((b, block_m), lambda i: (0, i)),    # x block
        ],
        out_specs=pl.BlockSpec((block_m, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, k), q.dtype),
        interpret=interpret,
    )(p, q, x)
    return out[:m]
