"""Payload row gather / scatter-add Pallas kernels.

The payload subset operations are the per-round hot path of the FL server:
  * download: Q* = Q[idx]            (gather M_s of M rows)
  * upload:   Q[idx] += grad_rows    (scatter-add aggregated gradients)

For LLM-scale tables (256k x 5120) these run every round; blocking them
keeps only (block_rows, K) tiles in VMEM and uses scalar prefetch so the
row indices are available to the index_map before the DMA is issued —
the TPU-native equivalent of the paper's "subset the Q factor matrix".

Note on scatter semantics: indices are assumed UNIQUE (payload selections
are top-k / choice-without-replacement, so this holds by construction).
TPU grids execute sequentially so revisiting would still be correct, but
uniqueness is asserted in the ops.py wrapper for defense in depth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, table_ref, out_ref):
    # table_ref block is (1, K) at row idx[i] — selected by the index_map.
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(
    table: jax.Array,      # (M, K)
    idx: jax.Array,        # (M_s,) int32 unique row ids
    *,
    interpret: bool = False,
) -> jax.Array:
    """out[i] = table[idx[i]] via scalar-prefetch indexed DMA."""
    m_s = idx.shape[0]
    k = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_s,),
        in_specs=[pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, k), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_s, k), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)


def _scatter_set_kernel(idx_ref, rows_ref, table_in_ref, out_ref):
    # aliased in/out: replace the table row with the payload row.
    del table_in_ref
    out_ref[...] = rows_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def scatter_set_rows(
    table: jax.Array,      # (M, K) — donated and updated in place
    idx: jax.Array,        # (M_s,) unique row ids
    rows: jax.Array,       # (M_s, K)
    *,
    interpret: bool = False,
) -> jax.Array:
    """table[idx[i]] = rows[i]; the table is aliased (no O(M*K) copy).

    The row-replace flavour of :func:`scatter_add_rows` — this is the commit
    path of the payload-selected sparse Adam update, where the server writes
    fully-formed new rows (params and moments) back into the global table.
    """
    m_s = idx.shape[0]
    k = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_s,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, idx_ref: (i, 0)),           # rows
            pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),  # table
        ],
        out_specs=pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_set_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        # alias the table operand (positional arg 2: idx, rows, table)
        input_output_aliases={2: 0},
        interpret=interpret,
    )(idx.astype(jnp.int32), rows, table)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows_block(
    table: jax.Array,      # (m, K) — one shard's row block of a larger table
    local_idx: jax.Array,  # (M_s,) shard-local row ids; may be out of range
    *,
    interpret: bool = False,
) -> jax.Array:
    """Shard-local payload gather: ``out[i] = table[clip(local_idx[i])]``.

    The per-device half of a row-sharded table gather: the caller translates
    global payload indices to ``idx - shard_offset`` and every shard gathers
    a full (M_s, K) candidate block — rows it does not own come from the
    clamp and are discarded by the owner-select after the all-gather
    (:func:`repro.kernels.ops.assemble_rows`). Clamping instead of masking
    keeps the kernel identical to :func:`gather_rows` (one indexed row DMA
    per grid step) with no divergent control flow.
    """
    m = table.shape[0]
    safe = jnp.clip(local_idx.astype(jnp.int32), 0, m - 1)
    return gather_rows(table, safe, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def scatter_set_rows_block(
    table: jax.Array,      # (m, K) — one shard's row block, donated
    local_idx: jax.Array,  # (M_s,) shard-local row ids; out-of-range dropped
    rows: jax.Array,       # (M_s, K)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Shard-local row commit: ``table[local_idx[i]] = rows[i]`` where
    ``0 <= local_idx[i] < m``; out-of-range entries (rows owned by another
    shard) are dropped.

    Built over the :func:`scatter_set_rows` kernel by stably compacting the
    in-range entries to the front and pointing every masked grid step at the
    last in-range entry *with its own row value* — duplicate writes of
    identical data are idempotent under the sequential TPU grid, so no grid
    step ever touches a row this shard does not own and no step can clobber
    an earlier write with stale data. An all-out-of-range call (possible
    when M_s < num_shards) returns the shard unchanged.
    """
    m_s = local_idx.shape[0]
    m = table.shape[0]
    local_idx = local_idx.astype(jnp.int32)
    valid = (local_idx >= 0) & (local_idx < m)
    n_valid = jnp.sum(valid.astype(jnp.int32))
    # stable partition: in-range entries first, original order preserved
    perm = jnp.argsort(jnp.where(valid, 0, 1).astype(jnp.int32))
    safe = perm[jnp.minimum(jnp.arange(m_s), n_valid - 1)]
    idx_safe = jnp.clip(local_idx[safe], 0, m - 1)
    rows_safe = rows[safe]

    def commit(tab):
        return scatter_set_rows(tab, idx_safe, rows_safe, interpret=interpret)

    return jax.lax.cond(n_valid > 0, commit, lambda tab: tab, table)


def _scatter_add_kernel(idx_ref, rows_ref, table_in_ref, out_ref):
    # aliased in/out: accumulate the payload gradient row into the table row.
    out_ref[...] = table_in_ref[...] + rows_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def scatter_add_rows(
    table: jax.Array,      # (M, K) — donated and updated in place
    idx: jax.Array,        # (M_s,) unique row ids
    rows: jax.Array,       # (M_s, K)
    *,
    interpret: bool = False,
) -> jax.Array:
    """table[idx[i]] += rows[i]; the table is aliased (no O(M*K) copy)."""
    m_s = idx.shape[0]
    k = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_s,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, idx_ref: (i, 0)),           # rows
            pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),  # table
        ],
        out_specs=pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_add_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        # alias the table operand (positional arg 2: idx, rows, table)
        input_output_aliases={2: 0},
        interpret=interpret,
    )(idx.astype(jnp.int32), rows, table)
