"""Payload row gather / scatter-add Pallas kernels.

The payload subset operations are the per-round hot path of the FL server:
  * download: Q* = Q[idx]            (gather M_s of M rows)
  * upload:   Q[idx] += grad_rows    (scatter-add aggregated gradients)

For LLM-scale tables (256k x 5120) these run every round; blocking them
keeps only (block_rows, K) tiles in VMEM and uses scalar prefetch so the
row indices are available to the index_map before the DMA is issued —
the TPU-native equivalent of the paper's "subset the Q factor matrix".

Note on scatter semantics: indices are assumed UNIQUE (payload selections
are top-k / choice-without-replacement, so this holds by construction).
TPU grids execute sequentially so revisiting would still be correct, but
uniqueness is asserted in the ops.py wrapper for defense in depth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, table_ref, out_ref):
    # table_ref block is (1, K) at row idx[i] — selected by the index_map.
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(
    table: jax.Array,      # (M, K)
    idx: jax.Array,        # (M_s,) int32 unique row ids
    *,
    interpret: bool = False,
) -> jax.Array:
    """out[i] = table[idx[i]] via scalar-prefetch indexed DMA."""
    m_s = idx.shape[0]
    k = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_s,),
        in_specs=[pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, k), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_s, k), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)


def _scatter_set_kernel(idx_ref, rows_ref, table_in_ref, out_ref):
    # aliased in/out: replace the table row with the payload row.
    del table_in_ref
    out_ref[...] = rows_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def scatter_set_rows(
    table: jax.Array,      # (M, K) — donated and updated in place
    idx: jax.Array,        # (M_s,) unique row ids
    rows: jax.Array,       # (M_s, K)
    *,
    interpret: bool = False,
) -> jax.Array:
    """table[idx[i]] = rows[i]; the table is aliased (no O(M*K) copy).

    The row-replace flavour of :func:`scatter_add_rows` — this is the commit
    path of the payload-selected sparse Adam update, where the server writes
    fully-formed new rows (params and moments) back into the global table.
    """
    m_s = idx.shape[0]
    k = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_s,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, idx_ref: (i, 0)),           # rows
            pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),  # table
        ],
        out_specs=pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_set_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        # alias the table operand (positional arg 2: idx, rows, table)
        input_output_aliases={2: 0},
        interpret=interpret,
    )(idx.astype(jnp.int32), rows, table)


def _scatter_add_kernel(idx_ref, rows_ref, table_in_ref, out_ref):
    # aliased in/out: accumulate the payload gradient row into the table row.
    out_ref[...] = table_in_ref[...] + rows_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def scatter_add_rows(
    table: jax.Array,      # (M, K) — donated and updated in place
    idx: jax.Array,        # (M_s,) unique row ids
    rows: jax.Array,       # (M_s, K)
    *,
    interpret: bool = False,
) -> jax.Array:
    """table[idx[i]] += rows[i]; the table is aliased (no O(M*K) copy)."""
    m_s = idx.shape[0]
    k = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_s,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, idx_ref: (i, 0)),           # rows
            pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),  # table
        ],
        out_specs=pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_add_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        # alias the table operand (positional arg 2: idx, rows, table)
        input_output_aliases={2: 0},
        interpret=interpret,
    )(idx.astype(jnp.int32), rows, table)
