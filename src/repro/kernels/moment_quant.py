"""Fused compressed-moment Pallas kernels (repro.optim.state_compress).

With int8 Adam moments the sparse commit's per-row hot path becomes

  * read:  m_f32[i] = codes[idx[i]] * scales[idx[i]]   — gather the stored
    int8 row AND dequantize it, fused so each selected moment row makes a
    single HBM->VMEM trip and lands in VMEM already as the fp32 tile the
    Adam math consumes (:func:`gather_dequant_rows`);
  * write: (codes[idx[i]], scales[idx[i]]) = quantize(m_f32'[i]) — requant
    the updated fp32 tile and scatter it back into the resident int8
    table + scale vector in one kernel, both aliased in place
    (:func:`quant_scatter_set_rows`). The stochastic variant adds a U[0,1)
    dither operand and rounds with ``floor(x/scale + u)``.

The fp32 moments of the full (M, K) table are never materialized — the
whole point of compressed state. Same structure as
:mod:`repro.kernels.payload_quant`: one grid step per selected row,
scalar-prefetched indices steering the row DMA, (1, K) blocks in VMEM.

BIT-EXACTNESS CONTRACT: the quantization math must reproduce
:func:`repro.compress.codecs.quantize_rows` /
``quantize_rows_stochastic`` / ``dequantize_rows`` bit-for-bit (same op
sequence), so a kernel-routed compressed update and the pure-codec
composed path (the sharded engine's per-leaf collective gathers) produce
identical trajectories. ``kernels/ref.py`` delegates to the codec
functions and the kernel tests assert exact equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compress.codecs import _QMAX as _CODEC_QMAX

_QMAX = float(_CODEC_QMAX[8])      # symmetric int8 grid, shared w/ codec

# explicit oracle registry (analysis rule `kernel-parity`): every public
# kernel here maps onto its pure-jnp twin in kernels/ref.py
PARITY_ORACLES = {
    "gather_dequant_rows": "gather_dequant_rows_ref",
    "gather_dequant_rows_block": "gather_dequant_rows_block_ref",
    "quant_scatter_set_rows": "quant_scatter_set_rows_ref",
    "quant_scatter_set_rows_block": "quant_scatter_set_rows_block_ref",
}


def _gather_dequant_kernel(idx_ref, codes_ref, scales_ref, out_ref):
    # codes/scales blocks are the (1, K) / (1, 1) rows at idx[i]
    del idx_ref
    out_ref[...] = codes_ref[...].astype(jnp.float32) * scales_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_dequant_rows(
    codes: jax.Array,      # (M, K) int8 moment codes
    scales: jax.Array,     # (M, 1) float32 per-row scales
    idx: jax.Array,        # (M_s,) int32 unique row ids
    *,
    interpret: bool = False,
) -> jax.Array:
    """Fused moment read: ``out[i] = codes[idx[i]] * scales[idx[i]]``.

    Returns the float32 (M_s, K) tile of the selected rows' dequantized
    moments, one pass over the stored int8 rows.
    """
    m_s = idx.shape[0]
    k = codes.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_s,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),
            pl.BlockSpec((1, 1), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_dequant_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_s, k), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), codes, scales)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_dequant_rows_block(
    codes: jax.Array,      # (m, K) — one shard's row block of the codes
    scales: jax.Array,     # (m, 1) — matching scale block
    local_idx: jax.Array,  # (M_s,) shard-local row ids; may be out of range
    *,
    interpret: bool = False,
) -> jax.Array:
    """Shard-local fused moment read over a row-sharded int8 table.

    Identical to :func:`gather_dequant_rows` on ``clip(local_idx)`` —
    out-of-range rows are clamp artifacts discarded by the owner-select
    after the all-gather, exactly like every other block gather.
    """
    m = codes.shape[0]
    safe = jnp.clip(local_idx.astype(jnp.int32), 0, m - 1)
    return gather_dequant_rows(codes, scales, safe, interpret=interpret)


def _quant_scatter_kernel(idx_ref, rows_ref, codes_in, scales_in,
                          codes_out, scales_out):
    # aliased in/out: overwrite the stored row with the requantized tile.
    del idx_ref, codes_in, scales_in
    row = rows_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(row), axis=-1, keepdims=True)      # (1, 1)
    scale = absmax * (1.0 / _QMAX)   # matches codecs.quantize_rows exactly
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    codes_out[...] = jnp.clip(
        jnp.round(row * inv), -_QMAX, _QMAX).astype(jnp.int8)
    scales_out[...] = scale


def _quant_scatter_sr_kernel(idx_ref, rows_ref, noise_ref, codes_in,
                             scales_in, codes_out, scales_out):
    # stochastic variant: floor(x/scale + u) — codecs.quantize_rows_stochastic
    del idx_ref, codes_in, scales_in
    row = rows_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(row), axis=-1, keepdims=True)      # (1, 1)
    scale = absmax * (1.0 / _QMAX)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    codes_out[...] = jnp.clip(
        jnp.floor(row * inv + noise_ref[...].astype(jnp.float32)),
        -_QMAX, _QMAX).astype(jnp.int8)
    scales_out[...] = scale


@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=(0, 1))
def quant_scatter_set_rows(
    codes: jax.Array,      # (M, K) int8 — donated, updated in place
    scales: jax.Array,     # (M, 1) float32 — donated, updated in place
    idx: jax.Array,        # (M_s,) unique row ids
    rows: jax.Array,       # (M_s, K) float32 updated moment tile
    noise=None,            # optional (M_s, K) U[0,1) stochastic dither
    *,
    interpret: bool = False,
):
    """Fused moment write: ``(codes[idx[i]], scales[idx[i]]) =
    quantize(rows[i])``, stochastic when ``noise`` is given.

    Requantize-and-patch of the updated fp32 tile into the resident int8
    moment table, aliased so no O(M*K) copy is ever made.
    """
    m_s = idx.shape[0]
    k = codes.shape[1]
    row_spec = pl.BlockSpec((1, k), lambda i, idx_ref: (i, 0))
    codes_spec = pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0))
    scales_spec = pl.BlockSpec((1, 1), lambda i, idx_ref: (idx_ref[i], 0))
    out_shape = (
        jax.ShapeDtypeStruct(codes.shape, jnp.int8),
        jax.ShapeDtypeStruct(scales.shape, jnp.float32),
    )
    out_specs = [codes_spec, scales_spec]
    if noise is None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=(m_s,),
            in_specs=[row_spec, codes_spec, scales_spec],
            out_specs=out_specs,
        )
        return pl.pallas_call(
            _quant_scatter_kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            # alias codes/scales operands (args: idx, rows, codes, scales)
            input_output_aliases={2: 0, 3: 1},
            interpret=interpret,
        )(idx.astype(jnp.int32), rows, codes, scales)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(m_s,),
        in_specs=[row_spec, row_spec, codes_spec, scales_spec],
        out_specs=out_specs,
    )
    return pl.pallas_call(
        _quant_scatter_sr_kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        # alias codes/scales operands (args: idx, rows, noise, codes, scales)
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(idx.astype(jnp.int32), rows, noise, codes, scales)


@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=(0, 1))
def quant_scatter_set_rows_block(
    codes: jax.Array,      # (m, K) int8 — one shard's row block, donated
    scales: jax.Array,     # (m, 1) float32 — matching scale block, donated
    local_idx: jax.Array,  # (M_s,) shard-local row ids; out-of-range dropped
    rows: jax.Array,       # (M_s, K) float32 updated moment tile
    noise=None,            # optional (M_s, K) U[0,1) stochastic dither
    *,
    interpret: bool = False,
):
    """Shard-local fused moment write: in-range rows requantized+written,
    out-of-range entries (rows owned by another shard) dropped.

    Same stable in-range compaction as
    :func:`repro.kernels.payload_gather.scatter_set_rows_block` — masked
    grid steps repeat the last in-range entry with its own values, so
    duplicate writes are idempotent and no step touches a foreign row.
    """
    m_s = local_idx.shape[0]
    m = codes.shape[0]
    local_idx = local_idx.astype(jnp.int32)
    valid = (local_idx >= 0) & (local_idx < m)
    n_valid = jnp.sum(valid.astype(jnp.int32))
    perm = jnp.argsort(jnp.where(valid, 0, 1).astype(jnp.int32))
    safe = perm[jnp.minimum(jnp.arange(m_s), n_valid - 1)]
    idx_safe = jnp.clip(local_idx[safe], 0, m - 1)
    rows_safe = rows[safe]
    noise_safe = None if noise is None else noise[safe]

    def commit(ops_in):
        c, s = ops_in
        return quant_scatter_set_rows(c, s, idx_safe, rows_safe, noise_safe,
                                      interpret=interpret)

    return jax.lax.cond(n_valid > 0, commit, lambda ops_in: ops_in,
                        (codes, scales))
