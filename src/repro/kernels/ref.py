"""Pure-jnp oracles for every Pallas kernel. The kernels' tests sweep shapes
and dtypes and assert_allclose against these."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def fcf_grad_ref(
    q: jax.Array,          # (M, K) item factors (payload rows)
    p: jax.Array,          # (B, K) cohort user factors
    x: jax.Array,          # (B, M) binary interactions
    l2: float = 1.0,
    alpha: float = 4.0,
) -> jax.Array:
    """Aggregated FCF item gradient (Eqs. 5-6 summed over the cohort)."""
    err = x - p @ q.T                      # (B, M)
    cw = 1.0 + alpha * x
    grad = -2.0 * ((cw * err).T @ p)       # (M, K)
    return grad + 2.0 * l2 * x.shape[0] * q


def gather_rows_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    """out[i] = table[idx[i]] — payload subset download."""
    return table[idx]


def scatter_add_rows_ref(
    table: jax.Array, idx: jax.Array, rows: jax.Array
) -> jax.Array:
    """table[idx[i]] += rows[i] — payload gradient write-back (unique idx)."""
    return table.at[idx].add(rows)


def scatter_set_rows_ref(
    table: jax.Array, idx: jax.Array, rows: jax.Array
) -> jax.Array:
    """table[idx[i]] = rows[i] — payload row commit (unique idx)."""
    return table.at[idx].set(rows.astype(table.dtype))


def gather_quantize_rows_ref(table: jax.Array, idx: jax.Array):
    """(codes, scales) = int8-quantize(table[idx]) — fused downlink encode.

    Delegates to the canonical codec math (:mod:`repro.compress.codecs`)
    so the Pallas kernel's bit-exactness contract is against the exact
    arithmetic the pure codec path uses.
    """
    from repro.compress.codecs import quantize_rows

    return quantize_rows(table[idx], nbits=8)


def dequant_scatter_set_rows_ref(
    table: jax.Array, idx: jax.Array, values: jax.Array, scales: jax.Array
) -> jax.Array:
    """table[idx[i]] = dequantize(values[i], scales[i]) — wire commit."""
    from repro.compress.codecs import dequantize_rows

    return table.at[idx].set(
        dequantize_rows(values, scales).astype(table.dtype))


def gather_rows_block_ref(table: jax.Array, local_idx: jax.Array) -> jax.Array:
    """Shard-local gather: ``out[i] = table[clip(local_idx[i], 0, m-1)]``.

    Out-of-range entries (rows owned by another shard) come from the clamp
    and are discarded by the owner-select after the all-gather.
    """
    return table[jnp.clip(local_idx, 0, table.shape[0] - 1)]


def scatter_set_rows_block_ref(
    table: jax.Array, local_idx: jax.Array, rows: jax.Array
) -> jax.Array:
    """Shard-local row commit: in-range rows written, out-of-range dropped."""
    m = table.shape[0]
    safe = jnp.where((local_idx >= 0) & (local_idx < m), local_idx, m)
    return table.at[safe].set(rows.astype(table.dtype), mode="drop")


def gather_quantize_rows_block_ref(table: jax.Array, local_idx: jax.Array):
    """Shard-local fused downlink encode (clamped gather + per-row int8)."""
    return gather_quantize_rows_ref(
        table, jnp.clip(local_idx, 0, table.shape[0] - 1))


def gather_dequant_rows_ref(
    codes: jax.Array, scales: jax.Array, idx: jax.Array
) -> jax.Array:
    """Fused moment read: ``out[i] = dequantize(codes[idx[i]], scales[idx[i]])``.

    Delegates to the canonical codec math so the Pallas kernel's
    bit-exactness contract is against the arithmetic the pure
    compressed-state path (sharded engine) uses.
    """
    from repro.compress.codecs import dequantize_rows

    return dequantize_rows(codes[idx], scales[idx])


def quant_scatter_set_rows_ref(
    codes: jax.Array, scales: jax.Array, idx: jax.Array, rows: jax.Array,
    noise: Optional[jax.Array] = None,
):
    """Fused moment write: ``(codes[idx[i]], scales[idx[i]]) =
    quantize(rows[i])`` — stochastic (floor + U[0,1) dither) when ``noise``
    is given, nearest otherwise. Unique ``idx``."""
    from repro.compress.codecs import quantize_rows, quantize_rows_stochastic

    if noise is None:
        new_codes, new_scales = quantize_rows(rows, nbits=8)
    else:
        new_codes, new_scales = quantize_rows_stochastic(rows, noise, nbits=8)
    return (codes.at[idx].set(new_codes),
            scales.at[idx].set(new_scales.astype(scales.dtype)))


def gather_dequant_rows_block_ref(
    codes: jax.Array, scales: jax.Array, local_idx: jax.Array
) -> jax.Array:
    """Shard-local fused moment read (clamped gather + dequantize)."""
    return gather_dequant_rows_ref(
        codes, scales, jnp.clip(local_idx, 0, codes.shape[0] - 1))


def quant_scatter_set_rows_block_ref(
    codes: jax.Array, scales: jax.Array, local_idx: jax.Array,
    rows: jax.Array, noise: Optional[jax.Array] = None,
):
    """Shard-local fused moment write: in-range rows requantized+written,
    out-of-range (foreign-shard) entries dropped."""
    from repro.compress.codecs import quantize_rows, quantize_rows_stochastic

    if noise is None:
        new_codes, new_scales = quantize_rows(rows, nbits=8)
    else:
        new_codes, new_scales = quantize_rows_stochastic(rows, noise, nbits=8)
    m = codes.shape[0]
    safe = jnp.where((local_idx >= 0) & (local_idx < m), local_idx, m)
    return (codes.at[safe].set(new_codes, mode="drop"),
            scales.at[safe].set(new_scales.astype(scales.dtype), mode="drop"))


NEG_INF = -1e30     # train-mask sentinel, shared with repro.cf.metrics


def topn_merge_ref(
    vals: jax.Array,       # (B, N) running top-N scores, descending
    idxs: jax.Array,       # (B, N) their global item ids
    cand_vals: jax.Array,  # (B, C) candidate block scores
    cand_idx: jax.Array,   # (B, C) candidate global item ids
):
    """Merge a candidate block into a running top-N list.

    The running list is concatenated IN FRONT of the candidates, so
    ``lax.top_k``'s stable tie rule (lower position first) resolves equal
    scores toward the earlier — i.e. lower item id — entry. By induction
    over blocks this makes the chunked top-N bit-identical, values and
    indices and order, to one ``lax.top_k`` over the full score row.
    """
    top_n = vals.shape[1]
    allv = jnp.concatenate([vals, cand_vals], axis=1)
    alli = jnp.concatenate([idxs, cand_idx], axis=1)
    v, pos = jax.lax.top_k(allv, top_n)
    return v, jnp.take_along_axis(alli, pos, axis=1)


def wire_topn_ref(
    cfg,                   # repro.compress.CodecConfig (any codec)
    wire,                  # full-table wire pytree (row-leading leaves)
    p: jax.Array,          # (B, K) user factors
    dim: int,              # K — the decoded row width
    top_n: int,
    train_mask: Optional[jax.Array] = None,   # (B, M) binary; 1 = exclude
    block_m: int = 4096,
) -> Tuple[jax.Array, jax.Array]:
    """Fused dequant->score->top-N oracle: ``(scores (B, N), ids (B, N))``.

    Scores users directly against the COMPRESSED table: each row block is
    decoded on the fly (``compress.decode_row_block`` — per-row encoding
    makes block decode exact), scored as ``p @ q_blk.T``, train-masked with
    the metrics module's ``NEG_INF`` sentinel, and merged into a running
    top-N. Neither the dense fp32 table nor the (B, M) score matrix is ever
    materialized — peak extra memory is one (block_m, K) decode plus one
    (B, block_m) score block.

    Blocking over items never changes a score (each ``p_i . q_j`` reduces
    over K only) and the merge preserves ``lax.top_k``'s tie order, so the
    result matches the naive dense path
    ``lax.top_k(where(mask, NEG_INF, p @ decode(wire).T), N)``.

    The table is zero-padded to a whole number of ``block_m`` blocks and the
    pad lanes forced to -inf AFTER train-masking — the same block structure,
    dot shapes and mask order as the Pallas kernel, which is what makes the
    kernel-vs-ref comparison bitwise (a gemm's rounding may legitimately
    vary with its output shape, so a remainder-sized dot would not do).
    """
    from repro.compress.codecs import decode_row_block

    num_rows = jax.tree.leaves(wire)[0].shape[0]
    b = p.shape[0]
    p = p.astype(jnp.float32)

    nb = -(-num_rows // block_m)
    pad = nb * block_m - num_rows
    if pad:
        wire = jax.tree.map(
            lambda leaf: jnp.pad(
                leaf, ((0, pad),) + ((0, 0),) * (leaf.ndim - 1)), wire)
        if train_mask is not None:
            train_mask = jnp.pad(train_mask, ((0, 0), (0, pad)))

    def score_block(start: jax.Array) -> Tuple[jax.Array, jax.Array]:
        q_blk = decode_row_block(cfg, wire, dim, start, block_m)  # (bm, K)
        s = p @ q_blk.T                                           # (B, bm)
        gidx = start + jnp.arange(block_m, dtype=jnp.int32)
        if train_mask is not None:
            m_blk = jax.lax.dynamic_slice_in_dim(
                train_mask, start, block_m, axis=1)
            s = jnp.where(m_blk > 0, NEG_INF, s)
        s = jnp.where(gidx[None, :] < num_rows, s, -jnp.inf)
        return s, jnp.broadcast_to(gidx[None, :], (b, block_m))

    vals0 = jnp.full((b, top_n), -jnp.inf, jnp.float32)
    idxs0 = jnp.zeros((b, top_n), jnp.int32)

    def body(carry, start):
        return topn_merge_ref(*carry, *score_block(start)), None

    starts = jnp.arange(nb, dtype=jnp.int32) * block_m
    (vals, idxs), _ = jax.lax.scan(body, (vals0, idxs0), starts)
    return vals, idxs


def mha_chunked_ref(
    q: jax.Array,                  # (B, H, S, D)
    k: jax.Array,                  # (B, KVH, T, D)
    v: jax.Array,                  # (B, KVH, T, D)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention chunked over KV — the pure-jnp analogue of
    the flash kernel's memory behaviour. Used as the CPU / dry-run stand-in
    for long sequences so the compiled HLO's memory footprint reflects the
    TPU kernel's O(S*chunk) working set instead of a naive S*T score matrix
    (the dry-run cost analysis depends on this)."""
    b, h, s, d = q.shape
    kvh, t = k.shape[1], k.shape[2]
    group = h // kvh
    t_pad = (t + chunk - 1) // chunk * chunk
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
    nk = t_pad // chunk
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qf = q.astype(jnp.float32)
    qpos = (jnp.arange(s) + q_offset)[:, None]                     # (S, 1)

    k_chunks = k.reshape(b, kvh, nk, chunk, d).transpose(2, 0, 1, 3, 4)
    v_chunks = v.reshape(b, kvh, nk, chunk, d).transpose(2, 0, 1, 3, 4)

    def step(carry, inputs):
        m_prev, l_prev, acc = carry
        j, kc, vc = inputs
        kc = jnp.repeat(kc, group, axis=1).astype(jnp.float32)     # (B,H,C,D)
        vc = jnp.repeat(vc, group, axis=1).astype(jnp.float32)
        logits = jnp.einsum("bhsd,bhcd->bhsc", qf, kc) * scale
        kpos = (j * chunk + jnp.arange(chunk))[None, :]            # (1, C)
        mask = kpos < t
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask[None, None], jnp.exp(logits - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhsc,bhcd->bhsd", p, vc)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, s, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, s, 1), jnp.float32)
    acc0 = jnp.zeros((b, h, s, d), jnp.float32)
    (m_f, l_f, acc_f), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(nk), k_chunks, v_chunks))
    return (acc_f / jnp.maximum(l_f, 1e-30)).astype(q.dtype)


def mha_ref(
    q: jax.Array,                  # (B, H, S, D)
    k: jax.Array,                  # (B, KVH, T, D)
    v: jax.Array,                  # (B, KVH, T, D)
    causal: bool = True,
    window: Optional[int] = None,  # sliding window size (None = full)
    q_offset: int = 0,             # absolute position of q[0] (decode)
) -> jax.Array:
    """Reference grouped-query attention with optional causal + sliding window.

    GQA: head h of q attends to kv head h // (H // KVH).
    Sliding window w: query at absolute position i sees keys in
    (i - w, i] intersected with the causal constraint.
    """
    b, h, s, d = q.shape
    kvh = k.shape[1]
    group = h // kvh
    kk = jnp.repeat(k, group, axis=1)      # (B, H, T, D)
    vv = jnp.repeat(v, group, axis=1)

    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale

    t = k.shape[2]
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)
