"""Public jit'd kernel entry points.

Backend dispatch:
  * TPU: compiled Pallas kernels.
  * CPU + REPRO_INTERPRET=1: Pallas interpret mode (kernel body in Python) —
    what the kernel tests exercise.
  * CPU default: the jnp oracles (bit-identical semantics, fast on CPU) so
    simulations and benchmarks are not throttled by interpret mode.
  * REPRO_FORCE_REF=1 forces oracles everywhere (A/B a suspected kernel bug).
"""
from __future__ import annotations

import os
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _flash
from repro.kernels import fcf_grad as _fcf
from repro.kernels import moment_quant as _mq
from repro.kernels import payload_gather as _pg
from repro.kernels import payload_quant as _pq
from repro.kernels import payload_score as _ps
from repro.kernels import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _use_ref() -> bool:
    if os.environ.get("REPRO_FORCE_REF", "0") == "1":
        return True
    on_cpu = jax.default_backend() != "tpu"
    return on_cpu and os.environ.get("REPRO_INTERPRET", "0") != "1"


def fcf_item_gradients(
    q: jax.Array, p: jax.Array, x: jax.Array,
    *, alpha: float = 4.0, l2: float = 1.0, block_m: int = 256,
) -> jax.Array:
    """Fused FCF item gradient (Eqs. 5-6) over an item-blocked grid."""
    if _use_ref():
        return _ref.fcf_grad_ref(q, p, x, l2=l2, alpha=alpha)
    return _fcf.fcf_grad(q, p, x, alpha=alpha, l2=l2, block_m=block_m,
                         interpret=_interpret())


def gather_rows(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Payload download: Q* = Q[idx]."""
    if _use_ref():
        return _ref.gather_rows_ref(table, idx)
    return _pg.gather_rows(table, idx, interpret=_interpret())


def scatter_add_rows(table: jax.Array, idx: jax.Array, rows: jax.Array) -> jax.Array:
    """Payload upload: Q[idx] += rows. ``idx`` must be unique."""
    if _use_ref():
        return _ref.scatter_add_rows_ref(table, idx, rows)
    return _pg.scatter_add_rows(table, idx, rows, interpret=_interpret())


def scatter_set_rows(table: jax.Array, idx: jax.Array, rows: jax.Array) -> jax.Array:
    """Payload row commit: Q[idx] = rows. ``idx`` must be unique."""
    if _use_ref():
        return _ref.scatter_set_rows_ref(table, idx, rows)
    return _pg.scatter_set_rows(table, idx, rows, interpret=_interpret())


def gather_quantize_rows(table: jax.Array, idx: jax.Array):
    """Fused downlink encode: (int8 codes, f32 scales) = quant(Q[idx])."""
    if _use_ref():
        return _ref.gather_quantize_rows_ref(table, idx)
    return _pq.gather_quantize_rows(table, idx, interpret=_interpret())


# ------------------------------------------------------------------ #
# shard-local (row-block) variants — the per-device halves of the
# collective row ops used by the sharded round engine. ``local_idx``
# is ``global_idx - shard_offset``; out-of-range entries are rows the
# shard does not own (gathers clamp and let the owner-select drop them,
# scatters drop the write).
# ------------------------------------------------------------------ #
def gather_rows_block(table: jax.Array, local_idx: jax.Array) -> jax.Array:
    """Shard-local payload gather over one row block of a sharded table."""
    if _use_ref():
        return _ref.gather_rows_block_ref(table, local_idx)
    return _pg.gather_rows_block(table, local_idx, interpret=_interpret())


def scatter_set_rows_block(
    table: jax.Array, local_idx: jax.Array, rows: jax.Array
) -> jax.Array:
    """Shard-local row commit: in-range rows written, out-of-range dropped."""
    if _use_ref():
        return _ref.scatter_set_rows_block_ref(table, local_idx, rows)
    return _pg.scatter_set_rows_block(table, local_idx, rows,
                                      interpret=_interpret())


def gather_quantize_rows_block(table: jax.Array, local_idx: jax.Array):
    """Shard-local fused gather+int8-quantize over one row block."""
    if _use_ref():
        return _ref.gather_quantize_rows_block_ref(table, local_idx)
    return _pq.gather_quantize_rows_block(table, local_idx,
                                          interpret=_interpret())


# ------------------------------------------------------------------ #
# compressed optimizer-moment row ops (repro.optim.state_compress):
# int8 moment tables are read and written through these fused
# dequant/requant kernels so the full-table fp32 moments never exist.
# ------------------------------------------------------------------ #
def gather_dequant_rows(
    codes: jax.Array, scales: jax.Array, idx: jax.Array
) -> jax.Array:
    """Fused moment read: f32 rows = codes[idx] * scales[idx]."""
    if _use_ref():
        return _ref.gather_dequant_rows_ref(codes, scales, idx)
    return _mq.gather_dequant_rows(codes, scales, idx, interpret=_interpret())


def quant_scatter_set_rows(
    codes: jax.Array, scales: jax.Array, idx: jax.Array, rows: jax.Array,
    noise: Optional[jax.Array] = None,
):
    """Fused moment write: (codes[idx], scales[idx]) = quantize(rows);
    stochastic floor-rounding when ``noise`` (U[0,1) dither) is given."""
    if _use_ref():
        return _ref.quant_scatter_set_rows_ref(codes, scales, idx, rows,
                                               noise)
    return _mq.quant_scatter_set_rows(codes, scales, idx, rows, noise,
                                      interpret=_interpret())


def gather_dequant_rows_block(
    codes: jax.Array, scales: jax.Array, local_idx: jax.Array
) -> jax.Array:
    """Shard-local fused moment read over one row block (clamped gather)."""
    if _use_ref():
        return _ref.gather_dequant_rows_block_ref(codes, scales, local_idx)
    return _mq.gather_dequant_rows_block(codes, scales, local_idx,
                                         interpret=_interpret())


def quant_scatter_set_rows_block(
    codes: jax.Array, scales: jax.Array, local_idx: jax.Array,
    rows: jax.Array, noise: Optional[jax.Array] = None,
):
    """Shard-local fused moment write: out-of-range entries dropped."""
    if _use_ref():
        return _ref.quant_scatter_set_rows_block_ref(codes, scales, local_idx,
                                                     rows, noise)
    return _mq.quant_scatter_set_rows_block(codes, scales, local_idx, rows,
                                            noise, interpret=_interpret())


class RowOps(NamedTuple):
    """Row-granular access to a (possibly row-sharded) (M, K) table.

    The FL round step, the sparse Adam commit and the BTS reward update all
    touch full tables only through gather/scatter of the selected payload
    rows. Abstracting that pair lets the same code run on a resident table
    (``default_row_ops`` — the Pallas/jnp kernels above) or on a row shard
    inside ``shard_map`` (collective-aware ops built by
    :func:`repro.cf.server.shard_row_ops`: local gather -> all-gather ->
    owner-select, and shard-local drop-scatter).

    CONTRACT: ``gather`` returns its rows behind a
    ``lax.optimization_barrier``. The sharded round engine's bit-parity with
    the single-device scan relies on update expressions (Adam moments,
    reward EMAs) compiling against *identical producer graphs* in both
    programs — without the barrier, XLA/LLVM may contract an
    ``a*x + b*y*y`` into an FMA in one fusion context and not the other,
    and the trajectories drift by an ulp per round. Materializing gathered
    rows costs one (M_s, K) buffer and pins the fusion boundary.
    """

    gather: Callable[[jax.Array, jax.Array], jax.Array]
    scatter_set: Callable[[jax.Array, jax.Array, jax.Array], jax.Array]


def default_row_ops() -> RowOps:
    """Row ops over a fully-resident table (the single-device hot path)."""
    from repro.utils.compat import optimization_barrier

    def gather(table: jax.Array, idx: jax.Array) -> jax.Array:
        return optimization_barrier(gather_rows(table, idx))

    return RowOps(gather=gather, scatter_set=scatter_set_rows)


def dequant_scatter_set_rows(
    table: jax.Array, idx: jax.Array, values: jax.Array, scales: jax.Array
) -> jax.Array:
    """Fused wire commit: Q[idx] = dequant(values, scales). Unique ``idx``."""
    if _use_ref():
        return _ref.dequant_scatter_set_rows_ref(table, idx, values, scales)
    return _pq.dequant_scatter_set_rows(table, idx, values, scales,
                                        interpret=_interpret())


def wire_topn(
    cfg,                   # repro.compress.CodecConfig
    wire,                  # full-table wire pytree (row-leading leaves)
    p: jax.Array,          # (B, K) user factors
    dim: int,              # K — decoded row width
    top_n: int,
    train_mask: Optional[jax.Array] = None,   # (B, M) binary; 1 = exclude
    *,
    block_m: int = 1024,
):
    """Fused dequant->score->top-N over a COMPRESSED table: the serving read
    path. Returns ``(scores (B, N) f32, item ids (B, N) i32)`` in descending
    score order with ``lax.top_k`` tie semantics (equal scores -> lowest id).

    Neither the dense fp32 table nor the (B, M) score matrix is ever
    materialized. The topk wire format has no block-dequant kernel (sparse
    scatter, not a row transform) and always takes the chunked oracle.
    """
    if _use_ref() or cfg.name == "topk":
        return _ref.wire_topn_ref(cfg, wire, p, dim, top_n,
                                  train_mask=train_mask, block_m=block_m)
    interp = _interpret()
    if cfg.name in ("fp32", "fp16"):
        return _ps.dense_topn(p, wire.values, top_n, train_mask,
                              block_m=block_m, interpret=interp)
    if cfg.name == "int8":
        return _ps.quant_topn(p, wire.values, wire.scales, top_n, train_mask,
                              block_m=block_m, interpret=interp)
    if cfg.name == "int4":
        return _ps.quant4_topn(p, wire.values, wire.scales, dim, top_n,
                               train_mask, block_m=block_m, interpret=interp)
    raise ValueError(f"no fused scoring path for codec {cfg.name!r}")


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 256,
) -> jax.Array:
    """Grouped-query flash attention (B, H, S, D) x (B, KVH, T, D)."""
    if _use_ref():
        # long sequences: chunked online-softmax oracle so the compiled HLO
        # has flash-like O(S*chunk) memory (dry-run fidelity + CPU memory)
        if q.shape[2] * k.shape[2] > 1024 * 2048:
            return _ref.mha_chunked_ref(q, k, v, causal=causal, window=window,
                                        q_offset=q_offset)
        return _ref.mha_ref(q, k, v, causal=causal, window=window,
                            q_offset=q_offset)
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=_interpret())
