"""Pallas TPU kernels for the framework's compute hot spots.

  fcf_grad        fused FCF item-gradient (the paper's server/client compute)
  payload_gather  payload row gather / scatter-add (the paper's subset ops)
  payload_score   fused dequant->score->top-N over compressed tables (serving)
  flash_attention blockwise GQA attention w/ sliding window (model zoo)

Each kernel has a pure-jnp oracle in ref.py; ops.py exposes the jit'd
wrappers that auto-interpret on CPU.
"""
from repro.kernels.ops import (
    attention, fcf_item_gradients, gather_rows, scatter_add_rows,
    scatter_set_rows, wire_topn,
)

__all__ = [
    "attention", "fcf_item_gradients", "gather_rows", "scatter_add_rows",
    "scatter_set_rows", "wire_topn",
]
