"""Fused dequant -> score -> top-N Pallas kernels: the serving read path.

Training optimizes the write path (which rows move); production FRS traffic
is dominated by recommendation READS. The serving hot loop is

    top-N( mask( P @ decode(wire_table).T ) )

and a naive implementation materializes two tensors the paper's compressed
deployment model says should never exist: the dense fp32 item table
(decode of the whole wire image) and the (B, M) score matrix. These kernels
fuse all three stages over item blocks:

  * one grid step per (block_m, K) row block of the WIRE table — the block
    is dequantized in VMEM (int8/int4 per-row-scale, fp16 widen, fp32
    passthrough), scored against the resident (B, K) user factors on the
    MXU, train-masked, and folded into a running per-user top-N carried in
    the output refs. HBM traffic is one pass over the compressed table
    (4x/~7x fewer bytes than fp32 for int8/int4) plus the (B, N) results;
    peak VMEM is one block + one (B, block_m) score tile.
  * the top-N merge is N unrolled rounds of vectorized first-argmax
    selection over [running top-N | block scores], which reproduces
    ``lax.top_k``'s stable tie rule (equal scores -> lowest item id first)
    exactly — see ``ref.topn_merge_ref`` for the induction argument.

BIT-EXACTNESS CONTRACT (same shape as payload_quant's): dequantization
reproduces :mod:`repro.compress.codecs` op-for-op, scores reduce over K
only (item blocking cannot reorder a dot product), and the merge preserves
top_k tie order — so fp32/fp16/int8 results are bit-identical to
``ref.wire_topn_ref``, values AND indices AND order. int4 shares the exact
unpack sequence but its unpack->dequant->matmul chain may fuse differently
under Mosaic on real TPUs; parity there is documented-ulp (exact in
interpret mode, allclose on hardware) — same caveat class as the round
engine's int4 note. The topk wire format has no kernel (scoring a sparse
wire is a scatter, not a block dequant) and always routes through the ref.

Masking uses the metrics module's ``NEG_INF`` (-1e30) sentinel, so a
train-interaction mask here ranks identically to ``cf.metrics
.ranked_metrics`` — the kernel can back ranked evaluation, not just
serving.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# kernel -> ref.py oracle, for kernels whose oracle is not `<name>_ref`:
# all three codec variants share the one wire-level oracle (repro.analysis
# kernel-parity reads this mapping)
PARITY_ORACLES = {
    "dense_topn": "wire_topn_ref",
    "quant_topn": "wire_topn_ref",
    "quant4_topn": "wire_topn_ref",
}

NEG_INF = -1e30     # train-mask sentinel, shared with repro.cf.metrics


def _unpack_int4_block(packed: jax.Array, dim: int) -> jax.Array:
    """In-VMEM nibble unpack, op-for-op ``codecs.unpack_int4``."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    codes = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    return codes[:, :dim]


def _merge_topn(vals, idxs, s, gidx, top_n: int):
    """N rounds of first-argmax selection over [carry | block] candidates.

    Returns the new (B, N) running top — bit-equal to
    ``lax.top_k(concat([vals, s]), N)`` re-gathered through the candidate
    ids: each round takes the FIRST unpicked position holding the row max
    (ties -> lowest position -> carry before block -> lower item id), which
    is exactly top_k's documented stable order. Selection only moves values
    (no arithmetic), so merged scores are the block scores bit-for-bit.
    """
    b = s.shape[0]
    cand_v = jnp.concatenate([vals, s], axis=1)
    cand_i = jnp.concatenate([idxs, gidx], axis=1)
    c = cand_v.shape[1]
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (b, c), 1)
    col_n = jax.lax.broadcasted_iota(jnp.int32, (b, top_n), 1)
    picked = jnp.zeros((b, c), jnp.bool_)
    new_v = jnp.zeros((b, top_n), jnp.float32)
    new_i = jnp.zeros((b, top_n), jnp.int32)
    for n in range(top_n):
        avail = jnp.where(picked, -jnp.inf, cand_v)
        row_max = jnp.max(avail, axis=1, keepdims=True)          # (B, 1)
        hit = (avail == row_max) & ~picked
        pos = jnp.min(jnp.where(hit, iota_c, c), axis=1, keepdims=True)
        at = iota_c == pos
        val_n = jnp.max(jnp.where(at, cand_v, -jnp.inf), axis=1,
                        keepdims=True)
        idx_n = jnp.sum(jnp.where(at, cand_i, 0), axis=1, keepdims=True)
        new_v = jnp.where(col_n == n, val_n, new_v)
        new_i = jnp.where(col_n == n, idx_n, new_i)
        picked = picked | at
    return new_v, new_i


def _make_score_kernel(kind: str, masked: bool, num_rows: int, dim: int,
                       top_n: int, block_m: int):
    """Kernel body for one wire layout; refs arrive [p, wire..., mask?, outs]."""
    n_wire = 1 if kind == "dense" else 2

    def dequant(wire_refs) -> jax.Array:
        if kind == "dense":
            return wire_refs[0][...].astype(jnp.float32)
        codes_ref, scales_ref = wire_refs
        if kind == "int4":
            codes = _unpack_int4_block(codes_ref[...], dim)
        else:
            codes = codes_ref[...]
        # op-for-op codecs.dequantize_rows: codes f32 * per-row f32 scale
        return codes.astype(jnp.float32) * scales_ref[...]

    def kernel(*refs):
        p_ref = refs[0]
        wire_refs = refs[1:1 + n_wire]
        mask_ref = refs[1 + n_wire] if masked else None
        vals_ref, idx_ref = refs[-2], refs[-1]
        j = pl.program_id(0)

        @pl.when(j == 0)
        def _init():
            vals_ref[...] = jnp.full(vals_ref.shape, -jnp.inf, jnp.float32)
            idx_ref[...] = jnp.zeros(idx_ref.shape, jnp.int32)

        q = dequant(wire_refs)                                  # (bm, K) f32
        s = jnp.dot(p_ref[...].astype(jnp.float32), q.T,
                    preferred_element_type=jnp.float32)         # (B, bm)
        b = s.shape[0]
        gidx = j * block_m + jax.lax.broadcasted_iota(
            jnp.int32, (b, block_m), 1)
        if masked:
            s = jnp.where(mask_ref[...] > 0, NEG_INF, s)
        # rows past the true table end (grid padding) can never win
        s = jnp.where(gidx < num_rows, s, -jnp.inf)
        new_v, new_i = _merge_topn(vals_ref[...], idx_ref[...], s, gidx,
                                   top_n)
        vals_ref[...] = new_v
        idx_ref[...] = new_i

    return kernel


def _call_topn(kind, p, wire_arrays, mask, top_n, block_m, interpret,
               num_rows, dim):
    b, _ = p.shape
    nb = -(-num_rows // block_m)
    wire_specs = [
        pl.BlockSpec((block_m, a.shape[1]), lambda j: (j, 0))
        for a in wire_arrays
    ]
    in_specs = [pl.BlockSpec(p.shape, lambda j: (0, 0))] + wire_specs
    operands = [p] + list(wire_arrays)
    if mask is not None:
        in_specs.append(pl.BlockSpec((b, block_m), lambda j: (0, j)))
        operands.append(mask)
    kernel = _make_score_kernel(kind, mask is not None, num_rows, dim,
                                top_n, block_m)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((b, top_n), lambda j: (0, 0)),
            pl.BlockSpec((b, top_n), lambda j: (0, 0)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((b, top_n), jnp.float32),
            jax.ShapeDtypeStruct((b, top_n), jnp.int32),
        ),
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit,
                   static_argnames=("top_n", "block_m", "interpret"))
def dense_topn(
    p: jax.Array,          # (B, K) user factors
    values: jax.Array,     # (M, K) fp32/fp16 table (DenseWire.values)
    top_n: int,
    mask: Optional[jax.Array] = None,    # (B, M) binary; 1 = exclude
    *,
    block_m: int = 1024,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused score+top-N over a dense (possibly fp16) wire table."""
    return _call_topn("dense", p, (values,), mask, top_n, block_m,
                      interpret, values.shape[0], values.shape[1])


@functools.partial(jax.jit,
                   static_argnames=("top_n", "block_m", "interpret"))
def quant_topn(
    p: jax.Array,          # (B, K)
    codes: jax.Array,      # (M, K) int8 codes (QuantWire.values)
    scales: jax.Array,     # (M, 1) float32 per-row scales
    top_n: int,
    mask: Optional[jax.Array] = None,
    *,
    block_m: int = 1024,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused int8 dequant+score+top-N — never materializes fp32 rows."""
    return _call_topn("int8", p, (codes, scales), mask, top_n, block_m,
                      interpret, codes.shape[0], codes.shape[1])


@functools.partial(jax.jit,
                   static_argnames=("dim", "top_n", "block_m", "interpret"))
def quant4_topn(
    p: jax.Array,          # (B, K)
    packed: jax.Array,     # (M, ceil(K/2)) uint8 nibble pairs
    scales: jax.Array,     # (M, 1) float32
    dim: int,              # K (the unpacked row width)
    top_n: int,
    mask: Optional[jax.Array] = None,
    *,
    block_m: int = 1024,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused int4 unpack+dequant+score+top-N (documented-ulp tier)."""
    return _call_topn("int4", p, (packed, scales), mask, top_n, block_m,
                      interpret, packed.shape[0], dim)
