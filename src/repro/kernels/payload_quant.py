"""Fused payload compression Pallas kernels.

With a quantized wire format the FL server's per-round hot path becomes:

  * downlink: Q*[wire] = quantize(Q[idx])  — gather M_s of M rows AND
    quantize them, fused into one kernel so each selected row makes a
    single HBM->VMEM trip and leaves VMEM already in wire format
    (:func:`gather_quantize_rows`).
  * uplink/commit: table[idx] = dequantize(wire rows) — dequantize the
    received int8 rows and scatter them into the resident float32 table in
    one kernel, aliased in place (:func:`dequant_scatter_set_rows`). This
    is the client-side patch-in of a quantized downlink (the client's
    local model is the server model with the fresh rows written over it)
    and the server-side commit of wire-format row payloads.

Same structure as :mod:`repro.kernels.payload_gather`: one grid step per
selected row, scalar-prefetched indices so the index_map can steer the row
DMA, (1, K) blocks in VMEM.

BIT-EXACTNESS CONTRACT: the quantization math here must reproduce
:func:`repro.compress.codecs.quantize_rows` / ``dequantize_rows``
bit-for-bit (same op sequence: absmax -> scale = absmax/qmax ->
codes = clip(round(x * (1/scale)))), so a kernel-routed round and a
pure-codec round produce identical trajectories. ``kernels/ref.py``
delegates to the codec functions and the kernel tests assert exact
equality against those refs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compress.codecs import _QMAX as _CODEC_QMAX

_QMAX = float(_CODEC_QMAX[8])      # symmetric int8 grid, shared w/ codec


def _gather_quant_kernel(idx_ref, table_ref, values_ref, scales_ref):
    # table_ref block is (1, K) at row idx[i] — selected by the index_map.
    row = table_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(row), axis=-1, keepdims=True)      # (1, 1)
    scale = absmax * (1.0 / _QMAX)   # matches codecs.quantize_rows exactly
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    values_ref[...] = jnp.clip(
        jnp.round(row * inv), -_QMAX, _QMAX).astype(jnp.int8)
    scales_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_quantize_rows(
    table: jax.Array,      # (M, K) float table
    idx: jax.Array,        # (M_s,) int32 unique row ids
    *,
    interpret: bool = False,
):
    """Fused downlink encode: ``(codes, scales) = quantize(table[idx])``.

    Returns ``codes`` int8 (M_s, K) and ``scales`` float32 (M_s, 1) — the
    int8 wire image of the selected payload rows, produced in one pass
    over the gathered rows instead of gather-then-quantize.
    """
    m_s = idx.shape[0]
    k = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_s,),
        in_specs=[pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, idx_ref: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, idx_ref: (i, 0)),
        ],
    )
    return pl.pallas_call(
        _gather_quant_kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((m_s, k), jnp.int8),
            jax.ShapeDtypeStruct((m_s, 1), jnp.float32),
        ),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_quantize_rows_block(
    table: jax.Array,      # (m, K) — one shard's row block of a larger table
    local_idx: jax.Array,  # (M_s,) shard-local row ids; may be out of range
    *,
    interpret: bool = False,
):
    """Shard-local fused downlink encode over a row-sharded table.

    Identical to :func:`gather_quantize_rows` on ``clip(local_idx)``: every
    shard produces a full (M_s,) wire candidate block (int8 codes + scales)
    whose rows it does not own are clamp artifacts, discarded by the
    owner-select after the all-gather. Because quantization is per-row, the
    rows a shard *does* own carry exactly the codes/scales a single-device
    encode of the full table would produce — so the collective moves the
    already-quantized wire image (4x fewer bytes than fp32 rows) without
    giving up bit-parity with the unsharded path.
    """
    m = table.shape[0]
    safe = jnp.clip(local_idx.astype(jnp.int32), 0, m - 1)
    return gather_quantize_rows(table, safe, interpret=interpret)


def _dequant_scatter_kernel(idx_ref, values_ref, scales_ref, table_in_ref,
                            out_ref):
    # aliased in/out: overwrite the table row with the dequantized payload.
    del table_in_ref
    row = values_ref[...].astype(jnp.float32) * scales_ref[...]
    out_ref[...] = row.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def dequant_scatter_set_rows(
    table: jax.Array,      # (M, K) — donated and updated in place
    idx: jax.Array,        # (M_s,) unique row ids
    values: jax.Array,     # (M_s, K) int8 codes
    scales: jax.Array,     # (M_s, 1) float32 per-row scales
    *,
    interpret: bool = False,
) -> jax.Array:
    """Fused wire commit: ``table[idx[i]] = values[i] * scales[i]``.

    The dequantize-and-patch of a quantized row payload into a resident
    float table, aliased so no O(M*K) copy is made.
    """
    m_s = idx.shape[0]
    k = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_s,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, idx_ref: (i, 0)),           # values
            pl.BlockSpec((1, 1), lambda i, idx_ref: (i, 0)),           # scales
            pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),  # table
        ],
        out_specs=pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    return pl.pallas_call(
        _dequant_scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        # alias the table operand (positional arg 3: idx, values, scales, table)
        input_output_aliases={3: 0},
        interpret=interpret,
    )(idx.astype(jnp.int32), values, scales, table)
