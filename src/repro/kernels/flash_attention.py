"""Blockwise (flash) attention Pallas kernel for TPU.

Online-softmax attention with support for:
  * causal masking,
  * GQA (q heads grouped onto fewer kv heads) via index-map arithmetic —
    kv blocks are never replicated in HBM,
  * sliding-window masking (the sub-quadratic variant used by the SWA /
    hybrid architectures and required for the long_500k decode shape),
  * a query-position offset so the same kernel serves decode (1 query token
    against a long KV cache).

TPU mapping:
  grid = (B, H, num_q_blocks, num_kv_blocks) — kv is the minor (sequential)
  dimension, so the running max / denominator / accumulator for one q block
  live in VMEM scratch across kv steps (revisited output block). Block shapes
  keep the MXU busy: (block_q, d) x (d, block_k) with d padded to 128 by the
  wrapper in ops.py; block_q/block_k default to 128/256.

  VMEM working set per program ~= block_q*d + block_k*d (q,k,v tiles)
  + block_q*block_k logits + scratch — ~1.2 MB at the defaults in f32,
  comfortably under the ~16 MB/core v5e budget with double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# kernel -> ref.py oracle (repro.analysis kernel-parity reads this mapping)
PARITY_ORACLES = {"flash_attention": "mha_ref"}

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, out_ref,
    m_scratch, l_scratch, acc_scratch,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    block_q: int,
    block_k: int,
    kv_len: int,
):
    kv_idx = pl.program_id(3)
    q_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0].astype(jnp.float32)          # (block_q, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_k, d)
    v = v_ref[0, 0].astype(jnp.float32)          # (block_k, d)

    logits = jax.lax.dot_general(                # (block_q, block_k)
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale

    qpos = q_offset + q_idx * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = kv_idx * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len                          # kv padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scratch[...]                       # (block_q, 1)
    l_prev = l_scratch[...]
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (all NEG_INF): keep exp at 0, not NaN
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)

    acc = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scratch[...] = m_new
    l_scratch[...] = l_new
    acc_scratch[...] = acc

    @pl.when(kv_idx == pl.num_programs(3) - 1)
    def _finalize():
        denom = jnp.maximum(l_scratch[...], 1e-30)
        out_ref[0, 0] = (acc_scratch[...] / denom).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(
    q: jax.Array,                  # (B, H, S, D)
    k: jax.Array,                  # (B, KVH, T, D)
    v: jax.Array,                  # (B, KVH, T, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Grouped-query flash attention. Returns (B, H, S, D) in q.dtype."""
    b, h, s, d = q.shape
    kvh, t = k.shape[1], k.shape[2]
    assert h % kvh == 0, f"GQA requires H % KVH == 0, got {h} % {kvh}"
    group = h // kvh
    scale = 1.0 / (d ** 0.5)

    block_q = min(block_q, s)
    block_k = min(block_k, t)
    s_pad = (s + block_q - 1) // block_q * block_q
    t_pad = (t + block_k - 1) // block_k * block_k
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))

    grid = (b, h, s_pad // block_q, t_pad // block_k)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            q_offset=q_offset, block_q=block_q, block_k=block_k, kv_len=t,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s, :]
