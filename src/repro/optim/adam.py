"""Adam (Kingma & Ba, 2015) from scratch — no optax in this environment.

Two entry points:

  * ``adam_update``       — dense update over an arbitrary pytree (LLM training).
  * ``adam_update_rows``  — sparse row-subset update over a 2-D table: only the
    selected rows' parameters *and moments* advance, with per-row timestep
    bias correction. This is the server-side update of Algorithm 1 line 13 for
    payload-selected item-factor (or vocab-embedding) rows.
  * ``adam_update_rows_scattered`` — same update with row traffic routed
    through the payload gather/scatter Pallas kernels; used by the fused
    ``server_round_step`` so a compiled FL round never copies the full table.

Paper server hyper-parameters (Table 3): beta1=0.1, beta2=0.99, eta=0.01,
eps=1e-8.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamConfig(NamedTuple):
    lr: float = 0.01
    beta1: float = 0.1
    beta2: float = 0.99
    eps: float = 1e-8


class AdamState(NamedTuple):
    m: Any         # first-moment pytree (or (M, K) table for row mode)
    v: Any         # second-moment pytree
    t: jax.Array   # scalar step count (dense) or (M,) per-row step counts


def adam_init(params: Any, per_row: bool = False,
              moment: Optional[Any] = None) -> AdamState:
    """Zero state for ``params``. ``per_row=True`` is the row-subset mode
    over a single (M, K) table (per-row timesteps); ``moment`` (a
    :class:`repro.optim.state_compress.MomentCodecConfig`) selects
    compressed moment storage for that table — ``None`` or the fp32
    default allocates exactly the historical fp32 state."""
    if per_row:
        if not (hasattr(params, "shape") and hasattr(params, "dtype")):
            raise TypeError(
                "adam_init(per_row=True) operates on a single (M, K) row "
                f"table, not a pytree; got {type(params).__name__}. Build "
                "one per-row AdamState per table, or use per_row=False for "
                "pytree parameters.")
        num_rows = params.shape[0]
        t = jnp.zeros((num_rows,), jnp.int32)
        if moment is not None:
            from repro.optim import state_compress as sc  # deferred: no cycle

            if sc.is_compressed(moment):
                dim = params.shape[1]
                return AdamState(
                    m=sc.moment_init(moment.m_dtype, num_rows, dim),
                    v=sc.moment_init(moment.v_dtype, num_rows, dim),
                    t=t)
        return AdamState(m=jnp.zeros_like(params), v=jnp.zeros_like(params),
                         t=t)
    if moment is not None:
        raise ValueError("compressed moment storage (moment=...) requires "
                         "per_row=True — dense pytree Adam stays fp32")
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamState(m=zeros, v=zeros, t=jnp.zeros((), jnp.int32))


def adam_update(
    grads: Any, state: AdamState, params: Any, config: AdamConfig = AdamConfig()
) -> Tuple[Any, AdamState]:
    """Standard dense Adam over a pytree. Returns (new_params, new_state)."""
    t = state.t + 1
    tf = t.astype(jnp.float32)
    b1, b2 = config.beta1, config.beta2

    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g), state.v, grads)
    mhat_scale = 1.0 / (1.0 - jnp.power(b1, tf))
    vhat_scale = 1.0 / (1.0 - jnp.power(b2, tf))

    def step(p, mm, vv):
        return p - config.lr * (mm * mhat_scale) / (
            jnp.sqrt(vv * vhat_scale) + config.eps)

    new_params = jax.tree.map(step, params, m, v)
    return new_params, AdamState(m=m, v=v, t=t)


def adam_update_rows(
    grad_rows: jax.Array,   # (M_s, K) aggregated gradient for selected rows
    indices: jax.Array,     # (M_s,) row ids
    state: AdamState,       # per-row state over the full (M, K) table
    table: jax.Array,       # (M, K) full parameter table
    config: AdamConfig = AdamConfig(),
) -> Tuple[jax.Array, AdamState]:
    """Sparse Adam: advance only the selected rows (payload-subset update).

    Per-row timesteps keep bias correction exact for rows that are selected
    at different frequencies — important under bandit selection where popular
    arms are updated far more often than tail arms.
    """
    b1, b2 = config.beta1, config.beta2
    t_rows = state.t[indices] + 1
    tf = t_rows.astype(jnp.float32)[:, None]

    m_rows = b1 * state.m[indices] + (1 - b1) * grad_rows
    v_rows = b2 * state.v[indices] + (1 - b2) * jnp.square(grad_rows)
    mhat = m_rows / (1.0 - jnp.power(b1, tf))
    vhat = v_rows / (1.0 - jnp.power(b2, tf))
    new_rows = table[indices] - config.lr * mhat / (jnp.sqrt(vhat) + config.eps)

    return (
        table.at[indices].set(new_rows),
        AdamState(
            m=state.m.at[indices].set(m_rows),
            v=state.v.at[indices].set(v_rows),
            t=state.t.at[indices].set(t_rows),
        ),
    )


def adam_update_rows_scattered(
    grad_rows: jax.Array,   # (M_s, K) aggregated gradient for selected rows
    indices: jax.Array,     # (M_s,) row ids
    state: AdamState,       # per-row state over the full (M, K) table
    table: jax.Array,       # (M, K) full parameter table
    config: AdamConfig = AdamConfig(),
    row_ops=None,           # optional kernels.ops.RowOps override
    row_weights: Optional[jax.Array] = None,   # (M_s,) staleness discounts
    row_mask: Optional[jax.Array] = None,      # (M_s,) bool commit gate
    moment: Optional[Any] = None,              # MomentCodecConfig (fp32=None)
    moment_key: Optional[jax.Array] = None,    # SR dither key (int8 moments)
) -> Tuple[jax.Array, AdamState]:
    """:func:`adam_update_rows` with all row traffic routed through the
    payload gather / scatter kernels (:mod:`repro.kernels.ops`).

    Semantically identical to the ``.at[idx]`` variant; on TPU the four
    (M, K) tables (params, m, v) never materialize an O(M*K) copy — only the
    selected (M_s, K) tiles move through VMEM, which is what makes the fused
    scan round step cheap at LLM-vocab scale. On CPU the ops layer dispatches
    to the jnp oracles, so the math is bit-identical across backends.

    ``row_ops`` swaps the row gather/scatter pair, letting the sharded round
    engine run this exact update against row-sharded params/moments inside
    ``shard_map`` (collective gathers, shard-local scatters). The (M,)
    per-row timestep vector is cheap and always stays resident/replicated.

    ``row_weights`` is the async engine's per-row staleness discount: each
    committed row's *step* is scaled by its weight (FedAsync-style
    ``q <- q - w(s) * eta * step``). The discount deliberately lands on the
    step, not the gradient: Adam's update is near-invariant to gradient
    scaling (m and v scale together), so damping the gradient would damp
    nothing. Moments and per-row timesteps advance undamped — they are
    statistics of the arriving gradients, and a stale gradient is still an
    observation. A weight of exactly 1.0 is a bitwise no-op (IEEE multiply
    by one), which is what makes the async engine's ``max_staleness=0``
    trajectory bit-identical to the synchronous scan.

    ``row_mask`` is the fault layer's per-row commit gate (repro.faults):
    a False row scatters back its *old* table/moment/timestep values — an
    exact no-op, as if the row's update never arrived — which is how
    checksum-rejected wire rows are kept out of the model. ``None`` (the
    default) compiles the exact program this function always built.

    ``moment`` (a :class:`repro.optim.state_compress.MomentCodecConfig`)
    selects compressed moment storage: the update decodes the selected
    rows' moments to fp32 tiles, runs this exact math, and re-encodes —
    fp32 moments of the full table are never materialized. ``None`` or
    the fp32 default takes the code path below UNTOUCHED (the frozen ==
    today contract). ``moment_key`` seeds the stochastic-rounding dither
    for int8 moment writes (required iff the config stochastically
    rounds an int8 moment).
    """
    from repro.kernels import ops  # deferred: keep optim importable standalone

    if moment is not None:
        from repro.optim import state_compress as sc  # deferred: no cycle

        if sc.is_compressed(moment):
            return sc.adam_update_rows_compressed(
                grad_rows, indices, state, table, config, moment,
                key=moment_key, row_ops=row_ops, row_weights=row_weights,
                row_mask=row_mask)
    if row_ops is None:
        row_ops = ops.default_row_ops()
    b1, b2 = config.beta1, config.beta2
    t_rows = state.t[indices] + 1            # (M_s,) 1-D: plain jnp indexing
    tf = t_rows.astype(jnp.float32)[:, None]

    m_old = row_ops.gather(state.m, indices)
    v_old = row_ops.gather(state.v, indices)
    m_rows = b1 * m_old + (1 - b1) * grad_rows
    v_rows = b2 * v_old + (1 - b2) * jnp.square(grad_rows)
    mhat = m_rows / (1.0 - jnp.power(b1, tf))
    vhat = v_rows / (1.0 - jnp.power(b2, tf))
    step = config.lr * mhat / (jnp.sqrt(vhat) + config.eps)
    if row_weights is not None:
        step = step * row_weights.astype(jnp.float32)[:, None]
    table_old = row_ops.gather(table, indices)
    new_rows = table_old - step
    if row_mask is not None:
        keep = row_mask[:, None]
        m_rows = jnp.where(keep, m_rows, m_old)
        v_rows = jnp.where(keep, v_rows, v_old)
        new_rows = jnp.where(keep, new_rows, table_old)
        t_rows = jnp.where(row_mask, t_rows, state.t[indices])
    # pin the update expressions' fusion boundary on the consumer side too:
    # sandwiched between the gather barriers (RowOps contract) and this one,
    # the moment/param math compiles identically no matter which scatter
    # flavor (resident vs shard-local) consumes it — the bit-parity contract
    # between the sharded and single-device round engines
    from repro.utils.compat import optimization_barrier
    m_rows, v_rows, new_rows = optimization_barrier(
        (m_rows, v_rows, new_rows))

    return (
        row_ops.scatter_set(table, indices, new_rows),
        AdamState(
            m=row_ops.scatter_set(state.m, indices, m_rows),
            v=row_ops.scatter_set(state.v, indices, v_rows),
            t=state.t.at[indices].set(t_rows),
        ),
    )


def sgd_update(grads: Any, params: Any, lr: float) -> Any:
    """Plain SGD (Eq. 4 without Adam), kept for ablations."""
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)
