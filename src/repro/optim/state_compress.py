"""Compressed Adam moment storage — payload optimization for the OPTIMIZER.

The paper shrinks what crosses the wire; this module shrinks what stays
resident. At M=10^7 items the fp32 Adam moments are 2x the size of the
model itself (8 bytes/value vs Q's 4), so the largest table one host can
train is bounded by optimizer state, not the model. The same per-row-scale
quantization the wire codecs use (:mod:`repro.compress.codecs` — encode
and decode stay property-tested in ONE place) applies to the moments:

  * ``bf16``     — 2 bytes/value, round-to-nearest-even cast. 0.5x fp32.
  * ``int8``     — 1 byte/value + one float32 scale per row
    (:class:`QuantMoment`), written with STOCHASTIC rounding so sub-quantum
    updates accumulate in expectation instead of rounding away. 0.26x fp32
    at K=16.
  * ``factored`` — SM3/Adafactor-style factored SECOND moment: the (M, K)
    accumulator collapses to a per-row (M,) + per-column (K,) pair
    (:class:`FactoredMoment`) with ``v[i, j]`` estimated as
    ``r[i] * c[j] / mean(c)``. O(M+K) instead of O(M*K) — the second
    moment all but vanishes from the resident budget.

:class:`MomentCodecConfig` is static configuration (a hashable NamedTuple
living in ``FCFServerConfig``, never in the scan carry); the moment
*representation* it selects is an ordinary pytree riding ``AdamState.m`` /
``AdamState.v``, so compressed states scan, vmap, shard (codes and scales
are rank-2 leading-M leaves — ``fcf_state_pspecs`` row-shards them like
every other table) and checkpoint (flat-key npz) with zero special cases.

FROZEN CONTRACT: the default config (``m_dtype="fp32", v_dtype="fp32"``,
or a ``None`` moment config anywhere one is accepted) is *not routed
through this module at all* — :func:`repro.optim.adam.adam_init` and
``adam_update_rows_scattered`` take their historical code paths and
compile byte-identical programs, keeping every existing trajectory
bit-for-bit across the scan/python/shard/async backends.

Update semantics (:func:`adam_update_rows_compressed`): decode the
selected rows' moments to float32, run EXACTLY the dense-path Adam math on
those (M_s, K) tiles, re-encode, scatter. The fp32 moments of the full
table are never materialized — only payload-sized tiles move — and on the
single-device hot path the decode-gather and requant-scatter are fused
Pallas kernels (:mod:`repro.kernels.moment_quant`), one HBM trip per row.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compress.codecs import (
    dequantize_rows, quantize_rows, quantize_rows_stochastic,
)
from repro.optim.adam import AdamConfig, AdamState

M_DTYPES = ("fp32", "bf16", "int8")
V_DTYPES = ("fp32", "bf16", "int8", "factored")

# fold_in salts deriving the two independent stochastic-rounding streams
# from one per-round key (m and v must not share dither)
_SALT_M = 0x6d
_SALT_V = 0x76


class MomentCodecConfig(NamedTuple):
    """Static (hashable) moment-storage config, fixed for a whole run."""

    m_dtype: str = "fp32"            # fp32 | bf16 | int8
    v_dtype: str = "fp32"            # fp32 | bf16 | int8 | factored
    # int8 write path: stochastic rounding (floor(x/scale + u), u~U[0,1))
    # keeps the quantized moment an unbiased estimate of the fp32 one.
    # Irrelevant for fp32/bf16/factored.
    stochastic_rounding: bool = True


class QuantMoment(NamedTuple):
    """int8 moment table: per-row-scale codes, the wire codec's layout."""

    codes: jax.Array                 # (M, K) int8
    scales: jax.Array                # (M, 1) float32


class FactoredMoment(NamedTuple):
    """SM3-style factored second moment: (M, K) collapsed to (M,) + (K,).

    ``row[i]`` and ``col[j]`` are EMAs of the per-row / per-column mean
    squared gradient over the rows each commit touches; the full second
    moment is estimated as ``row[i] * col[j] / mean(col)`` (exact for
    rank-1 squared gradients, and exactly ``row`` when K == 1). ``row``
    uses the per-row timesteps for bias correction (rows commit at
    different frequencies under bandit selection); ``col`` aggregates
    over every commit and carries its own scalar timestep.
    """

    row: jax.Array                   # (M,) float32
    col: jax.Array                   # (K,) float32
    col_t: jax.Array                 # () int32 — commits observed


def validate_config(cfg: MomentCodecConfig) -> None:
    if cfg.m_dtype not in M_DTYPES:
        raise ValueError(
            f"moment m_dtype must be one of {M_DTYPES}, got {cfg.m_dtype!r}")
    if cfg.v_dtype not in V_DTYPES:
        raise ValueError(
            f"moment v_dtype must be one of {V_DTYPES}, got {cfg.v_dtype!r}")


def is_compressed(cfg: Optional[MomentCodecConfig]) -> bool:
    """True when ``cfg`` selects anything other than the frozen fp32 path."""
    if cfg is None:
        return False
    validate_config(cfg)
    return cfg.m_dtype != "fp32" or cfg.v_dtype != "fp32"


def needs_sr_key(cfg: Optional[MomentCodecConfig]) -> bool:
    """True when the update needs a PRNG key (stochastic int8 writes)."""
    return (is_compressed(cfg) and cfg.stochastic_rounding
            and "int8" in (cfg.m_dtype, cfg.v_dtype))


def moment_init(dtype: str, num_rows: int, dim: int) -> Any:
    """All-zero moment pytree for one (num_rows, dim) table."""
    if dtype == "fp32":
        return jnp.zeros((num_rows, dim), jnp.float32)
    if dtype == "bf16":
        return jnp.zeros((num_rows, dim), jnp.bfloat16)
    if dtype == "int8":
        return QuantMoment(codes=jnp.zeros((num_rows, dim), jnp.int8),
                           scales=jnp.zeros((num_rows, 1), jnp.float32))
    if dtype == "factored":
        return FactoredMoment(row=jnp.zeros((num_rows,), jnp.float32),
                              col=jnp.zeros((dim,), jnp.float32),
                              col_t=jnp.zeros((), jnp.int32))
    raise ValueError(f"unknown moment dtype {dtype!r}")


def moment_nbytes(dtype: str, num_rows: int, dim: int) -> int:
    """Resident bytes of one moment table (static accounting)."""
    if dtype == "fp32":
        return num_rows * dim * 4
    if dtype == "bf16":
        return num_rows * dim * 2
    if dtype == "int8":
        return num_rows * dim + num_rows * 4
    if dtype == "factored":
        return num_rows * 4 + dim * 4 + 4
    raise ValueError(f"unknown moment dtype {dtype!r}")


def state_nbytes(cfg: Optional[MomentCodecConfig], num_rows: int,
                 dim: int) -> int:
    """Resident bytes of a full per-row AdamState (m + v + (M,) timesteps)."""
    c = cfg or MomentCodecConfig()
    return (moment_nbytes(c.m_dtype, num_rows, dim)
            + moment_nbytes(c.v_dtype, num_rows, dim)
            + num_rows * 4)


# ===================================================================== #
# row-tile encode / decode — all math delegated to compress.codecs
# ===================================================================== #
def decode_moment_rows(dtype: str, mom: Any, indices: jax.Array,
                       row_ops, fused: bool,
                       need_raw: bool = False) -> Tuple[jax.Array, Any]:
    """Gather + decode the selected rows of a dense moment table.

    Returns ``(rows_f32, raw_rows)``: the float32 (M_s, K) tile the Adam
    math runs on, plus (when ``need_raw`` — the fault-mask path) the
    gathered rows in their STORED representation — what a masked
    (fault-rejected) row must scatter back for an exact no-op, since a
    stochastic re-encode of a decoded row is not the identity. ``fused``
    (single-device resident tables only) routes the int8 path through the
    fused gather+dequant kernel; the sharded path composes the per-leaf
    collective gathers and dequantizes the assembled tiles — per-row
    encoding makes the two bit-identical.
    """
    from repro.kernels import ops
    from repro.utils.compat import optimization_barrier

    if dtype == "bf16":
        raw = row_ops.gather(mom, indices)
        return raw.astype(jnp.float32), raw
    if dtype == "int8":
        if fused and not need_raw:
            rows = optimization_barrier(
                ops.gather_dequant_rows(mom.codes, mom.scales, indices))
            return rows, None
        code_rows = row_ops.gather(mom.codes, indices)
        scale_rows = row_ops.gather(mom.scales, indices)
        return (dequantize_rows(code_rows, scale_rows),
                QuantMoment(codes=code_rows, scales=scale_rows))
    raise ValueError(f"no dense row decode for moment dtype {dtype!r}")


def encode_scatter_moment_rows(
    dtype: str, mom: Any, indices: jax.Array, rows_f32: jax.Array,
    raw_old: Any, row_mask: Optional[jax.Array],
    noise: Optional[jax.Array], row_ops, fused: bool,
) -> Any:
    """Re-encode updated float32 row tiles and scatter them back.

    ``noise`` (U[0,1), same shape as ``rows_f32``) selects stochastic
    rounding on the int8 path; ``None`` is round-to-nearest. ``row_mask``
    restores the ORIGINAL stored rows (``raw_old``) for False entries —
    bit-exact no-ops, the fault layer's reject contract.
    """
    from repro.kernels import ops

    if dtype == "bf16":
        out = rows_f32.astype(jnp.bfloat16)
        if row_mask is not None:
            out = jnp.where(row_mask[:, None], out, raw_old)
        return row_ops.scatter_set(mom, indices, out)
    if dtype == "int8":
        if fused and row_mask is None:
            codes, scales = ops.quant_scatter_set_rows(
                mom.codes, mom.scales, indices, rows_f32, noise)
            return QuantMoment(codes=codes, scales=scales)
        if noise is not None:
            code_rows, scale_rows = quantize_rows_stochastic(rows_f32, noise)
        else:
            code_rows, scale_rows = quantize_rows(rows_f32, nbits=8)
        if row_mask is not None:
            keep = row_mask[:, None]
            code_rows = jnp.where(keep, code_rows, raw_old.codes)
            scale_rows = jnp.where(keep, scale_rows, raw_old.scales)
        return QuantMoment(
            codes=row_ops.scatter_set(mom.codes, indices, code_rows),
            scales=row_ops.scatter_set(mom.scales, indices, scale_rows))
    raise ValueError(f"no dense row encode for moment dtype {dtype!r}")


# ===================================================================== #
# the compressed sparse-Adam commit
# ===================================================================== #
def adam_update_rows_compressed(
    grad_rows: jax.Array,   # (M_s, K) aggregated gradient for selected rows
    indices: jax.Array,     # (M_s,) row ids
    state: AdamState,       # moments stored per ``moment``'s dtypes
    table: jax.Array,       # (M, K) full parameter table
    config: AdamConfig,
    moment: MomentCodecConfig,
    *,
    key: Optional[jax.Array] = None,     # per-commit PRNG key (SR dither)
    row_ops=None,
    row_weights: Optional[jax.Array] = None,
    row_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, AdamState]:
    """:func:`repro.optim.adam.adam_update_rows_scattered` over compressed
    moment storage: decode the selected tiles, run the IDENTICAL fp32 Adam
    math, re-encode, scatter. Entered only for genuinely compressed
    configs — the fp32 default never reaches this function (frozen
    contract). ``key`` is required when the config stochastically rounds
    an int8 moment; two independent dither streams are folded out of it.

    The factored second moment updates its (M,) row EMA on the selected
    rows (per-row timestep bias correction, like every dense moment) and
    its (K,) column EMA once per commit from the column mean of g^2 over
    the committed rows (masked rows excluded); ``v_hat`` is the SM3-style
    outer-product estimate ``r_hat[i] * c_hat[j] / mean(c_hat)``.
    """
    from repro.kernels import ops as kops
    from repro.utils.compat import optimization_barrier

    validate_config(moment)
    if needs_sr_key(moment) and key is None:
        raise ValueError(
            "MomentCodecConfig with stochastic_rounding=True and an int8 "
            "moment needs a per-commit PRNG key (pass key=...)")
    fused = row_ops is None
    if row_ops is None:
        row_ops = kops.default_row_ops()
    b1, b2 = config.beta1, config.beta2
    t_rows = state.t[indices] + 1            # (M_s,)
    tf = t_rows.astype(jnp.float32)[:, None]

    noise_m = noise_v = None
    if moment.stochastic_rounding and key is not None:
        if moment.m_dtype == "int8":
            noise_m = jax.random.uniform(
                jax.random.fold_in(key, _SALT_M), grad_rows.shape)
        if moment.v_dtype == "int8":
            noise_v = jax.random.uniform(
                jax.random.fold_in(key, _SALT_V), grad_rows.shape)

    # first moment: decode -> EMA -> bias-correct (dense-path math verbatim)
    if moment.m_dtype == "fp32":
        m_old, m_raw = row_ops.gather(state.m, indices), None
    else:
        m_old, m_raw = decode_moment_rows(
            moment.m_dtype, state.m, indices, row_ops, fused,
            need_raw=row_mask is not None)
    m_rows = b1 * m_old + (1 - b1) * grad_rows
    mhat = m_rows / (1.0 - jnp.power(b1, tf))

    # second moment: dense (any dtype) or factored estimate
    g2 = jnp.square(grad_rows)
    factored = moment.v_dtype == "factored"
    if factored:
        fac: FactoredMoment = state.v
        r_old = fac.row[indices]                               # (M_s,)
        r_rows = b2 * r_old + (1 - b2) * jnp.mean(g2, axis=1)
        if row_mask is not None:
            w = row_mask.astype(jnp.float32)[:, None]
            col_obs = (jnp.sum(g2 * w, axis=0)
                       / jnp.maximum(jnp.sum(w), 1.0))
        else:
            col_obs = jnp.mean(g2, axis=0)                     # (K,)
        col_t = fac.col_t + 1
        c_new = b2 * fac.col + (1 - b2) * col_obs
        rhat = r_rows / (1.0 - jnp.power(b2, tf[:, 0]))        # (M_s,)
        chat = c_new / (1.0 - jnp.power(b2, col_t.astype(jnp.float32)))
        vhat = (rhat[:, None] * chat[None, :]
                / jnp.maximum(jnp.mean(chat), config.eps))
        v_rows = v_raw = None
    else:
        if moment.v_dtype == "fp32":
            v_old, v_raw = row_ops.gather(state.v, indices), None
        else:
            v_old, v_raw = decode_moment_rows(
                moment.v_dtype, state.v, indices, row_ops, fused,
                need_raw=row_mask is not None)
        v_rows = b2 * v_old + (1 - b2) * g2
        vhat = v_rows / (1.0 - jnp.power(b2, tf))

    step = config.lr * mhat / (jnp.sqrt(vhat) + config.eps)
    if row_weights is not None:
        step = step * row_weights.astype(jnp.float32)[:, None]
    table_old = row_ops.gather(table, indices)
    new_rows = table_old - step
    if row_mask is not None:
        keep = row_mask[:, None]
        new_rows = jnp.where(keep, new_rows, table_old)
        t_rows = jnp.where(row_mask, t_rows, state.t[indices])
        if factored:
            r_rows = jnp.where(row_mask, r_rows, r_old)
        if moment.m_dtype == "fp32":
            m_rows = jnp.where(keep, m_rows, m_old)
        if not factored and moment.v_dtype == "fp32":
            v_rows = jnp.where(keep, v_rows, v_old)
    # same fusion-boundary discipline as the fp32 path: pin the update
    # tiles' producer graphs before any scatter flavor consumes them
    barrier_v = r_rows if factored else v_rows
    m_rows, barrier_v, new_rows = optimization_barrier(
        (m_rows, barrier_v, new_rows))

    if moment.m_dtype == "fp32":
        new_m = row_ops.scatter_set(state.m, indices, m_rows)
    else:
        new_m = encode_scatter_moment_rows(
            moment.m_dtype, state.m, indices, m_rows, m_raw, row_mask,
            noise_m, row_ops, fused)
    if factored:
        new_v = FactoredMoment(
            row=state.v.row.at[indices].set(barrier_v),
            col=c_new, col_t=col_t)
    elif moment.v_dtype == "fp32":
        new_v = row_ops.scatter_set(state.v, indices, barrier_v)
    else:
        new_v = encode_scatter_moment_rows(
            moment.v_dtype, state.v, indices, barrier_v, v_raw, row_mask,
            noise_v, row_ops, fused)

    return (
        row_ops.scatter_set(table, indices, new_rows),
        AdamState(m=new_m, v=new_v, t=state.t.at[indices].set(t_rows)),
    )
