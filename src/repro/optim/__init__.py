from repro.optim.adam import (
    AdamConfig, AdamState, adam_init, adam_update, adam_update_rows, sgd_update,
)

__all__ = [
    "AdamConfig", "AdamState", "adam_init", "adam_update", "adam_update_rows",
    "sgd_update",
]
