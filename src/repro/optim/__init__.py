from repro.optim.adam import (
    AdamConfig, AdamState, adam_init, adam_update, adam_update_rows,
    adam_update_rows_scattered, sgd_update,
)
from repro.optim.state_compress import (
    FactoredMoment, MomentCodecConfig, QuantMoment,
    adam_update_rows_compressed, moment_nbytes, state_nbytes,
)

__all__ = [
    "AdamConfig", "AdamState", "adam_init", "adam_update", "adam_update_rows",
    "adam_update_rows_scattered", "sgd_update",
    "FactoredMoment", "MomentCodecConfig", "QuantMoment",
    "adam_update_rows_compressed", "moment_nbytes", "state_nbytes",
]
