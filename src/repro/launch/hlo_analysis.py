"""Post-SPMD HLO analysis: collective-byte accounting for the roofline.

``compiled.cost_analysis()`` reports FLOPs and bytes-accessed but NOT
collective traffic, so we parse the compiled HLO text and sum the result
sizes of every collective op. Methodology (documented in EXPERIMENTS.md):

  * all-gather / reduce-scatter / all-to-all / collective-permute move
    ~result_bytes per participating device (ring schedules move
    size*(g-1)/g ~= size), so we count 1x result bytes.
  * all-reduce moves ~2x result bytes per device (reduce-scatter +
    all-gather phases of a ring all-reduce).

The returned dict maps op kind -> bytes, plus "total" and a per-op list.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# one HLO instruction result:  %name = TYPE[dims]{layout} op-name(...)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'f32[128,1024]{1,0}' or tuple '(f32[2], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# computation header: a column-0 line "%name (args...) -> ... {" (args may
# nest parens, so match only the name prefix)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")


def collective_bytes(hlo_text: str, while_trip: int = 1) -> Dict[str, int]:
    """Sum collective result bytes per op kind over a compiled HLO module.

    ``while_trip``: collectives inside while-loop *body* computations execute
    once per iteration, so they are weighted by the loop trip count (all
    whiles in our programs are layer scans with the same known trip count);
    top-level collectives — e.g. the stacked gradient all-reduce that the
    scan emits once, outside the loop — count once. Without this split a
    probe-based correction double-counts the gradient sync ~2x.
    """
    # split the module into computations; record collectives per computation
    per_comp: Dict[str, List[Tuple[str, int]]] = {}
    bodies: set = set()
    current = "__module__"
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            current = mc.group(1)
            continue
        for mb in _BODY_RE.finditer(line):
            bodies.add(mb.group(1))
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        nbytes = _shape_bytes(shape_str)
        # all-gather-start result tuple repeats (operand, result); count once
        if "(" in shape_str and kind in ("all-gather", "collective-permute"):
            nbytes //= 2
        weight = 2 if kind == "all-reduce" else 1
        per_comp.setdefault(current, []).append((kind, weight * nbytes))

    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    n_ops = 0
    in_body = 0
    for comp, ops in per_comp.items():
        mult = while_trip if comp in bodies else 1
        for kind, nbytes in ops:
            out[kind] += mult * nbytes
            n_ops += 1
            if mult > 1:
                in_body += 1
    out["total"] = sum(out[k] for k in COLLECTIVE_KINDS)
    out["num_ops"] = n_ops
    out["num_in_loop"] = in_body
    return out


def roofline_terms(
    flops: float,
    bytes_accessed: float,
    coll_bytes: float,
    num_chips: int,
    *,
    peak_flops: float = 197e12,      # TPU v5e bf16 per chip
    hbm_bw: float = 819e9,           # bytes/s per chip
    link_bw: float = 50e9,           # bytes/s per ICI link
) -> Dict[str, float]:
    """The three roofline terms (seconds) + dominant bottleneck.

    ``flops``/``bytes_accessed`` are whole-program (cost_analysis on the
    SPMD module is per-device already on recent jax; we treat them as
    per-device and divide only by 1 -- callers pass per-device numbers).
    ``coll_bytes`` is per-device collective traffic from the HLO.
    """
    compute_s = flops / peak_flops
    memory_s = bytes_accessed / hbm_bw
    collective_s = coll_bytes / link_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    terms["step_time_s"] = max(compute_s, memory_s, collective_s)
    return terms
