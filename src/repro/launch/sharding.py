"""Partition-spec assignment for every parameter / input / cache leaf.

Rules are by leaf *name* (the trailing path component) with trailing-dims
semantics: a rule gives the spec of the leaf's logical (unstacked) dims and
is left-padded with None to the actual rank — so the same rule covers both
plain blocks and scan-stacked (periods, ...) parameters.

Mapping (DESIGN.md §5):
  vocab tables          (V, d)      -> ("model", None)     vocab-parallel
  attention in-proj     (d, X)      -> (None, "model")     head-parallel
  attention out-proj    (X, d)      -> ("model", None)
  MLP up/gate           (d, ff)     -> (None, "model")
  MLP down              (ff, d)     -> ("model", None)
  MoE experts (E>=model axis size)  -> expert-parallel on E
  MoE experts (E < model axis size) -> shard the ff dim instead
  recurrent widths (r / d_inner)    -> "model" on the wide dim
  norms / biases / gates            -> replicated
Activations: global batch over ("pod","data"); long_500k (batch=1) shards
the KV-cache sequence dim over "data" instead (sequence parallelism).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes

# name -> trailing-dims spec (None entries padded on the left to leaf rank)
_IN_PROJ = ("wq", "wk", "wv", "w_up", "w_gate", "w_gate_branch", "w_in",
            "w_up_gate")
_OUT_PROJ = ("wo", "w_down", "w_out")
_REPLICATED = ("scale", "bias", "b", "b_if", "lam", "w_if", "router", "r")


def _rule_for(name: str, leaf, cfg: ModelConfig, model_axis: int,
              path_names) -> tuple:
    if name == "table":
        return ("model", None)
    moe = any(p == "moe" for p in path_names)
    if moe and name in ("w_gate", "w_up"):
        if cfg.num_experts >= model_axis:
            return ("model", None, None)
        return (None, None, "model")
    if moe and name == "w_down":
        if cfg.num_experts >= model_axis:
            return ("model", None, None)
        return (None, "model", None)
    if name in _IN_PROJ:
        return (None, "model")
    if name in _OUT_PROJ:
        return ("model", None)
    if name in ("w_a", "w_x"):       # rglru square recurrences
        return (None, "model")
    if name == "conv_w":
        return (None, "model")
    if name in _REPLICATED:
        return ()
    return ()                        # default: replicate


def _pad_spec(spec: tuple, rank: int) -> P:
    spec = tuple(spec)[-rank:] if len(spec) > rank else spec
    return P(*((None,) * (rank - len(spec)) + tuple(spec)))


def param_pspecs(cfg: ModelConfig, params_tree: Any) -> Any:
    """PartitionSpec tree matching an (eval_shape'd) params/opt-state tree."""
    mesh_model = 16  # model-axis size is 16 on both meshes

    def assign(path, leaf):
        names = []
        for entry in path:
            if hasattr(entry, "key"):
                names.append(str(entry.key))
            elif hasattr(entry, "name"):
                names.append(str(entry.name))
        name = names[-1] if names else ""
        rank = len(leaf.shape)
        if rank == 0:
            return P()
        rule = _rule_for(name, leaf, cfg, mesh_model, names)
        return _pad_spec(rule, rank)

    return jax.tree_util.tree_map_with_path(assign, params_tree)


def input_pspecs(cfg: ModelConfig, specs_tree: Any, mesh: Mesh,
                 seq_shard: bool = False,
                 kv_model_shard: bool = False) -> Any:
    """Specs for batch inputs / decode caches.

    seq_shard=True (long_500k, batch=1): KV-cache time dim goes over "data".
    kv_model_shard=True (§Perf decode): KV-cache time dim goes over "model"
    (batch stays on data); pairs with the distributed-LSE decode path.
    """
    baxes = batch_axes(mesh)
    bspec = P(baxes)

    def assign(path, leaf):
        names = []
        for entry in path:
            if hasattr(entry, "key"):
                names.append(str(entry.key))
            elif hasattr(entry, "name"):
                names.append(str(entry.name))
        name = names[-1] if names else ""
        rank = len(leaf.shape)
        if rank == 0:
            return P()
        # scan-stacked cache leaves carry a leading (periods,) axis
        stacked = "scanned" in names
        base = rank - (1 if stacked else 0)
        spec = [None] * rank

        def set_base(i_from_right: int, axis):
            spec[rank - 1 - i_from_right] = axis

        if name in ("tokens", "token", "prefix_embeds", "enc_embeds",
                    "enc_out"):
            if not seq_shard:
                spec[0] = baxes
            return P(*spec)
        if name in ("k", "v", "xk", "xv"):        # base (B, KVH, T, D)
            if kv_model_shard:
                set_base(1, "model")               # time over model (+LSE)
                set_base(3, baxes)
                return P(*spec)
            if seq_shard:
                set_base(1, "data")                # sequence parallelism
            else:
                set_base(3, baxes)
            if leaf.shape[rank - 3] % 16 == 0:     # KVH shardable (seamless)
                set_base(2, "model")
            return P(*spec)
        if name == "h" and base == 2:              # rglru state (B, r)
            set_base(0, "model")
            if not seq_shard:
                set_base(1, baxes)
            return P(*spec)
        if name == "conv" and base == 3:           # rglru conv (B, W-1, r)
            set_base(0, "model")
            if not seq_shard:
                set_base(2, baxes)
            return P(*spec)
        if name in ("c", "n", "h") and base >= 3:  # xlstm states (B,H,dh[,dh])
            set_base(0, "model")
            if not seq_shard:
                set_base(base - 1, baxes)
            return P(*spec)
        return P(*spec)                            # m, len, misc: replicate

    return jax.tree_util.tree_map_with_path(assign, specs_tree)


def to_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def fcf_state_pspecs(state: Any, axis: str = "data",
                     num_rows: Optional[int] = None) -> Any:
    """PartitionSpec tree for an FCF server-state pytree (sharded rounds).

    Rule: every rank-2 leaf whose leading dim is the item count M — the
    global model Q, the per-row Adam moments, the BTS reward buffers
    (v / prev_grad) and the topk codec's error-feedback residual — is
    row-sharded ``P(axis, None)``; everything else (the (M,) posterior /
    count / timestep vectors, PRNG key, scalar counters) is replicated.
    The (M,) vectors stay replicated on purpose: selection is a full-table
    top_k over them every round, and at 4 bytes/row they are ~K*4 times
    cheaper than the tables that do get sharded.

    ``num_rows`` defaults to ``state.q.shape[0]`` (a
    :class:`repro.cf.server.ServerState`); pass it explicitly for other
    state pytrees.
    """
    if num_rows is None:
        num_rows = state.q.shape[0]

    def spec(leaf):
        shape = getattr(leaf, "shape", ())
        if len(shape) == 2 and shape[0] == num_rows:
            return P(axis, None)
        return P()

    return jax.tree.map(spec, state)


def zero_shard_moments(cfg: ModelConfig, pspec_tree: Any,
                       shape_tree: Any, axis: str = "data") -> Any:
    """ZeRO-1-style optimizer-state sharding (beyond-paper §Perf lever):
    shard each Adam-moment leaf over ``axis`` on its first still-
    unsharded dim whose size divides the axis — XLA then reduce-scatters
    the gradients into the moment sharding and all-gathers the updated
    params, cutting per-chip f32 moment memory by the axis size."""
    import numpy as _np

    def upgrade(spec, leaf):
        if not isinstance(spec, P):
            return spec
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (p, dim) in enumerate(zip(parts, leaf.shape)):
            if p is None and dim % 16 == 0:
                parts[i] = axis
                return P(*parts)
        return spec

    return jax.tree.map(upgrade, pspec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))
