"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Mesh axes over which the global batch is sharded."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_axis_size(mesh: jax.sharding.Mesh) -> int:
    size = 1
    for ax in batch_axes(mesh):
        size *= mesh.shape[ax]
    return size
