"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

:func:`make_data_mesh` builds the 1-D ("data",) mesh the sharded FCF round
engine runs on; :func:`fake_cpu_devices_env` prepares the environment for a
subprocess that should see N fake CPU devices (the only way to get a
multi-device CPU mesh — ``XLA_FLAGS`` must be set before the first jax
init, so tests and benchmarks spawn workers rather than re-init in place).
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_data_mesh(num_shards: Optional[int] = None) -> jax.sharding.Mesh:
    """1-D ("data",) mesh over the first ``num_shards`` local devices.

    The mesh of the sharded FCF round engine: (M, K) tables row-shard over
    "data", cohorts split one user block per device. ``None`` takes every
    visible device.
    """
    devices = jax.devices()
    d = len(devices) if num_shards is None else int(num_shards)
    if d < 1 or d > len(devices):
        raise ValueError(
            f"requested {num_shards} mesh devices, have {len(devices)}")
    return jax.sharding.Mesh(np.array(devices[:d]), ("data",))


_FAKE_CPU_FLAG = "--xla_force_host_platform_device_count"


def fake_cpu_devices_env(num_devices: int,
                         env: Optional[Dict[str, str]] = None
                         ) -> Dict[str, str]:
    """Environment for a subprocess that sees ``num_devices`` fake CPU devices.

    Appends ``--xla_force_host_platform_device_count=N`` to ``XLA_FLAGS``
    (dropping any previous setting of that flag). The flag only takes effect
    before the first jax initialization, hence the subprocess pattern used by
    ``tests/test_sharded_rounds.py`` and ``benchmarks/sharded_rounds.py``.
    """
    env = dict(os.environ if env is None else env)
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if not f.startswith(_FAKE_CPU_FLAG)]
    kept.append(f"{_FAKE_CPU_FLAG}={int(num_devices)}")
    env["XLA_FLAGS"] = " ".join(kept)
    return env


def batch_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Mesh axes over which the global batch is sharded."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_axis_size(mesh: jax.sharding.Mesh) -> int:
    size = 1
    for ax in batch_axes(mesh):
        size *= mesh.shape[ax]
    return size
