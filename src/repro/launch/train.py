"""End-to-end training driver.

Two modes, matching the paper's setting and its LLM generalization:

  centralized  — plain Adam LM training of any --arch (reduced config on
                 CPU by default; full config under the production mesh on
                 real hardware). The ~100M-model-for-N-steps deliverable.
  federated    — the paper's technique at the LLM layer: FL rounds with
                 bandit-selected vocab-row payloads (federated/llm.py).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
      --reduced --steps 300 --log-every 20
  PYTHONPATH=src python -m repro.launch.train --mode federated \
      --arch qwen3-4b --reduced --rounds 20 --strategy bts
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.configs.registry import get_config, list_archs
from repro.data.tokens import TokenDataConfig, synthetic_token_batches
from repro.federated.llm import FedLLMConfig, run_federated_llm
from repro.models import lm
from repro.utils.logging import get_logger

log = get_logger("repro.train")


def _reduced_100m(cfg):
    """~100M-parameter member of the same family (end-to-end deliverable)."""
    pattern = cfg.block_pattern
    layers = max(8, len(pattern))
    layers = (layers // len(pattern)) * len(pattern) or len(pattern)
    return dataclasses.replace(
        cfg.reduced(num_layers=layers, d_model=768, vocab=32768,
                    num_experts=min(cfg.num_experts, 4) or 0),
        dtype="float32")


def train_centralized(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = _reduced_100m(cfg)
    log.info("arch=%s params=%.1fM layers=%d d_model=%d vocab=%d",
             cfg.name, cfg.param_count() / 1e6, cfg.num_layers, cfg.d_model,
             cfg.vocab_size)

    key = jax.random.PRNGKey(args.seed)
    state = lm.init_train_state(cfg, key)
    if args.ckpt_dir:
        found = latest_checkpoint(args.ckpt_dir)
        if found:
            step0, path = found
            state = load_checkpoint(path, like=state)
            log.info("resumed from %s (step %d)", path, step0)

    step_fn = jax.jit(lambda s, b: lm.train_step(s, b, cfg, lr=args.lr))
    data = synthetic_token_batches(TokenDataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        batch_size=args.batch_size, seed=args.seed))

    losses, t0 = [], time.time()
    first_loss = None
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        if cfg.modality == "vision":
            batch["prefix_embeds"] = 0.02 * jax.random.normal(
                jax.random.PRNGKey(step), (args.batch_size, cfg.frontend_seq,
                                           cfg.d_model), jnp.float32)
        if cfg.is_enc_dec:
            batch["enc_embeds"] = 0.02 * jax.random.normal(
                jax.random.PRNGKey(step), (args.batch_size, cfg.frontend_seq,
                                           cfg.d_model), jnp.float32)
        state, loss = step_fn(state, batch)
        losses.append(float(loss))
        if first_loss is None:
            first_loss = float(loss)
        if step % args.log_every == 0:
            dt = time.time() - t0
            tok_s = args.log_every * args.batch_size * args.seq_len / dt
            log.info("step %5d  loss %.4f  (%.0f tok/s)", step,
                     np.mean(losses[-args.log_every:]), tok_s)
            t0 = time.time()
        if args.ckpt_dir and step % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step, state)

    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state)
    summary = {
        "arch": cfg.name, "params": cfg.param_count(),
        "steps": args.steps, "first_loss": first_loss,
        "final_loss": float(np.mean(losses[-10:])),
        "loss_dropped": float(np.mean(losses[-10:])) < first_loss,
    }
    log.info("done: %s", json.dumps(summary))
    return summary


def train_federated(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = _reduced_100m(cfg)
    fed = FedLLMConfig(
        strategy=args.strategy, keep_fraction=args.keep_fraction,
        rounds=args.rounds, num_clients=args.clients,
        clients_per_round=args.cohort, local_steps=args.local_steps,
        seq_len=args.seq_len, batch_size=args.batch_size, seed=args.seed)
    out = run_federated_llm(cfg, fed, csv_path=args.csv)
    log.info("federated done: eval %.4f -> %.4f, item-payload reduction %.1f%%",
             out["first_eval_loss"], out["final_eval_loss"],
             out["item_payload_reduction_pct"])
    return {k: v for k, v in out.items() if k != "history"
            and not hasattr(v, "shape")}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("centralized", "federated"),
                    default="centralized")
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="~100M family member (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    # federated
    ap.add_argument("--strategy", default="bts",
                    choices=("bts", "random", "full", "magnitude"))
    ap.add_argument("--keep-fraction", type=float, default=0.1)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()

    if args.mode == "federated":
        train_federated(args)
    else:
        train_centralized(args)


if __name__ == "__main__":
    main()
