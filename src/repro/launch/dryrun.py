import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) combination this lowers the
appropriate step (train_step / prefill_step / decode_step) with explicit
in/out shardings on the production mesh, compiles it, and records

  * memory_analysis()  -- proves the per-device working set fits,
  * cost_analysis()    -- HLO FLOPs / bytes for the roofline,
  * collective traffic -- parsed from the compiled HLO (hlo_analysis).

The two XLA_FLAGS lines above MUST stay the first statements in the file:
jax locks the device count on first init, and only the dry-run may see 512
placeholder devices (tests/benches see the single real CPU device).

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all                    # 10x4 single-pod
  python -m repro.launch.dryrun --all --multi-pod        # 2x16x16 sweep
"""
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config, list_archs
from repro.configs.shapes import INPUT_SHAPES, InputShape
from repro.launch.hlo_analysis import collective_bytes, roofline_terms
from repro.launch.mesh import batch_axes, data_axis_size, make_production_mesh
from repro.launch.sharding import input_pspecs, param_pspecs, to_shardings
from repro.models import lm

_KEY_SPEC = jax.ShapeDtypeStruct((2,), jnp.uint32)


def skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """DESIGN.md section 4 skip rules (documented, not silent)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention blocks are quadratic at 524k context; "
                "long_500k is assigned only to sub-quadratic archs")
    return None


# --------------------------------------------------------------------- #
# step builders: (fn, arg_shapes, in_specs, out_specs)
# --------------------------------------------------------------------- #
def _kv_model_shard(shape: InputShape) -> bool:
    return (os.environ.get("REPRO_KV_MODEL_SHARD", "0") == "1"
            and shape.kind == "decode")


def build_lowerable(cfg: ModelConfig, shape: InputShape, mesh) -> Tuple:
    baxes = batch_axes(mesh)
    kv_ms = _kv_model_shard(shape)
    seq_shard = (not kv_ms and shape.kind == "decode"
                 and shape.global_batch % data_axis_size(mesh) != 0)
    specs = lm.input_specs(cfg, shape)
    in_batch_specs = input_pspecs(cfg, specs, mesh, seq_shard=seq_shard,
                                  kv_model_shard=kv_ms)

    if shape.kind == "train":
        state_shape = jax.eval_shape(
            lambda k: lm.init_train_state(cfg, k), _KEY_SPEC)
        m_specs = param_pspecs(cfg, state_shape.m)
        if os.environ.get("REPRO_ZERO", "0") == "1":
            from repro.launch.sharding import zero_shard_moments
            m_specs = zero_shard_moments(cfg, m_specs, state_shape.m)
        state_specs = lm.TrainState(
            params=param_pspecs(cfg, state_shape.params),
            m=m_specs, v=m_specs,
            step=P())

        def step(state, batch):
            return lm.train_step(state, batch, cfg)

        return (step, (state_shape, specs),
                (state_specs, in_batch_specs), (state_specs, P()))

    params_shape = jax.eval_shape(
        lambda k: lm.init_lm_params(cfg, k), _KEY_SPEC)
    pspecs = param_pspecs(cfg, params_shape)
    logit_spec = P(None if seq_shard else baxes, "model")

    if shape.kind == "prefill":
        def step(params, inputs):
            return lm.prefill_step(
                params, cfg, inputs["tokens"],
                prefix_embeds=inputs.get("prefix_embeds"),
                enc_embeds=inputs.get("enc_embeds"))

        out_shape = jax.eval_shape(step, params_shape, specs)
        cache_specs = input_pspecs(cfg, out_shape[1], mesh,
                                   seq_shard=seq_shard)
        return (step, (params_shape, specs),
                (pspecs, in_batch_specs), (logit_spec, cache_specs))

    if shape.kind == "decode":
        def step(params, inputs):
            return lm.decode_step(
                params, cfg, inputs["cache"], inputs["token"], inputs["pos"],
                enc_out=inputs.get("enc_out"))

        out_shape = jax.eval_shape(step, params_shape, specs)
        cache_specs = input_pspecs(cfg, out_shape[1], mesh,
                                   seq_shard=seq_shard, kv_model_shard=kv_ms)
        return (step, (params_shape, specs),
                (pspecs, in_batch_specs), (logit_spec, cache_specs))

    raise ValueError(shape.kind)


def payload_builder(keep_fraction: float = 0.10, shard_rows: bool = True):
    """Builder for the paper-technique train step: vocab-table gradients
    restricted to the bandit-selected 10% of rows (lm.payload_train_step).
    ``shard_rows`` shards the (M_s, d) row block over the model axis —
    the §Perf lever that makes the row collective 16x smaller."""
    def build(cfg: ModelConfig, shape: InputShape, mesh):
        assert shape.kind == "train", "payload step applies to training"
        baxes = batch_axes(mesh)
        specs = lm.input_specs(cfg, shape)
        in_batch_specs = input_pspecs(cfg, specs, mesh)
        state_shape = jax.eval_shape(
            lambda k: lm.init_train_state(cfg, k), _KEY_SPEC)
        state_specs = lm.TrainState(
            params=param_pspecs(cfg, state_shape.params),
            m=param_pspecs(cfg, state_shape.m),
            v=param_pspecs(cfg, state_shape.v),
            step=P())
        m_s = max(16, int(keep_fraction * cfg.padded_vocab) // 16 * 16)
        sel = jax.ShapeDtypeStruct((m_s,), jnp.int32)
        row_spec = P("model", None) if shard_rows else P(None, None)

        def step(state, batch, selected):
            return lm.payload_train_step(state, batch, selected, cfg,
                                         row_spec=row_spec)

        return (step, (state_shape, specs, sel),
                (state_specs, in_batch_specs, P()),
                (state_specs, P(), row_spec))
    return build


# --------------------------------------------------------------------- #
# while-body cost correction
#
# XLA's HloCostAnalysis visits each while body ONCE — it does not multiply
# by trip count — so a scanned P-period model under-reports everything that
# lives inside the layer loop by ~P×. We correct exactly with two shallow
# UNROLLED probes of the same config: U1 (1 period) and U2 (2 periods) give
# per-period cost B = U2 − U1 and loop-free overhead O = U1 − B; the
# corrected full-model cost is  S_full + (P − 1)·B  (S_full already counts
# the body once plus all out-of-loop work including remainder layers).
# Valid because every while in our programs is a layer scan with the same
# trip count P (encoder and decoder periods are equal for the enc-dec arch).
# --------------------------------------------------------------------- #
def _lower_compile(cfg, shape, mesh, builder):
    from repro.utils import hints
    step, arg_shapes, in_specs, out_specs = builder(cfg, shape, mesh)
    with mesh, hints.batch_axes(batch_axes(mesh), mesh=mesh,
                                kv_time_shard=_kv_model_shard(shape)):
        jitted = jax.jit(step,
                         in_shardings=to_shardings(mesh, in_specs),
                         out_shardings=to_shardings(mesh, out_specs))
        lowered = jitted.lower(*arg_shapes)
        compiled = lowered.compile()
    return compiled


def _probe_cfg(cfg: ModelConfig, n_periods: int) -> ModelConfig:
    reps = {"num_layers": n_periods * len(cfg.block_pattern)}
    if cfg.is_enc_dec:
        reps["encoder_layers"] = n_periods
    return dataclasses.replace(cfg, **reps)


def _extract_costs(compiled) -> Dict[str, float]:
    cost = _cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll": float(coll["total"])}


def corrected_costs(cfg: ModelConfig, shape: InputShape, mesh,
                    builder, scanned: Dict[str, float]) -> Dict[str, float]:
    """Trip-count-corrected {flops, bytes} for the full model. Collective
    bytes are NOT probe-corrected — they use the structured while-body
    accounting in hlo_analysis (probes would double-count the once-per-step
    stacked gradient sync, which unrolled probes emit per layer)."""
    periods = cfg.num_layers // len(cfg.block_pattern)
    out = dict(scanned)
    if periods <= 1:
        return out
    os.environ["REPRO_SCAN_UNROLL"] = "1"
    try:
        u1 = _extract_costs(_lower_compile(_probe_cfg(cfg, 1), shape, mesh,
                                           builder))
        u2 = _extract_costs(_lower_compile(_probe_cfg(cfg, 2), shape, mesh,
                                           builder))
    finally:
        os.environ["REPRO_SCAN_UNROLL"] = "0"
    for k in ("flops", "bytes"):
        body = max(u2[k] - u1[k], 0.0)
        out[k] = scanned[k] + (periods - 1) * body
        out[f"probe_body_{k}"] = body
    return out


def _cost_dict(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


def _memory_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(ma, attr):
            out[attr] = float(getattr(ma, attr))
    return out


def run_pair(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: Optional[str] = None,
             step_override=None, tag: str = "") -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh); return the roofline record."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_tag, "kind": shape.kind}

    reason = skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["skip_reason"] = reason
        _save(rec, out_dir, tag)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    num_chips = mesh.size
    builder = step_override or build_lowerable
    step, arg_shapes, in_specs, out_specs = builder(cfg, shape, mesh)

    from repro.utils import hints
    t0 = time.time()
    with mesh, hints.batch_axes(batch_axes(mesh), mesh=mesh,
                                kv_time_shard=_kv_model_shard(shape)):
        jitted = jax.jit(
            step,
            in_shardings=to_shardings(mesh, in_specs),
            out_shardings=to_shardings(mesh, out_specs))
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = _cost_dict(compiled)
    memory = _memory_dict(compiled)
    periods = cfg.num_layers // len(cfg.block_pattern)
    coll = collective_bytes(compiled.as_text(), while_trip=periods)

    scanned = {"flops": cost.get("flops", 0.0),
               "bytes": cost.get("bytes accessed", 0.0),
               "coll": float(coll["total"])}
    corrected = corrected_costs(cfg, shape, mesh, builder, scanned)
    terms = roofline_terms(corrected["flops"], corrected["bytes"],
                           corrected["coll"], num_chips)

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = shape.tokens
    model_flops = 6.0 * n_active * tokens if shape.kind == "train" else (
        2.0 * n_active * tokens if shape.kind == "prefill"
        else 2.0 * n_active * shape.global_batch)
    rec.update({
        "status": "ok",
        "num_chips": num_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost_analysis": cost,
        "memory_analysis": memory,
        "collectives": coll,
        "scanned_costs": scanned,
        "corrected_costs": corrected,
        "roofline": terms,
        "params": n_params,
        "active_params": n_active,
        "model_flops_global": model_flops,
        "model_flops_per_chip": model_flops / num_chips,
        "useful_flops_ratio": (model_flops / num_chips) / corrected["flops"]
        if corrected["flops"] else None,
    })
    _save(rec, out_dir, tag)
    return rec


def _save(rec: Dict, out_dir: Optional[str], tag: str = "") -> None:
    if not out_dir:
        return
    d = os.path.join(out_dir, rec["mesh"])
    os.makedirs(d, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(d, f"{rec['arch']}__{rec['shape']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def _fmt(rec: Dict) -> str:
    if rec["status"] != "ok":
        return (f"{rec['arch']:<24} {rec['shape']:<12} {rec['mesh']:<11} "
                f"SKIP ({rec['skip_reason'][:60]}...)")
    r = rec["roofline"]
    return (f"{rec['arch']:<24} {rec['shape']:<12} {rec['mesh']:<11} "
            f"compile={rec['compile_s']:>6.1f}s "
            f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
            f"coll={r['collective_s']:.3e}s -> {r['bottleneck']}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--verbose", action="store_true",
                    help="print full memory/cost analysis per pair")
    ap.add_argument("--payload", action="store_true",
                    help="lower the payload-selected train step (10%% rows)")
    ap.add_argument("--payload-replicated-rows", action="store_true",
                    help="ablation: keep the selected-row block replicated")
    args = ap.parse_args()

    if args.all:
        pairs = [(a, s) for a in list_archs() for s in INPUT_SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        pairs = [(args.arch, args.shape)]

    override, tag = None, ""
    if args.payload:
        override = payload_builder(
            shard_rows=not args.payload_replicated_rows)
        tag = ("payload_repl" if args.payload_replicated_rows else "payload")

    failures = []
    for arch, shape in pairs:
        try:
            rec = run_pair(arch, shape, multi_pod=args.multi_pod,
                           out_dir=args.out_dir,
                           step_override=override, tag=tag)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
            print(f"{arch:<24} {shape:<12} FAILED: {e}")
            continue
        print(_fmt(rec), flush=True)
        if args.verbose and rec["status"] == "ok":
            print("  memory_analysis:", rec["memory_analysis"])
            print("  cost_analysis:",
                  {k: v for k, v in rec["cost_analysis"].items()
                   if k in ("flops", "bytes accessed")})
            print("  collectives:", rec["collectives"])

    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nALL PAIRS LOWERED + COMPILED OK")


if __name__ == "__main__":
    main()
