"""FRS serving driver: train briefly, publish encoded snapshots into a
:class:`repro.serve.ServingEngine`, then serve batched recommendation
requests straight off the compressed model.

The full deployment loop of the paper's system in one command: the async
round engine publishes its encoded Q* ring entries at every eval boundary
(``FLSimConfig.snapshot_hook``), the engine installs them into the
wire-resident serving model WITHOUT a fp32 round-trip, and a request
stream of per-user factor vectors is scored through the fused
dequant->score->top-N kernel (:mod:`repro.kernels.payload_score`).

  PYTHONPATH=src python -m repro.launch.serve_recs --codec int8 \
      --rounds 60 --requests 200 --batch 32

See also :mod:`repro.launch.serve` for the LLM decode serving driver.
"""
from __future__ import annotations

import argparse
import os
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import CodecConfig
from repro.data.synthetic import load_dataset
from repro.federated.simulation import FLSimConfig, run_fcf_simulation
from repro.obs import JsonlSink, LatencyHistogram, ObsConfig
from repro.obs.trace import install_tracer
from repro.serve import ServingEngine, ServingModel
from repro.utils.logging import get_logger

log = get_logger("repro.serve_recs")


def _build_obs(args) -> Optional[ObsConfig]:
    """An enabled ObsConfig when observability is asked for, else None.

    ``--obs-out DIR`` turns the full stream on: round telemetry to
    ``DIR/telemetry.jsonl``, host spans to ``DIR/trace.jsonl``, and a final
    ``DIR/metrics.prom`` scrape — the exact artifact set
    ``python -m repro.obs.check DIR`` validates. ``--metrics-port`` alone
    still enables in-loop telemetry (in-memory sink) so the live endpoint
    has latency histograms to serve.
    """
    if args.obs_out is None and args.metrics_port < 0:
        return None
    if args.obs_out is None:
        return ObsConfig(enabled=True, telemetry_every=args.telemetry_every)
    os.makedirs(args.obs_out, exist_ok=True)
    return ObsConfig(
        enabled=True,
        telemetry_every=args.telemetry_every,
        sink=JsonlSink(os.path.join(args.obs_out, "telemetry.jsonl")),
        trace_path=os.path.join(args.obs_out, "trace.jsonl"),
    )


def serve_recs(args) -> dict:
    spec, train, test = load_dataset(args.dataset, seed=args.seed)
    m = train.shape[1]
    k = args.factors
    obs = _build_obs(args)

    # cold engine around an all-zero wire model; training will publish into
    # it (the first published snapshot is the first real serving model)
    engine = ServingEngine(
        ServingModel.from_dense(CodecConfig(name=args.codec),
                                jnp.zeros((m, k), jnp.float32)),
        buckets=tuple(args.buckets), top_n=args.top_n,
        block_m=args.block_m, obs=obs)

    cfg = FLSimConfig(
        strategy="bts", rounds=args.rounds, theta=args.theta,
        num_factors=k, codec=args.codec, backend="async",
        max_staleness=args.max_staleness, eval_every=args.eval_every,
        eval_users=min(128, train.shape[0]), seed=args.seed,
        snapshot_hook=engine.publisher(), obs=obs)
    prev_tracer = None
    tracer_installed = False
    if obs is not None and obs.resolve_tracer() is not None:
        # keep the tracer installed past training so the serving phase's
        # serve_batch / publish spans land in the same trace.jsonl
        prev_tracer = install_tracer(obs.resolve_tracer())
        tracer_installed = True
    t0 = time.time()
    result = run_fcf_simulation(train, test, cfg)
    t_train = time.time() - t0
    log.info("trained %d rounds in %.2fs (F1@10 %.4f), published %d "
             "snapshots, serving model: %s wire, %d bytes resident",
             result.rounds, t_train, result.final["f1"],
             engine.stats().installs, engine.model.cfg.name,
             engine.model.resident_bytes())

    # request stream: solve eval users' factors once (the client-side step),
    # then serve them in random batches against the live engine
    from repro.cf.local import solve_user_factors

    q_dense = jnp.asarray(result.server_state.q)
    rng = np.random.default_rng(args.seed + 7)
    users = rng.choice(train.shape[0],
                       size=min(256, train.shape[0]), replace=False)
    p_all = solve_user_factors(q_dense, jnp.asarray(train[users]))
    mask_all = jnp.asarray(train[users])

    lat: List[float] = []
    for r in range(args.requests):
        ids = rng.integers(0, p_all.shape[0], size=args.batch)
        pb = p_all[ids]
        mb = mask_all[ids] if args.mask_train else None
        t0 = time.time()
        vals, idx = engine.recommend(pb, train_mask=mb)
        jax.block_until_ready(idx)
        lat.append(time.time() - t0)
    lat_arr = np.asarray(lat[1:]) if len(lat) > 1 else np.asarray(lat)
    users_per_s = args.batch * len(lat_arr) / max(lat_arr.sum(), 1e-9)
    stats = engine.stats()
    # one quantile definition repo-wide (obs.hist): this summary, the
    # engine's /metrics histograms and benchmarks/serving.py all read
    # p50/p99 off the same geometric bucketing
    req_hist = LatencyHistogram.from_values(lat_arr)
    summary = {
        "dataset": spec.name, "codec": args.codec, "batch": args.batch,
        "requests": stats.requests, "users_served": stats.users,
        "model_version": stats.version,
        "resident_bytes": engine.model.resident_bytes(),
        "users_per_sec": float(users_per_s),
        "p50_ms": req_hist.quantile(0.50) * 1e3,
        "p99_ms": req_hist.quantile(0.99) * 1e3,
        "f1_at_10": result.final["f1"],
    }
    log.info("served %d requests x %d users: %.0f users/s, "
             "p50 %.2f ms, p99 %.2f ms",
             stats.requests, args.batch, summary["users_per_sec"],
             summary["p50_ms"], summary["p99_ms"])

    server = None
    try:
        if args.metrics_port >= 0:
            from repro.obs.httpd import start_metrics_server
            server, url = start_metrics_server(engine.metrics,
                                               port=args.metrics_port)
            summary["metrics_url"] = url
            import urllib.request
            with urllib.request.urlopen(url, timeout=10) as resp:
                scraped = resp.read().decode("utf-8")
            log.info("metrics endpoint live at %s (%d bytes/scrape)",
                     url, len(scraped))
            if not args.serve_forever:
                pass    # CI mode: scrape once to prove liveness, then stop
            else:
                log.info("serving /metrics until interrupted (ctrl-c)")
                try:
                    while True:
                        time.sleep(3600)
                except KeyboardInterrupt:
                    pass
        if args.obs_out is not None:
            prom_path = os.path.join(args.obs_out, "metrics.prom")
            with open(prom_path, "w") as f:
                f.write(engine.metrics())
            summary["obs_out"] = args.obs_out
            log.info("observability artifacts in %s "
                     "(telemetry.jsonl, trace.jsonl, metrics.prom)",
                     args.obs_out)
    finally:
        if server is not None:
            server.shutdown()
        if tracer_installed:
            install_tracer(prev_tracer)
        if obs is not None:
            obs.close()
    return summary


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="movielens-mini")
    ap.add_argument("--codec", default="int8",
                    choices=("fp32", "fp16", "int8", "int4"))
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--theta", type=int, default=50)
    ap.add_argument("--factors", type=int, default=25)
    ap.add_argument("--max-staleness", type=int, default=2)
    ap.add_argument("--eval-every", type=int, default=20)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--top-n", type=int, default=10)
    ap.add_argument("--block-m", type=int, default=1024)
    ap.add_argument("--buckets", type=int, nargs="+", default=[8, 64, 256])
    ap.add_argument("--mask-train", action="store_true",
                    help="exclude each user's train interactions")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs-out", default=None, metavar="DIR",
                    help="enable observability and write telemetry.jsonl / "
                         "trace.jsonl / metrics.prom into DIR (validate "
                         "with: python -m repro.obs.check DIR)")
    ap.add_argument("--telemetry-every", type=int, default=1,
                    help="emit a round-telemetry event every N rounds")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="serve Prometheus /metrics on this port "
                         "(0 = ephemeral, -1 = off)")
    ap.add_argument("--serve-forever", action="store_true",
                    help="with --metrics-port: keep the endpoint up until "
                         "interrupted instead of one liveness scrape")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny smoke config (seconds, CI-sized)")
    return ap


def main(argv: Optional[List[str]] = None) -> dict:
    args = build_parser().parse_args(argv)
    if args.dry_run:
        args.rounds, args.eval_every = 6, 3
        args.requests, args.batch = 4, 4
        args.buckets, args.block_m = [4], 128
    out = serve_recs(args)
    print(f"serve_recs: {out['users_per_sec']:.0f} users/s "
          f"(p50 {out['p50_ms']:.2f} ms, p99 {out['p99_ms']:.2f} ms) "
          f"on a {out['codec']} wire model, "
          f"{out['resident_bytes']} bytes resident, "
          f"model v{out['model_version']}")
    return out


if __name__ == "__main__":
    main()
