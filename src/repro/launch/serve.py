"""Batched serving driver: prefill a batch of prompts, then step the decode
loop token by token against the KV cache — the serve_step the decode input
shapes lower in the dry-run, runnable end-to-end on CPU at reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, list_archs
from repro.models import lm
from repro.utils.logging import get_logger

log = get_logger("repro.serve")


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_lm_params(cfg, key)

    prompts = jax.random.randint(
        jax.random.PRNGKey(args.seed + 1), (args.batch, args.prompt_len),
        0, cfg.vocab_size, jnp.int32)
    enc = None
    kwargs = {}
    if cfg.is_enc_dec:
        kwargs["enc_embeds"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.frontend_seq, cfg.d_model), jnp.float32)
        enc = lm.encode(params, cfg, kwargs["enc_embeds"])
    if cfg.modality == "vision":
        kwargs["prefix_embeds"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.frontend_seq, cfg.d_model), jnp.float32)

    max_len = args.prompt_len + args.gen + cfg.frontend_seq + 8
    prefill = jax.jit(lambda p, t: lm.prefill_step(p, cfg, t, **kwargs))
    decode = jax.jit(lambda p, c, tok, pos: lm.decode_step(
        p, cfg, c, tok, pos, enc_out=enc))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    cache = _grow_cache(cfg, cache, args.batch, max_len)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    pos = args.prompt_len + (cfg.frontend_seq if cfg.modality == "vision" else 0)
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.asarray(pos + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    t_decode = time.time() - t0

    gen = np.concatenate(out, axis=1)
    assert gen.shape == (args.batch, args.gen)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()
    tok_s = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    log.info("prefill %.2fs | decode %d toks x %d seqs in %.2fs (%.1f tok/s)",
             t_prefill, args.gen, args.batch, t_decode, tok_s)
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "decode_tok_per_s": tok_s, "generated": gen}


def _grow_cache(cfg, cache, batch: int, max_len: int):
    """Right-pad the prefill KV cache out to max_len decode capacity."""
    def grow(path, leaf):
        name = ""
        for e in path:
            if hasattr(e, "key"):
                name = str(e.key)
        if name in ("k", "v") and leaf.ndim >= 4:
            t_axis = leaf.ndim - 2
            pad = max_len - leaf.shape[t_axis]
            if pad > 0:
                widths = [(0, 0)] * leaf.ndim
                widths[t_axis] = (0, pad)
                return jnp.pad(leaf, widths)
        return leaf

    return jax.tree_util.tree_map_with_path(grow, cache)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve(args)


if __name__ == "__main__":
    main()
