"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar
memory with recurrent mixing), both with exponential gating + stabilizers.

TPU adaptation notes (DESIGN.md §3): the xLSTM reference implementation uses
fused CUDA kernels for the recurrences. Here both blocks lower to
``jax.lax.scan`` over time — a single compiled loop body (HLO stays
layer-count independent), with the matrix-memory update expressed as MXU
outer products. The mLSTM's sequential scan is exact; a chunkwise-parallel
formulation is a known optimization (see EXPERIMENTS.md §Perf) but the
recurrent form is the correctness oracle. Decode is the natural O(1) step.

Shapes: mLSTM state C (B, H, dh, dh), n (B, H, dh), m (B, H).
        sLSTM state c/n/h (B, H, dh), m (B, H, dh).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _he, init_rmsnorm, rmsnorm


# --------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------- #


def _ffn_dim(d_model: int) -> int:
    """sLSTM post-up/down FFN width: the paper's 4/3*d, rounded up to a
    multiple of 256 for MXU alignment and 16-way model-parallel sharding."""
    raw = 4 * d_model / 3
    return int(-(-raw // 256) * 256)


def init_mlstm_block(key, d_model: int, num_heads: int, proj_factor: float = 2.0,
                     dtype=jnp.float32):
    d_inner = int(proj_factor * d_model)
    dh = d_inner // num_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": _he(ks[0], (d_model, d_inner), dtype, fan_in=d_model),
        "w_gate": _he(ks[1], (d_model, d_inner), dtype, fan_in=d_model),
        "wq": _he(ks[2], (d_inner, d_inner), dtype, fan_in=d_inner),
        "wk": _he(ks[3], (d_inner, d_inner), dtype, fan_in=d_inner),
        "wv": _he(ks[4], (d_inner, d_inner), dtype, fan_in=d_inner),
        "w_if": _he(ks[5], (d_inner, 2 * num_heads), jnp.float32, fan_in=d_inner),
        "b_if": jnp.concatenate([jnp.zeros((num_heads,)),
                                 jnp.linspace(3.0, 6.0, num_heads)]).astype(jnp.float32),
        "out_norm": init_rmsnorm(dh, dtype),
        "w_down": _he(ks[6], (d_inner, d_model), dtype, fan_in=d_inner),
    }


def _mlstm_step(state, inputs):
    """One recurrence step. state: (C, n, m); inputs per-step tensors."""
    c_prev, n_prev, m_prev = state
    q, k, v, i_log, f_log = inputs          # q/k/v: (B,H,dh); gates: (B,H)
    m_new = jnp.maximum(f_log + m_prev, i_log)
    i_g = jnp.exp(i_log - m_new)                      # (B,H)
    f_g = jnp.exp(f_log + m_prev - m_new)
    c_new = (f_g[..., None, None] * c_prev
             + i_g[..., None, None] * (v[..., :, None] * k[..., None, :]))
    n_new = f_g[..., None] * n_prev + i_g[..., None] * k
    h_num = jnp.einsum("bhij,bhj->bhi", c_new, q)     # (B,H,dh)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, q)),
                        jnp.exp(-m_new))
    h = h_num / h_den[..., None]
    return (c_new, n_new, m_new), h


def mlstm_block(
    params, x: jax.Array, *, num_heads: int,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    b, s, d_model = x.shape
    u = x @ params["w_up"]                            # (B,S,Di)
    gate = jax.nn.silu(x @ params["w_gate"])
    d_inner = u.shape[-1]
    dh = d_inner // num_heads

    def heads(t):
        return t.reshape(b, s, num_heads, dh).transpose(0, 2, 1, 3)

    q = heads(u @ params["wq"]) / (dh ** 0.5)
    k = heads(u @ params["wk"]) / (dh ** 0.5)
    v = heads(u @ params["wv"])
    if_log = u.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    i_log = if_log[..., :num_heads].transpose(0, 2, 1)          # (B,H,S)
    f_log = jax.nn.log_sigmoid(if_log[..., num_heads:]).transpose(0, 2, 1)

    if cache is None:
        c0 = jnp.zeros((b, num_heads, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, num_heads, dh), jnp.float32)
        m0 = jnp.full((b, num_heads), -1e30, jnp.float32)
    else:
        c0, n0, m0 = cache["c"], cache["n"], cache["m"]

    # scan over time (axis 2 for q/k/v heads layout, axis 2 for gates)
    xs = (
        q.transpose(2, 0, 1, 3).astype(jnp.float32),
        k.transpose(2, 0, 1, 3).astype(jnp.float32),
        v.transpose(2, 0, 1, 3).astype(jnp.float32),
        i_log.transpose(2, 0, 1), f_log.transpose(2, 0, 1),
    )
    (c_f, n_f, m_f), h_seq = jax.lax.scan(_mlstm_step, (c0, n0, m0), xs)
    h = h_seq.transpose(1, 2, 0, 3)                   # (B,H,S,dh)
    h = rmsnorm(params["out_norm"], h.astype(x.dtype))
    h = h.transpose(0, 2, 1, 3).reshape(b, s, d_inner)

    out = (h * gate) @ params["w_down"]
    new_cache = None
    if cache is not None:
        new_cache = {"c": c_f, "n": n_f, "m": m_f}
    return out, new_cache


def init_mlstm_cache(batch: int, num_heads: int, d_model: int,
                     proj_factor: float = 2.0) -> dict:
    dh = int(proj_factor * d_model) // num_heads
    return {
        "c": jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, num_heads, dh), jnp.float32),
        "m": jnp.full((batch, num_heads), -1e30, jnp.float32),
    }


# --------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------- #
def init_slstm_block(key, d_model: int, num_heads: int, dtype=jnp.float32):
    dh = d_model // num_heads
    ks = jax.random.split(key, 4)
    # input projections for (z, i, f, o) and block-diagonal recurrent weights
    return {
        "w_in": _he(ks[0], (d_model, 4 * d_model), dtype, fan_in=d_model),
        "r": _he(ks[1], (num_heads, dh, 4 * dh), dtype, fan_in=dh),
        "b": jnp.concatenate([
            jnp.zeros((2 * d_model,)),
            jnp.linspace(3.0, 6.0, d_model),     # forget-gate bias (powerful init)
            jnp.zeros((d_model,)),
        ]).astype(jnp.float32),
        "out_norm": init_rmsnorm(d_model, dtype),
        # post-up-projection (PF 4/3 GLU) per the xLSTM block design
        "w_up_gate": _he(ks[2], (d_model, _ffn_dim(d_model)), dtype,
                         fan_in=d_model),
        "w_up": _he(ks[2], (d_model, _ffn_dim(d_model)), dtype,
                    fan_in=d_model),
        "w_down": _he(ks[3], (_ffn_dim(d_model), d_model), dtype,
                      fan_in=_ffn_dim(d_model)),
    }


def _slstm_step(params_r, state, inp):
    """state: (c, n, h, m) each (B,H,dh); inp: pre-activation (B, 4*D)."""
    c_prev, n_prev, h_prev, m_prev = state
    b_, h_heads, dh = c_prev.shape
    # recurrent contribution: block-diagonal per head
    rec = jnp.einsum("bhd,hdf->bhf", h_prev, params_r)       # (B,H,4*dh)
    raw = inp.reshape(b_, h_heads, 4 * dh) + rec
    z_r, i_r, f_r, o_r = jnp.split(raw, 4, axis=-1)           # (B,H,dh)
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    i_log = i_r
    f_log = jax.nn.log_sigmoid(f_r)
    m_new = jnp.maximum(f_log + m_prev, i_log)
    i_g = jnp.exp(i_log - m_new)
    f_g = jnp.exp(f_log + m_prev - m_new)
    c_new = f_g * c_prev + i_g * z
    n_new = f_g * n_prev + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(
    params, x: jax.Array, *, num_heads: int,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    b, s, d_model = x.shape
    dh = d_model // num_heads
    pre = x.astype(jnp.float32) @ params["w_in"].astype(jnp.float32) + params["b"]

    if cache is None:
        zeros = jnp.zeros((b, num_heads, dh), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((b, num_heads, dh), -1e30))
    else:
        state = (cache["c"], cache["n"], cache["h"], cache["m"])

    def step(st, inp):
        return _slstm_step(params["r"].astype(jnp.float32), st, inp)

    state_f, h_seq = jax.lax.scan(step, state, pre.transpose(1, 0, 2))
    # h_seq: (S, B, H, dh) -> (B, S, D)
    h = h_seq.transpose(1, 0, 2, 3).reshape(b, s, d_model).astype(x.dtype)
    h = rmsnorm(params["out_norm"], h)

    # gated post-up-projection
    y = (jax.nn.gelu(h @ params["w_up_gate"]) * (h @ params["w_up"])
         ) @ params["w_down"]
    new_cache = None
    if cache is not None:
        c_f, n_f, h_f, m_f = state_f
        new_cache = {"c": c_f, "n": n_f, "h": h_f, "m": m_f}
    return y, new_cache


def init_slstm_cache(batch: int, num_heads: int, d_model: int) -> dict:
    dh = d_model // num_heads
    zeros = jnp.zeros((batch, num_heads, dh), jnp.float32)
    return {"c": zeros, "n": zeros, "h": zeros,
            "m": jnp.full((batch, num_heads, dh), -1e30, jnp.float32)}
