"""Top-level language model: embeddings -> block stack -> norm -> unembed,
with train / prefill / decode entry points, multimodal prefix support, and
the paper's payload-selected vocab-row sync as a first-class train step.

Encoder-decoder (audio): ``enc`` stack runs bidirectional over the frontend
embeddings; decoder blocks cross-attend to its output.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    embed, init_embedding, init_rmsnorm, rmsnorm, softmax_cross_entropy,
)
from repro.models.transformer import (
    apply_stack, init_stack, init_stack_cache, _dtype_of,
)

LMParams = Dict[str, Any]


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def init_lm_params(cfg: ModelConfig, key: jax.Array) -> LMParams:
    k_emb, k_stack, k_enc, k_out = jax.random.split(key, 4)
    params: LMParams = {
        "embed": init_embedding(k_emb, cfg.padded_vocab, cfg.d_model,
                                _dtype_of(cfg)),
        "stack": init_stack(k_stack, cfg, cross=cfg.is_enc_dec),
        "final_norm": init_rmsnorm(cfg.d_model, _dtype_of(cfg)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(k_out, cfg.padded_vocab, cfg.d_model,
                                           _dtype_of(cfg))
    if cfg.is_enc_dec:
        import dataclasses
        enc_cfg = dataclasses.replace(cfg, num_layers=cfg.encoder_layers,
                                      block_pattern=("attn",))
        params["encoder"] = init_stack(k_enc, enc_cfg, cross=False)
        params["enc_norm"] = init_rmsnorm(cfg.d_model, _dtype_of(cfg))
    return params


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, num_layers=cfg.encoder_layers,
                               block_pattern=("attn",))


def _unembed(params: LMParams, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    table = params["unembed"]["table"] if "unembed" in params \
        else params["embed"]["table"]
    return _mask_padded(x @ table.T, cfg)


def _mask_padded(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    """-inf out logits of vocab-padding rows (tables are padded to a
    16-shardable row count; padded ids must never win argmax or enter CE)."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    ids = jnp.arange(logits.shape[-1])
    neg = jnp.asarray(jnp.finfo(jnp.float32).min, logits.dtype)
    return jnp.where(ids < cfg.vocab_size, logits, neg)


def encode(params: LMParams, cfg: ModelConfig,
           frontend_embeds: jax.Array) -> jax.Array:
    """Bidirectional encoder over stub-frontend embeddings (audio)."""
    positions = jnp.arange(frontend_embeds.shape[1])
    h, _, _ = apply_stack(params["encoder"], _enc_cfg(cfg), frontend_embeds,
                          positions=positions, mode="train", causal=False)
    return rmsnorm(params["enc_norm"], h, cfg.norm_eps)


# --------------------------------------------------------------------- #
# forward / loss
# --------------------------------------------------------------------- #
def lm_forward(
    params: LMParams,
    cfg: ModelConfig,
    tokens: jax.Array,                       # (B, S) int32
    *,
    prefix_embeds: Optional[jax.Array] = None,   # (B, P, d) vlm patches
    enc_embeds: Optional[jax.Array] = None,      # (B, F, d) audio frames
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S_total, V), aux_loss). For vlm, S_total includes
    the visual prefix positions (their logits are present but unused in the
    loss, which offsets labels accordingly)."""
    x = embed(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])

    enc_out = None
    if cfg.is_enc_dec:
        assert enc_embeds is not None, "enc-dec model needs encoder inputs"
        enc_out = encode(params, cfg, enc_embeds)

    h, _, aux = apply_stack(params["stack"], cfg, x, positions=positions,
                            mode="train", enc_out=enc_out, causal=True)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return _unembed(params, cfg, h), aux


def lm_loss(
    params: LMParams,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    aux_weight: float = 0.01,
) -> jax.Array:
    tokens = batch["tokens"]                     # (B, S+1)
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits, aux = lm_forward(
        params, cfg, inputs,
        prefix_embeds=batch.get("prefix_embeds"),
        enc_embeds=batch.get("enc_embeds"))
    if batch.get("prefix_embeds") is not None:
        p = batch["prefix_embeds"].shape[1]
        logits = logits[:, p:]                   # text positions only
    return softmax_cross_entropy(logits, labels) + aux_weight * aux


# --------------------------------------------------------------------- #
# train step (Adam, from-scratch)
# --------------------------------------------------------------------- #
class TrainState(NamedTuple):
    params: LMParams
    m: LMParams
    v: LMParams
    step: jax.Array


def init_train_state(cfg: ModelConfig, key: jax.Array) -> TrainState:
    params = init_lm_params(cfg, key)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params, m=zeros,
                      v=jax.tree.map(jnp.copy, zeros),
                      step=jnp.zeros((), jnp.int32))


def train_step(
    state: TrainState,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    lr: float = 3e-4,
    beta1: float = 0.9,
    beta2: float = 0.95,
    eps: float = 1e-8,
) -> Tuple[TrainState, jax.Array]:
    """One Adam step. Returns (new_state, loss)."""
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, batch))(state.params)
    step = state.step + 1
    tf = step.astype(jnp.float32)

    m = jax.tree.map(lambda mm, g: beta1 * mm + (1 - beta1) * g.astype(jnp.float32),
                     state.m, grads)
    v = jax.tree.map(lambda vv, g: beta2 * vv
                     + (1 - beta2) * jnp.square(g.astype(jnp.float32)),
                     state.v, grads)
    m_scale = 1.0 / (1.0 - beta1 ** tf)
    v_scale = 1.0 / (1.0 - beta2 ** tf)
    params = jax.tree.map(
        lambda p, mm, vv: (p.astype(jnp.float32)
                           - lr * (mm * m_scale)
                           / (jnp.sqrt(vv * v_scale) + eps)).astype(p.dtype),
        state.params, m, v)
    return TrainState(params, m, v, step), loss


# --------------------------------------------------------------------- #
# payload-selected train step (the paper's technique at the jit level)
# --------------------------------------------------------------------- #
def payload_train_step(
    state: TrainState,
    batch: Dict[str, jax.Array],
    selected: jax.Array,                     # (M_s,) int32 vocab rows
    cfg: ModelConfig,
    lr: float = 3e-4,
    beta1: float = 0.9,
    beta2: float = 0.95,
    eps: float = 1e-8,
    row_spec=None,                           # PartitionSpec for (M_s, d) rows
) -> Tuple[TrainState, jax.Array, jax.Array]:
    """train_step with vocab-table gradients restricted to ``selected``.

    The FL mapping (DESIGN.md §3): the per-round item-dependent payload of
    an LLM is the embedding/unembedding pair; restricting their gradient to
    the bandit-selected rows shrinks the cross-replica (data-axis) gradient
    collective from O(V×d) to O(M_s×d) — the paper's 90% payload reduction,
    measurable in the compiled HLO. Rows not selected keep their server
    values (stop_gradient), exactly "clients update the transmitted subset".

    Returns (new_state, loss, selected-row grads of the unembedding) — the
    row grads are the bandit feedback s_t (Alg. 1 line 11).
    """
    params = state.params
    tables = [k for k in ("embed", "unembed") if k in params]
    body = {k: v for k, v in params.items() if k not in tables}

    def constrain(rows):
        if row_spec is None:
            return rows
        return jax.lax.with_sharding_constraint(rows, row_spec)

    rows0 = {t: constrain(params[t]["table"][selected]) for t in tables}

    def loss_fn(body_p, rows):
        p = dict(body_p)
        for t in tables:
            base = jax.lax.stop_gradient(params[t]["table"])
            p[t] = {"table": base.at[selected].set(rows[t])}
        return lm_loss(p, cfg, batch)

    loss, (body_g, rows_g) = jax.value_and_grad(
        loss_fn, argnums=(0, 1))(body, rows0)
    rows_g = {t: constrain(g) for t, g in rows_g.items()}

    step = state.step + 1
    tf = step.astype(jnp.float32)
    m_scale = 1.0 / (1.0 - beta1 ** tf)
    v_scale = 1.0 / (1.0 - beta2 ** tf)

    new_params, new_m, new_v = dict(params), dict(state.m), dict(state.v)
    # dense Adam on the body
    for k in body:
        mk = jax.tree.map(
            lambda mm, g: beta1 * mm + (1 - beta1) * g.astype(jnp.float32),
            state.m[k], body_g[k])
        vk = jax.tree.map(
            lambda vv, g: beta2 * vv
            + (1 - beta2) * jnp.square(g.astype(jnp.float32)),
            state.v[k], body_g[k])
        new_params[k] = jax.tree.map(
            lambda p, mm, vv: (p.astype(jnp.float32)
                               - lr * (mm * m_scale)
                               / (jnp.sqrt(vv * v_scale) + eps)
                               ).astype(p.dtype),
            body[k], mk, vk)
        new_m[k], new_v[k] = mk, vk

    # sparse (selected-rows) Adam on the vocab tables — untouched rows keep
    # their moments, matching the server-side selected-subset update
    for t in tables:
        g = rows_g[t].astype(jnp.float32)
        m_rows = beta1 * state.m[t]["table"][selected] + (1 - beta1) * g
        v_rows = (beta2 * state.v[t]["table"][selected]
                  + (1 - beta2) * jnp.square(g))
        p_rows = (params[t]["table"][selected].astype(jnp.float32)
                  - lr * (m_rows * m_scale)
                  / (jnp.sqrt(v_rows * v_scale) + eps))
        new_params[t] = {"table": params[t]["table"].at[selected].set(
            p_rows.astype(params[t]["table"].dtype))}
        new_m[t] = {"table": state.m[t]["table"].at[selected].set(m_rows)}
        new_v[t] = {"table": state.v[t]["table"].at[selected].set(v_rows)}

    feedback = rows_g[tables[-1]]
    return TrainState(new_params, new_m, new_v, step), loss, feedback


# --------------------------------------------------------------------- #
# serving: prefill + decode
# --------------------------------------------------------------------- #
def prefill_step(
    params: LMParams,
    cfg: ModelConfig,
    tokens: jax.Array,                       # (B, S)
    *,
    prefix_embeds: Optional[jax.Array] = None,
    enc_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Run the prompt, return (last-token logits (B, V), decode cache)."""
    x = embed(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    enc_out = None
    if cfg.is_enc_dec:
        enc_out = encode(params, cfg, enc_embeds)
    h, cache, _ = apply_stack(params["stack"], cfg, x, positions=positions,
                              mode="prefill", enc_out=enc_out, causal=True)
    h = rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    logits = _unembed(params, cfg, h)[:, 0]
    return logits, cache


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    enc_len = cfg.frontend_seq if cfg.is_enc_dec else 0
    return init_stack_cache(cfg, batch, max_len, enc_len)


def decode_step(
    params: LMParams,
    cfg: ModelConfig,
    cache: Dict,
    token: jax.Array,                        # (B, 1) int32 — the new token
    pos: jax.Array,                          # ()   int32 — its absolute position
    *,
    enc_out: Optional[jax.Array] = None,     # (B, F, d) cached encoder memory
) -> Tuple[jax.Array, Dict]:
    """serve_step: ONE new token against the KV cache. Returns (logits, cache)."""
    x = embed(params["embed"], token)
    positions = pos + jnp.arange(1)
    h, new_cache, _ = apply_stack(params["stack"], cfg, x, positions=positions,
                                  mode="decode", cache=cache, enc_out=enc_out,
                                  causal=True)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _unembed(params, cfg, h)[:, 0]
    return logits, new_cache


# --------------------------------------------------------------------- #
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------- #
def input_specs(cfg: ModelConfig, shape, for_grad: bool = False) -> Dict:
    """ShapeDtypeStruct inputs for (cfg, input shape) — the dry-run contract.

    train:   {"tokens": (B, S+1)} (+ modality embeds)
    prefill: {"tokens": (B, S)} (+ modality embeds)
    decode:  {"token": (B, 1), "pos": (), "cache": <stack cache>} (+ enc_out)
    """
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    dt = _dtype_of(cfg)

    def sds(shp, dtype):
        return jax.ShapeDtypeStruct(shp, dtype)

    if shape.kind == "train":
        specs = {"tokens": sds((b, s + 1), i32)}
        if cfg.modality == "vision":
            specs["prefix_embeds"] = sds((b, cfg.frontend_seq, cfg.d_model), dt)
        if cfg.is_enc_dec:
            specs["enc_embeds"] = sds((b, cfg.frontend_seq, cfg.d_model), dt)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((b, s), i32)}
        if cfg.modality == "vision":
            specs["prefix_embeds"] = sds((b, cfg.frontend_seq, cfg.d_model), dt)
        if cfg.is_enc_dec:
            specs["enc_embeds"] = sds((b, cfg.frontend_seq, cfg.d_model), dt)
        return specs
    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda: init_decode_cache(cfg, b, s))
        specs = {"token": sds((b, 1), i32), "pos": sds((), i32),
                 "cache": cache}
        if cfg.is_enc_dec:
            specs["enc_out"] = sds((b, cfg.frontend_seq, cfg.d_model), dt)
        return specs
    raise ValueError(shape.kind)
