"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Switch/Mixtral-style: tokens are routed to their top-k experts; each expert
processes at most ``capacity`` tokens (overflow dropped — standard for
TPU-shape-static MoE). Dispatch/combine use scatter/gather rather than the
dense one-hot einsum so compiled FLOPs stay ~(top_k * capacity_factor) x the
dense-FFN cost — the roofline then reflects the real MoE arithmetic, and the
expert dimension shards over the 'model' mesh axis (expert parallelism).

An auxiliary load-balance loss (Shazeer-style: E * sum_e f_e * p_e) is
returned so training discourages expert collapse.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _he
from repro.utils import hints
from repro.utils.compat import shard_map


def init_moe(key, d_model: int, d_ff: int, num_experts: int, dtype=jnp.float32):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": _he(kr, (d_model, num_experts), jnp.float32, fan_in=d_model),
        "w_gate": _he(k1, (num_experts, d_model, d_ff), dtype, fan_in=d_model),
        "w_up": _he(k2, (num_experts, d_model, d_ff), dtype, fan_in=d_model),
        "w_down": _he(k3, (num_experts, d_ff, d_model), dtype, fan_in=d_ff),
    }


def moe_ffn(
    params,
    x: jax.Array,                 # (B, S, d_model)
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux load-balance loss scalar).

    Distribution (the hard part, learned by measurement — §Perf):
    scatter/gather-based dispatch lowers to HLO scatter with iota-
    concatenated indices, which GSPMD cannot partition on the (data-
    sharded) batch axis — it replicates the (B,E,C,d) dispatch buffers and
    all-gathers them every layer (measured 43GB/layer on mixtral train_4k).
    When a mesh is active (hints.active()), we therefore run the whole
    dispatch→expert-FFN→combine path inside a *partial-auto shard_map*:
    the data/pod axes are manual (each shard dispatches its own tokens —
    zero dispatch collectives, the paper-faithful "local routing" of
    group-wise MoE), while the model axis stays auto so the expert einsums
    keep their tensor-parallel sharding (w_down partials psum over model).
    Weight gradients get the data-axis psum from shard_map's autodiff.

    Dispatch is GROUP-WISE (group = one batch row): position-in-expert is
    a cumsum over the sequence axis only; capacity is per group.
    """
    mode = os.environ.get("REPRO_MOE_DISPATCH", "sharded")
    if hints.active() and mode == "sharded":
        # batch must divide the data axes (long_500k decodes batch=1 —
        # a 1-token FFN is trivially local, plain SPMD handles it fine)
        mesh = hints.get_mesh()
        dsize = 1
        for ax in hints.get_batch_axes():
            dsize *= mesh.shape[ax]
        if x.shape[0] % dsize == 0:
            return _moe_manual(params, x, num_experts=num_experts,
                               top_k=top_k, capacity_factor=capacity_factor)
    if mode == "global":        # §Perf baseline: global-token-axis dispatch
        return _moe_global(params, x, num_experts=num_experts, top_k=top_k,
                           capacity_factor=capacity_factor)
    return _moe_local(params, x, num_experts=num_experts, top_k=top_k,
                      capacity_factor=capacity_factor)


def _moe_global(params, x, *, num_experts, top_k, capacity_factor):
    """The naive formulation kept for §Perf A/B: position-in-expert from a
    cumsum over the GLOBAL flattened token axis. Semantically fine, but the
    cross-shard cumsum + unbatchable scatter replicate the dispatch buffers
    under SPMD (the measured collective/memory catastrophe)."""
    b, s, d = x.shape
    n = b * s
    xt = x.reshape(n, d)
    logits = (xt.astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    capacity = max(1, int(capacity_factor * n * top_k / num_experts))
    out = jnp.zeros((n, d), jnp.float32)
    aux_f = jnp.zeros((num_experts,), jnp.float32)
    for slot in range(top_k):
        eid = expert_ids[:, slot]
        gv = gate_vals[:, slot]
        onehot = jax.nn.one_hot(eid, num_experts, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, -1)
        keep = pos < capacity
        aux_f = aux_f + jnp.sum(onehot, axis=0).astype(jnp.float32)
        safe_e = jnp.where(keep, eid, 0)
        safe_p = jnp.where(keep, pos, capacity)
        buf = jnp.zeros((num_experts, capacity + 1, d), x.dtype)
        buf = buf.at[safe_e, safe_p].set(xt)
        h = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, params["w_down"])
        gathered = y[safe_e, safe_p]
        out = out + jnp.where(keep[:, None], gathered.astype(jnp.float32),
                              0.0) * gv[:, None]
    frac = aux_f / jnp.maximum(aux_f.sum(), 1.0)
    aux = num_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
    return out.reshape(b, s, d).astype(x.dtype), aux


def _moe_manual(params, x, *, num_experts, top_k, capacity_factor,
                model_axis: str = "model"):
    """Fully-manual shard_map MoE: explicit expert/tensor parallelism.

    E >= |model axis|  -> expert parallelism: each model shard owns E/m
        experts, computes only its experts' tokens (foreign tokens combine
        from zero rows), one psum over the model axis per layer.
    E <  |model axis|  -> tensor parallelism on d_ff: every shard holds all
        experts with an f-slice; w_down partials psum over the model axis.

    The data/pod axes are manual too: each shard dispatches only its own
    tokens (zero dispatch collectives). Weight cotangents pick up the
    data-axis psum from shard_map's transpose of the replicated in_spec.
    (A partial-auto shard_map — model axis left auto — trips an XLA CPU
    CHECK in AllReducePromotion; fully-manual sidesteps it. §Perf)
    """
    from jax.sharding import PartitionSpec as P
    mesh = hints.get_mesh()
    baxes = hints.get_batch_axes()
    model_n = mesh.shape[model_axis]
    expert_parallel = num_experts >= model_n
    if expert_parallel:
        wspec = {"router": P(), "w_gate": P(model_axis),
                 "w_up": P(model_axis), "w_down": P(model_axis)}
    else:
        wspec = {"router": P(), "w_gate": P(None, None, model_axis),
                 "w_up": P(None, None, model_axis),
                 "w_down": P(None, model_axis, None)}

    def local(p, xl):
        out, aux = _moe_local(
            p, xl, num_experts=num_experts, top_k=top_k,
            capacity_factor=capacity_factor,
            expert_parallel=(expert_parallel, model_axis, model_n))
        out = jax.lax.psum(out.astype(jnp.float32), model_axis)
        # per-shard scalar -> (1,); averaged outside the shard_map (an
        # in-body pmean trips the same XLA CPU CHECK)
        return out.astype(xl.dtype), aux[None]

    fn = shard_map(
        local, mesh=mesh, in_specs=(wspec, P(baxes)),
        out_specs=(P(baxes), P(baxes)), check_vma=False)
    out, aux_shards = fn(params, x)
    return out, jnp.mean(aux_shards)


def _moe_local(
    params,
    x: jax.Array,
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float,
    expert_parallel=None,        # (enabled, model_axis, model_n) | None
) -> Tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    ep_on, ep_axis, ep_n = expert_parallel or (False, None, 1)
    e_loc = num_experts // ep_n if ep_on else num_experts

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])                        # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)          # (B, S, k)
    # renormalize the selected gates (Mixtral convention)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = max(1, int(capacity_factor * s * top_k / num_experts))

    def _slot(xg, eidg, gvg, posg, keepg):
        """One group's dispatch -> expert FFN -> combine (vmapped over the
        local batch). Under the mesh this runs inside the fully-manual
        shard_map (_moe_manual) so the scatter/gather never cross shards;
        see the module docstring and §Perf for why SPMD alone cannot
        partition this pattern."""
        safe_e = jnp.where(keepg, eidg, 0)
        safe_p = jnp.where(keepg, posg, capacity)        # trash slot
        buf = jnp.zeros((num_experts, capacity + 1, d), xg.dtype)
        buf = buf.at[safe_e, safe_p].set(xg)

        if ep_on:
            # expert parallelism: run only this shard's experts; foreign
            # tokens combine from the zero rows and the outer psum merges
            e0 = jax.lax.axis_index(ep_axis) * e_loc
            buf_my = jax.lax.dynamic_slice_in_dim(buf, e0, e_loc, 0)
        else:
            buf_my = buf

        h = jnp.einsum("ecd,edf->ecf", buf_my, params["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf_my, params["w_up"])
        act = jax.nn.silu(h) * u
        y_my = jnp.einsum("ecf,efd->ecd", act, params["w_down"])

        if ep_on:
            y = jnp.zeros((num_experts, capacity + 1, d), y_my.dtype)
            y = jax.lax.dynamic_update_slice_in_dim(y, y_my, e0, 0)
        else:
            y = y_my                                             # (E,C+1,d)

        gathered = y[safe_e, safe_p]                             # (S, d)
        return jnp.where(keepg[:, None],
                         gathered.astype(jnp.float32), 0.0) * gvg[:, None]

    out = jnp.zeros((b, s, d), jnp.float32)
    aux_f = jnp.zeros((num_experts,), jnp.float32)

    for slot in range(top_k):
        eid = expert_ids[..., slot]                              # (B, S)
        gv = gate_vals[..., slot]
        onehot = jax.nn.one_hot(eid, num_experts, dtype=jnp.int32)  # (B,S,E)
        pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot         # per group
        pos = jnp.sum(pos_in_expert * onehot, axis=-1)              # (B, S)
        keep = pos < capacity
        aux_f = aux_f + jnp.sum(onehot, axis=(0, 1)).astype(jnp.float32)
        out = out + jax.vmap(_slot)(x, eid, gv, pos, keep)

    # load-balance aux loss: E * sum_e (fraction routed to e) * (mean prob e)
    frac = aux_f / jnp.maximum(aux_f.sum(), 1.0)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = num_experts * jnp.sum(frac * mean_prob)
    return out.astype(x.dtype), aux
