"""RecurrentGemma's recurrent block: temporal conv + RG-LRU (arXiv:2402.19427).

RG-LRU recurrence per channel:
    r_t = sigmoid(W_a x_t + b_a)              (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)              (input gate)
    a_t = a ^ (c * r_t),  a = sigmoid(Lambda) (learnable decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is linear in h, so training/prefill uses
``jax.lax.associative_scan`` (log-depth, TPU-parallel, shardable over batch/
channels); decode is the O(1) single-step update. This is the hardware
adaptation of the paper-family's GPU linear-scan kernels to TPU: the
associative scan lowers to a work-efficient parallel prefix on XLA:TPU.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _he

_C = 8.0  # RG-LRU temperature constant from the paper


def init_rglru_block(key, d_model: int, d_rnn: int, conv_width: int = 4,
                     dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return {
        "w_in": _he(ks[0], (d_model, d_rnn), dtype, fan_in=d_model),
        "w_gate_branch": _he(ks[1], (d_model, d_rnn), dtype, fan_in=d_model),
        "conv_w": _he(ks[2], (conv_width, d_rnn), dtype, fan_in=conv_width),
        "w_a": _he(ks[3], (d_rnn, d_rnn), dtype, fan_in=d_rnn),
        "w_x": _he(ks[4], (d_rnn, d_rnn), dtype, fan_in=d_rnn),
        # Lambda init so a = sigmoid(Lambda) ~ 0.9..0.999 (paper init range)
        "lam": jnp.linspace(4.0, 8.0, d_rnn).astype(jnp.float32),
        "w_out": _he(ks[5], (d_rnn, d_model), dtype, fan_in=d_rnn),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time. x (B,S,D), w (W,D).

    Returns (y, new_state) where state carries the last W-1 inputs for decode.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)               # (B, S+W-1, D)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(width))
    new_state = xp[:, -(width - 1):, :] if width > 1 else pad
    return y.astype(x.dtype), new_state


def _rglru_scan(a: jax.Array, bx: jax.Array,
                h0: Optional[jax.Array]) -> jax.Array:
    """h_t = a_t * h_{t-1} + bx_t via associative scan over time axis 1."""
    if h0 is not None:
        # fold the carried state into the first step
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rglru_block(
    params,
    x: jax.Array,                       # (B, S, d_model)
    *,
    cache: Optional[dict] = None,       # {"h": (B, d_rnn), "conv": (B,W-1,d_rnn)}
) -> Tuple[jax.Array, Optional[dict]]:
    """RecurrentGemma recurrent block. Returns (out (B,S,d_model), cache)."""
    gate_branch = jax.nn.gelu(x @ params["w_gate_branch"])   # (B,S,R)
    u = x @ params["w_in"]                                   # (B,S,R)
    u, conv_state = _causal_conv(
        u, params["conv_w"], None if cache is None else cache["conv"])

    r = jax.nn.sigmoid(u @ params["w_a"])
    i = jax.nn.sigmoid(u @ params["w_x"])
    log_a = -_C * r * jax.nn.softplus(-params["lam"])        # log a_t <= 0
    a = jnp.exp(log_a.astype(jnp.float32)).astype(x.dtype)
    gated = i * u
    bx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a.astype(jnp.float32)),
                              1e-6)).astype(x.dtype) * gated

    h0 = None if cache is None else cache["h"]
    if x.shape[1] == 1 and cache is not None:
        # decode fast path: single step, no scan
        h = a[:, 0] * h0 + bx[:, 0] if h0 is not None else bx[:, 0]
        h_seq = h[:, None]
    else:
        h_seq = _rglru_scan(a, bx, h0)
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_seq[:, -1], "conv": conv_state}

    out = (h_seq * gate_branch) @ params["w_out"]
    return out, new_cache


def init_rglru_cache(batch: int, d_rnn: int, conv_width: int = 4,
                     dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, d_rnn), dtype),
        "conv": jnp.zeros((batch, conv_width - 1, d_rnn), dtype),
    }
