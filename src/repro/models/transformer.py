"""Block assembly + scan-over-layers for every architecture in the zoo.

A model is a repeating ``block_pattern`` (configs.base). Parameters for each
pattern position are stacked along a leading ``periods`` axis and the stack
is applied with ``jax.lax.scan`` — HLO size and dry-run compile time are
per-period, not per-layer (48-layer models compile as one loop body).
Remainder layers (num_layers % len(pattern)) are applied unscanned.

Modes:
  train   — full sequence, no cache
  prefill — full sequence, returns a decode cache
  decode  — S new tokens (usually 1) against the cache

Encoder-decoder (audio): a bidirectional full-attention encoder stack feeds
cross-attention in every decoder block.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def _scan_unroll() -> bool:
    """Full scan unroll (dry-run cost probes only; see launch/dryrun.py)."""
    return os.environ.get("REPRO_SCAN_UNROLL", "0") == "1"

from repro.configs.base import ModelConfig
from repro.models.attention import (
    attention_block, init_attention, init_attention_cache,
)
from repro.models.layers import init_mlp, init_rmsnorm, mlp, rmsnorm
from repro.models.moe import init_moe, moe_ffn
from repro.models.rglru import init_rglru_block, init_rglru_cache, rglru_block
from repro.models.xlstm import (
    init_mlstm_block, init_mlstm_cache, init_slstm_block, init_slstm_cache,
    mlstm_block, slstm_block,
)

ATTN_KINDS = ("attn", "swa", "moe", "moe_swa")


def _dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------------- #
# single block
# --------------------------------------------------------------------- #
def init_block(key, kind: str, cfg: ModelConfig, cross: bool = False) -> Dict:
    dtype = _dtype_of(cfg)
    keys = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if kind in ATTN_KINDS:
        p["attn"] = init_attention(
            keys[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.head_dim, dtype, qk_norm=cfg.qk_norm)
        if cross:
            p["norm_x"] = init_rmsnorm(cfg.d_model, dtype)
            p["xattn"] = init_attention(
                keys[1], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.head_dim, dtype, qk_norm=False)
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        if kind in ("moe", "moe_swa"):
            p["moe"] = init_moe(keys[2], cfg.d_model, cfg.d_ff,
                                cfg.num_experts, dtype)
        else:
            p["mlp"] = init_mlp(keys[2], cfg.d_model, cfg.d_ff, dtype)
    elif kind == "rglru":
        p["rglru"] = init_rglru_block(
            keys[0], cfg.d_model, cfg.d_rnn or cfg.d_model, cfg.conv_width,
            dtype)
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        p["mlp"] = init_mlp(keys[2], cfg.d_model, cfg.d_ff, dtype)
    elif kind == "mlstm":
        p["mlstm"] = init_mlstm_block(
            keys[0], cfg.d_model, cfg.num_heads, cfg.mlstm_proj_factor, dtype)
    elif kind == "slstm":
        p["slstm"] = init_slstm_block(keys[0], cfg.d_model, cfg.num_heads, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     enc_len: int = 0) -> Dict:
    """Decode-cache structure for one block."""
    dtype = _dtype_of(cfg)
    if kind in ATTN_KINDS:
        window = cfg.sliding_window if kind in ("swa", "moe_swa") else None
        buf_len = max_len if window is None else min(max_len, max(window, 1))
        # NOTE: baseline allocates the full max_len buffer even for windowed
        # attention; the ring-buffer variant is a §Perf optimization.
        cache = init_attention_cache(batch, cfg.num_kv_heads, cfg.head_dim,
                                     max_len, dtype)
        if enc_len > 0:
            cache["xk"] = jnp.zeros(
                (batch, cfg.num_kv_heads, enc_len, cfg.head_dim), dtype)
            cache["xv"] = jnp.zeros_like(cache["xk"])
        return cache
    if kind == "rglru":
        return init_rglru_cache(batch, cfg.d_rnn or cfg.d_model,
                                cfg.conv_width, dtype)
    if kind == "mlstm":
        return init_mlstm_cache(batch, cfg.num_heads, cfg.d_model,
                                cfg.mlstm_proj_factor)
    if kind == "slstm":
        return init_slstm_cache(batch, cfg.num_heads, cfg.d_model)
    raise ValueError(kind)


def apply_block(
    params: Dict,
    kind: str,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    mode: str,                       # train | prefill | decode
    cache: Optional[Dict] = None,
    enc_out: Optional[jax.Array] = None,
    causal: bool = True,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    window = cfg.sliding_window if kind in ("swa", "moe_swa") else None

    if kind in ATTN_KINDS:
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        attn_cache = None
        if mode == "decode":
            attn_cache = {k: cache[k] for k in ("k", "v", "len")}
        out, new_attn_cache = attention_block(
            params["attn"], h,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, positions=positions, causal=causal,
            window=window, rope_theta=cfg.rope_theta, cache=attn_cache)
        x = x + out

        new_cache = None
        if mode == "prefill":
            new_cache = _build_prefill_cache(params["attn"], h, cfg, positions,
                                             enc_out)
        elif mode == "decode":
            new_cache = dict(cache)
            new_cache.update(new_attn_cache)

        if "xattn" in params and enc_out is not None:
            hx = rmsnorm(params["norm_x"], x, cfg.norm_eps)
            x = x + _cross_attention(params["xattn"], hx, cfg, cache, enc_out,
                                     mode)

        h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if kind in ("moe", "moe_swa"):
            out2, aux = moe_ffn(params["moe"], h2, num_experts=cfg.num_experts,
                                top_k=cfg.experts_per_token,
                                capacity_factor=cfg.capacity_factor)
        else:
            out2 = mlp(params["mlp"], h2)
        x = x + out2
        return x, new_cache, aux

    if kind == "rglru":
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        rg_cache = cache if mode == "decode" else (
            init_rglru_cache(x.shape[0], cfg.d_rnn or cfg.d_model,
                             cfg.conv_width, x.dtype) if mode == "prefill" else None)
        out, new_cache = rglru_block(params["rglru"], h, cache=rg_cache)
        x = x + out
        h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
        x = x + mlp(params["mlp"], h2)
        return x, new_cache, aux

    if kind in ("mlstm", "slstm"):
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        fn = mlstm_block if kind == "mlstm" else slstm_block
        init_fn = init_mlstm_cache if kind == "mlstm" else init_slstm_cache
        blk_cache = cache if mode == "decode" else (
            (init_fn(x.shape[0], cfg.num_heads, cfg.d_model,
                     cfg.mlstm_proj_factor) if kind == "mlstm"
             else init_fn(x.shape[0], cfg.num_heads, cfg.d_model))
            if mode == "prefill" else None)
        out, new_cache = fn(params[kind], h, num_heads=cfg.num_heads,
                            cache=blk_cache)
        return x + out, new_cache, aux

    raise ValueError(kind)


def _build_prefill_cache(attn_params, h, cfg, positions, enc_out):
    """Materialize the roped K/V of the prompt as the decode cache."""
    from repro.models.attention import _split_heads
    from repro.models.layers import apply_rope

    k = _split_heads(h @ attn_params["wk"], cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(h @ attn_params["wv"], cfg.num_kv_heads, cfg.head_dim)
    if "k_norm" in attn_params:
        k = rmsnorm(attn_params["k_norm"], k)
    k = apply_rope(k, positions, cfg.rope_theta)
    return {"k": k, "v": v,
            "len": jnp.asarray(h.shape[1], jnp.int32)}


def _cross_attention(xattn_params, hx, cfg, cache, enc_out, mode):
    """Cross-attention onto the encoder memory (no positional rotation)."""
    from repro.models.attention import _merge_heads, _split_heads
    from repro.kernels import ops

    q = _split_heads(hx @ xattn_params["wq"], cfg.num_heads, cfg.head_dim)
    if mode == "decode" and cache is not None and "xk" in cache:
        k, v = cache["xk"], cache["xv"]
    else:
        k = _split_heads(enc_out @ xattn_params["wk"], cfg.num_kv_heads,
                         cfg.head_dim)
        v = _split_heads(enc_out @ xattn_params["wv"], cfg.num_kv_heads,
                         cfg.head_dim)
    out = ops.attention(q, k, v, causal=False)
    return _merge_heads(out) @ xattn_params["wo"]


# --------------------------------------------------------------------- #
# stacked layers (scan over periods)
# --------------------------------------------------------------------- #
def init_stack(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    pattern = cfg.block_pattern
    p_len = len(pattern)
    periods = cfg.num_layers // p_len
    remainder = cfg.num_layers % p_len

    keys = jax.random.split(key, periods * p_len + remainder)
    scanned = []
    for pos, kind in enumerate(pattern):
        per_period = [
            init_block(keys[t * p_len + pos], kind, cfg, cross=cross)
            for t in range(periods)
        ]
        scanned.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_period))
    rem_blocks = [
        init_block(keys[periods * p_len + i], pattern[i], cfg, cross=cross)
        for i in range(remainder)
    ]
    return {"scanned": tuple(scanned), "remainder": tuple(rem_blocks)}


def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int,
                     enc_len: int = 0) -> Dict:
    pattern = cfg.block_pattern
    p_len = len(pattern)
    periods = cfg.num_layers // p_len
    remainder = cfg.num_layers % p_len

    def stacked(kind):
        one = init_block_cache(kind, cfg, batch, max_len, enc_len)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (periods,) + x.shape), one)

    return {
        "scanned": tuple(stacked(kind) for kind in pattern),
        "remainder": tuple(
            init_block_cache(pattern[i], cfg, batch, max_len, enc_len)
            for i in range(remainder)),
    }


def apply_stack(
    params: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    mode: str,
    cache: Optional[Dict] = None,
    enc_out: Optional[jax.Array] = None,
    causal: bool = True,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Run all layers. Returns (x, new_cache, total_aux_loss)."""
    pattern = cfg.block_pattern
    p_len = len(pattern)
    use_cache = mode in ("prefill", "decode")

    def body(h, xs):
        layer_params, layer_cache = xs
        new_caches = []
        aux_total = jnp.zeros((), jnp.float32)
        for pos, kind in enumerate(pattern):
            blk_cache = None if layer_cache is None else layer_cache[pos]
            h, new_c, aux = apply_block(
                layer_params[pos], kind, cfg, h, positions=positions,
                mode=mode, cache=blk_cache, enc_out=enc_out, causal=causal)
            new_caches.append(new_c if new_c is not None else 0)
            aux_total = aux_total + aux
        return h, (tuple(new_caches), aux_total)

    unroll = _scan_unroll()
    scan_cache = cache["scanned"] if (use_cache and cache is not None) else None
    if scan_cache is None and mode == "prefill":
        def body_prefill(h, layer_params):
            return body(h, (layer_params, None))

        x, (new_caches, auxs) = jax.lax.scan(body_prefill, x,
                                             params["scanned"], unroll=unroll)
    else:
        if use_cache:
            x, (new_caches, auxs) = jax.lax.scan(
                lambda h, s: body(h, s), x,
                (params["scanned"], scan_cache), unroll=unroll)
        else:
            def body_train(h, layer_params):
                return body(h, (layer_params, None))
            if cfg.remat == "blocks":
                body_train = jax.checkpoint(body_train)
            x, (new_caches, auxs) = jax.lax.scan(body_train, x,
                                                 params["scanned"],
                                                 unroll=unroll)

    aux_total = jnp.sum(auxs)

    new_cache = None
    rem_caches = []
    for i, blk in enumerate(params["remainder"]):
        kind = pattern[i]
        blk_cache = cache["remainder"][i] if (use_cache and cache is not None
                                              and mode == "decode") else None
        x, new_c, aux = apply_block(
            blk, kind, cfg, x, positions=positions, mode=mode,
            cache=blk_cache, enc_out=enc_out, causal=causal)
        rem_caches.append(new_c if new_c is not None else 0)
        aux_total = aux_total + aux

    if use_cache:
        new_cache = {"scanned": new_caches, "remainder": tuple(rem_caches)}
    return x, new_cache, aux_total
