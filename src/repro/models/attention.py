"""GQA attention block with RoPE, optional qk-norm, sliding window, and a
KV cache for decode. Uses the Pallas flash kernel via kernels.ops."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.utils import hints
from repro.utils.compat import shard_map
from repro.models.layers import _he, apply_rope, init_rmsnorm, rmsnorm


def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype=jnp.float32, qk_norm: bool = False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": _he(kq, (d_model, num_heads * head_dim), dtype, fan_in=d_model),
        "wk": _he(kk, (d_model, num_kv_heads * head_dim), dtype, fan_in=d_model),
        "wv": _he(kv, (d_model, num_kv_heads * head_dim), dtype, fan_in=d_model),
        "wo": _he(ko, (num_heads * head_dim, d_model), dtype,
                  fan_in=num_heads * head_dim),
    }
    if qk_norm:
        p["q_norm"] = init_rmsnorm(head_dim, dtype)
        p["k_norm"] = init_rmsnorm(head_dim, dtype)
    return p


def _split_heads(x, num_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, num_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def attention_block(
    params,
    x: jax.Array,                       # (B, S, d_model)
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    positions: jax.Array,               # (S,) absolute positions
    causal: bool = True,
    window: Optional[int] = None,
    rope_theta: float = 10_000.0,
    cache: Optional[dict] = None,       # {"k","v": (B,KVH,T,D), "len": ()}
) -> Tuple[jax.Array, Optional[dict]]:
    """Returns (output (B,S,d_model), updated cache).

    Prefill/training: cache=None, full-sequence flash attention.
    Decode: S==1; the new k/v are written at cache["len"] via dynamic slice
    update and attention runs against the whole cache buffer with position
    masking (cache length handled by the causal mask on absolute positions).
    """
    q = _split_heads(x @ params["wq"], num_heads, head_dim)
    k = _split_heads(x @ params["wk"], num_kv_heads, head_dim)
    v = _split_heads(x @ params["wv"], num_kv_heads, head_dim)

    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)

    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    if cache is None:
        out = ops.attention(q, k, v, causal=causal, window=window)
        new_cache = None
    elif hints.kv_time_sharded() and x.shape[1] == 1:
        # §Perf decode path: cache time dim sharded over the model axis;
        # write + local attention + distributed log-sum-exp merge
        pos = cache["len"]
        out, ck, cv = _decode_attention_kv_sharded(
            q, cache["k"], cache["v"], k, v, pos, window)
        new_cache = {"k": ck, "v": cv, "len": pos + x.shape[1]}
    else:
        # decode: write the new kv at the current cache position
        pos = cache["len"]
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=2)
        new_cache = {"k": ck, "v": cv, "len": pos + x.shape[1]}
        # q_offset = absolute position of the query token; keys beyond the
        # causal horizon are masked inside the kernel.
        out = _decode_attention(q, ck, cv, pos, window)
    return _merge_heads(out) @ params["wo"], new_cache


def _decode_attention(q, ck, cv, pos, window):
    """Single/few-token attention against the cache buffer.

    The flash kernel's q_offset is static; for decode we instead mask by
    absolute position computed from the traced ``pos`` using the reference
    path formulated with dynamic masks (XLA fuses this fine for S=1).
    """
    b, h, s, d = q.shape
    kvh, t = ck.shape[1], ck.shape[2]
    group = h // kvh
    kk = jnp.repeat(ck, group, axis=1)
    vv = jnp.repeat(cv, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    qpos = pos + jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def _decode_attention_kv_sharded(q, ck, cv, k_new, v_new, pos, window):
    """Decode attention with the KV cache's TIME dim sharded over 'model'.

    Motivation (§Perf): at decode_32k a 32k-token cache for a 4B model is
    ~38-216 GB per device when only batch-sharded — far over the 16GB HBM.
    Each model shard holds T/m positions; the new token's K/V are written
    by the owning shard; every shard computes attention over its slice and
    the partial (max, sum, weighted-V) triples merge with the standard
    flash/log-sum-exp combination via psum — O(B·H·D) collective, not
    O(B·H·T). Fully-manual shard_map (all axes manual) so no partial-auto
    machinery is involved.

    q: (B, H, 1, D) full heads; ck/cv: (B, KVH, T, D) time-sharded.
    Returns (out (B, H, 1, D), new_ck, new_cv).
    """
    from jax.sharding import PartitionSpec as P

    mesh = hints.get_mesh()
    baxes = hints.get_batch_axes()
    model_n = mesh.shape["model"]
    t_loc = ck.shape[2] // model_n

    def local(ql, ckl, cvl, knl, vnl):
        b, h, s, d = ql.shape
        kvh = ckl.shape[1]
        i = jax.lax.axis_index("model")
        t0 = i * t_loc
        # write the new K/V on the owning shard
        off = pos - t0
        owned = (off >= 0) & (off < t_loc)
        safe = jnp.clip(off, 0, t_loc - 1)
        ck2 = jax.lax.dynamic_update_slice_in_dim(ckl, knl, safe, axis=2)
        cv2 = jax.lax.dynamic_update_slice_in_dim(cvl, vnl, safe, axis=2)
        ckl = jnp.where(owned, ck2, ckl)
        cvl = jnp.where(owned, cv2, cvl)

        group = h // kvh
        kk = jnp.repeat(ckl, group, axis=1).astype(jnp.float32)
        vv = jnp.repeat(cvl, group, axis=1).astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
        logits = jnp.einsum("bhsd,bhtd->bhst", ql.astype(jnp.float32),
                            kk) * scale                     # (B,H,1,T_loc)
        kpos = t0 + jnp.arange(t_loc)[None, :]
        qpos = pos + jnp.arange(s)[:, None]
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None], logits, -jnp.inf)

        m_loc = jnp.max(logits, axis=-1)                    # (B,H,1)
        m_glb = jax.lax.pmax(m_loc, "model")
        # shards with no visible position contribute nothing
        corr = jnp.where(jnp.isfinite(m_loc),
                         jnp.exp(m_loc - m_glb), 0.0)
        e = jnp.where(jnp.isfinite(logits),
                      jnp.exp(logits - m_loc[..., None]), 0.0)
        s_loc = jnp.sum(e, axis=-1) * corr                  # (B,H,1)
        o_loc = jnp.einsum("bhst,bhtd->bhsd", e, vv) * corr[..., None]
        s_glb = jax.lax.psum(s_loc, "model")
        o_glb = jax.lax.psum(o_loc, "model")
        out = o_glb / jnp.maximum(s_glb[..., None], 1e-30)
        return out.astype(ql.dtype), ckl, cvl

    kv_spec = P(baxes, None, "model", None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(baxes), kv_spec, kv_spec, P(baxes), P(baxes)),
        out_specs=(P(baxes), kv_spec, kv_spec),
        check_vma=False)
    return fn(q, ck, cv, k_new, v_new)


def init_attention_cache(batch: int, num_kv_heads: int, head_dim: int,
                         max_len: int, dtype=jnp.float32) -> dict:
    return {
        "k": jnp.zeros((batch, num_kv_heads, max_len, head_dim), dtype),
        "v": jnp.zeros((batch, num_kv_heads, max_len, head_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }
