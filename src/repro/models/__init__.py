from repro.models.lm import (
    LMParams, init_lm_params, lm_forward, lm_loss, train_step, prefill_step,
    decode_step, init_decode_cache, input_specs,
)

__all__ = [
    "LMParams", "init_lm_params", "lm_forward", "lm_loss", "train_step",
    "prefill_step", "decode_step", "init_decode_cache", "input_specs",
]
