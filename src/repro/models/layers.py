"""Primitive layers shared by every architecture in the zoo.

Parameters are plain nested dicts of jax.Arrays; every init function takes an
explicit key and dtype. Layers are pure functions: ``apply(params, x, ...)``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _he(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(fan, 1))
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# --------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------- #
def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# Dense / embedding
# --------------------------------------------------------------------- #
def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32, bias: bool = False):
    p = {"w": _he(key, (d_in, d_out), dtype, fan_in=d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def init_embedding(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": (0.02 * jax.random.normal(key, (vocab, dim), jnp.float32)
                      ).astype(dtype)}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x):
    """Tied or untied output projection onto the vocab: (..., d) -> (..., V)."""
    return x @ params["table"].T


# --------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0
               ) -> jax.Array:
    """x: (B, H, S, D), positions: (S,) absolute token positions."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, D/2)
    cos = jnp.cos(angles)[None, None]                       # (1,1,S,D/2)
    sin = jnp.sin(angles)[None, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# Gated MLPs
# --------------------------------------------------------------------- #
def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32,
             gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": _he(k1, (d_model, d_ff), dtype, fan_in=d_model),
        "w_down": _he(k2, (d_ff, d_model), dtype, fan_in=d_ff),
    }
    if gated:
        p["w_gate"] = _he(k3, (d_model, d_ff), dtype, fan_in=d_model)
    return p


def mlp(params, x):
    """SwiGLU when gated, GELU otherwise."""
    up = x @ params["w_up"]
    if "w_gate" in params:
        act = jax.nn.silu(x @ params["w_gate"]) * up
    else:
        act = jax.nn.gelu(up)
    return act @ params["w_down"]


# --------------------------------------------------------------------- #
# losses
# --------------------------------------------------------------------- #
def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token loss. logits (B,S,V) f32-upcast, labels (B,S) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
