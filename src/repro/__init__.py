"""repro — payload-optimized federated recommender framework (FCF-BTS, RecSys'21).

Layers:
  repro.core       bandit payload selection (the paper's contribution)
  repro.compress   payload wire-format codecs (bits-per-row axis)
  repro.cf         collaborative-filtering substrate (CF/FCF)
  repro.federated  federated-learning runtime (CF + LLM)
  repro.models     transformer model zoo (assigned architectures)
  repro.kernels    Pallas TPU kernels (interpret-mode validated on CPU)
  repro.configs    architecture + dataset + shape configs
  repro.launch     mesh construction, dry-run, train/serve drivers
"""

__version__ = "1.0.0"
