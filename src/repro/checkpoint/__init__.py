from repro.checkpoint.io import (
    CheckpointCorruptionError,
    checkpoint_step,
    latest_checkpoint,
    latest_verified_checkpoint,
    load_checkpoint,
    save_checkpoint,
    verify_checkpoint,
)

__all__ = [
    "CheckpointCorruptionError", "checkpoint_step", "latest_checkpoint",
    "latest_verified_checkpoint", "load_checkpoint", "save_checkpoint",
    "verify_checkpoint",
]
