"""Checkpoint IO: flat-key npz serialization of arbitrary pytrees.

No orbax in this environment; npz + a json treedef sidecar is portable,
inspectable, and survives process restarts. Keys are '/'-joined paths.

Crash-safety contract (docs/FAULT_MODEL.md):

  * WRITES ARE ATOMIC. The npz is written to a same-directory temp file,
    fsynced, and ``os.replace``d into place — a process killed mid-write
    can leave a stray temp file but never a truncated ``ckpt_*.npz``.
  * CONTENT IS VERIFIED. Every checkpoint gets a ``<name>.sha256`` sidecar
    (hashed over the exact bytes renamed into place, itself written
    atomically). :func:`load_checkpoint` re-hashes on load and raises
    :class:`CheckpointCorruptionError` on mismatch;
    :func:`latest_verified_checkpoint` walks newest-to-oldest past any
    corrupt entry so crash-resume always lands on intact bytes. A missing
    sidecar (pre-hardening checkpoint) is accepted as legacy.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

from repro.obs.trace import span
from repro.utils.logging import get_logger

log = get_logger("repro.checkpoint")

_STEP_RE = re.compile(r"ckpt_(\d+)\.npz$")

# npz cannot round-trip ml_dtypes.bfloat16 (numpy reloads it as an opaque
# void dtype) — bf16 leaves (compressed optimizer moments) are stored as
# their raw uint16 bit patterns under a suffixed key and viewed back on
# load. Bit-exact both ways.
_BF16_SUFFIX = "::bf16"

# cumulative hash-verification failures observed by this process (exposed
# for tests/diagnostics; verification failures are survivable by design —
# resume just walks back one checkpoint — so they are counted, not raised,
# in the discovery path)
_verify_failures = {"total": 0}


class CheckpointCorruptionError(RuntimeError):
    """A checkpoint's bytes no longer match its sha256 sidecar."""


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _sidecar(path: str) -> str:
    return path + ".sha256"


def _write_atomic_text(path: str, text: str) -> None:
    directory = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    flat = {}
    paths_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in paths_leaves:
        key = "/".join(_path_str(p) for p in path) or "leaf"
        arr = np.asarray(leaf)
        if arr.dtype == ml_dtypes.bfloat16:
            key, arr = key + _BF16_SUFFIX, arr.view(np.uint16)
        flat[key] = arr
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def save_checkpoint(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    with span("checkpoint_save", step=step):
        os.makedirs(directory, exist_ok=True)
        flat = _flatten(tree)
        path = os.path.join(directory, f"ckpt_{step:08d}.npz")
        # np.savez appends '.npz' to bare paths; keep the suffix so the
        # atomic rename moves the file actually written.
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.npz")
        os.close(fd)
        try:
            with open(tmp, "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
            # hash the exact bytes about to be renamed into place; the
            # sidecar lands AFTER the data file, so a crash between the two
            # renames leaves a valid-but-legacy checkpoint, never a
            # sidecar pointing at absent data
            digest = _sha256_file(tmp)
            os.replace(tmp, path)
            _write_atomic_text(_sidecar(path), digest + "\n")
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        _prune(directory, keep)
    return path


def verify_checkpoint(path: str) -> bool:
    """True iff ``path`` exists, has a sha256 sidecar, and the bytes match.

    Never raises: unreadable/missing/mismatching checkpoints return False
    (and bump the module failure counter) so discovery loops can walk past
    damage."""
    try:
        with open(_sidecar(path)) as f:
            expected = f.read().strip()
        ok = _sha256_file(path) == expected
    except OSError:
        _verify_failures["total"] += 1
        return False
    if not ok:
        _verify_failures["total"] += 1
        log.warning("checkpoint %s failed sha256 verification", path)
    return ok


def checkpoint_step(path: str) -> int:
    """The step number encoded in a ``ckpt_<step>.npz`` filename."""
    m = _STEP_RE.search(os.path.basename(path))
    if not m:
        raise ValueError(f"not a checkpoint path: {path!r}")
    return int(m.group(1))


def load_checkpoint(path: str, like: Any = None, verify: bool = True) -> Any:
    """Load. With ``like`` (a pytree template), restores the exact structure;
    without, returns the flat {key: array} dict.

    ``verify`` re-hashes the file against its sha256 sidecar first and
    raises :class:`CheckpointCorruptionError` on mismatch; a checkpoint
    without a sidecar (written before hardening) loads unverified."""
    if verify and os.path.exists(_sidecar(path)):
        with open(_sidecar(path)) as f:
            expected = f.read().strip()
        actual = _sha256_file(path)
        if actual != expected:
            _verify_failures["total"] += 1
            raise CheckpointCorruptionError(
                f"checkpoint {path!r} sha256 {actual[:12]}... does not "
                f"match sidecar {expected[:12]}...")
    with span("checkpoint_load"), np.load(path) as data:
        flat = {}
        for k in data.files:
            if k.endswith(_BF16_SUFFIX):
                flat[k[:-len(_BF16_SUFFIX)]] = data[k].view(ml_dtypes.bfloat16)
            else:
                flat[k] = data[k]
    if like is None:
        return flat
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_entries, leaf in paths_leaves:
        key = "/".join(_path_str(p) for p in path_entries) or "leaf"
        if key not in flat:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_checkpoint(directory: str) -> Optional[Tuple[int, str]]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m:
            step = int(m.group(1))
            if best is None or step > best[0]:
                best = (step, os.path.join(directory, name))
    return best


def latest_verified_checkpoint(directory: str) -> Optional[str]:
    """Newest checkpoint whose sha256 sidecar verifies.

    Walks newest-to-oldest, skipping (and logging) corrupt or sidecar-less
    damaged entries — the crash-resume discovery path must land on intact
    bytes even when the newest file was torn by the crash. A checkpoint
    with NO sidecar is accepted as legacy (pre-hardening) only if every
    newer checkpoint failed; returns None when nothing loads."""
    if not os.path.isdir(directory):
        return None
    entries = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m:
            entries.append((int(m.group(1)), name))
    for _, name in sorted(entries, reverse=True):
        path = os.path.join(directory, name)
        if os.path.exists(_sidecar(path)):
            if verify_checkpoint(path):
                return path
            log.warning("skipping corrupt checkpoint %s during discovery",
                        path)
        else:
            return path     # legacy: no sidecar to verify against
    return None


def _prune(directory: str, keep: int) -> None:
    entries = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m:
            entries.append((int(m.group(1)), name))
    entries.sort()
    for _, name in entries[:-keep] if keep > 0 else []:
        path = os.path.join(directory, name)
        os.unlink(path)
        if os.path.exists(_sidecar(path)):
            os.unlink(_sidecar(path))
