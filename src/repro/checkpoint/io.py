"""Checkpoint IO: flat-key npz serialization of arbitrary pytrees.

No orbax in this environment; npz + a json treedef sidecar is portable,
inspectable, and survives process restarts. Keys are '/'-joined paths.
Supports atomic writes (tmp + rename) and step-numbered retention.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.obs.trace import span

_STEP_RE = re.compile(r"ckpt_(\d+)\.npz$")


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    flat = {}
    paths_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in paths_leaves:
        key = "/".join(_path_str(p) for p in path) or "leaf"
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def save_checkpoint(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    with span("checkpoint_save", step=step):
        os.makedirs(directory, exist_ok=True)
        flat = _flatten(tree)
        path = os.path.join(directory, f"ckpt_{step:08d}.npz")
        # np.savez appends '.npz' to bare paths; keep the suffix so the
        # atomic rename moves the file actually written.
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.npz")
        os.close(fd)
        try:
            with open(tmp, "wb") as f:
                np.savez(f, **flat)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        _prune(directory, keep)
    return path


def load_checkpoint(path: str, like: Any = None) -> Any:
    """Load. With ``like`` (a pytree template), restores the exact structure;
    without, returns the flat {key: array} dict."""
    with span("checkpoint_load"), np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    if like is None:
        return flat
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_entries, leaf in paths_leaves:
        key = "/".join(_path_str(p) for p in path_entries) or "leaf"
        if key not in flat:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_checkpoint(directory: str) -> Optional[Tuple[int, str]]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m:
            step = int(m.group(1))
            if best is None or step > best[0]:
                best = (step, os.path.join(directory, name))
    return best


def _prune(directory: str, keep: int) -> None:
    entries = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m:
            entries.append((int(m.group(1)), name))
    entries.sort()
    for _, name in entries[:-keep] if keep > 0 else []:
        os.unlink(os.path.join(directory, name))
