"""Synthetic implicit-feedback datasets matched to the paper's Table 2.

The container is offline, so Movielens-1M / Last-FM / MIND cannot be
downloaded. We generate synthetic datasets that preserve the statistics the
paper's analysis depends on:

  * exact #users and #items of the preprocessed datasets (Table 2),
  * approximate #interactions / sparsity,
  * a popularity power law (Zipf) over items — the property that makes
    TopList a meaningful baseline and gives the bandit signal to find,
  * a planted low-rank user-item affinity — the property that makes CF work
    and separates personalized methods from popularity.

Generation model per user i with degree n_i (log-normal, >= 5 as in the
paper's MIND preprocessing):
    score_ij = signal * <u_i, v_j>/sqrt(K0) + pop_j + Gumbel noise
    interactions = top-n_i items by score  (Gumbel-top-k == Plackett-Luce
    sampling without replacement)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_users: int
    num_items: int
    num_interactions: int
    latent_dim: int = 16
    signal: float = 4.0        # strength of low-rank structure vs popularity
    zipf_exponent: float = 1.0
    min_degree: int = 5


# Paper Table 2 (preprocessed sizes).
DATASET_SPECS: Dict[str, DatasetSpec] = {
    "movielens": DatasetSpec("movielens", 6040, 3064, 914_676),
    "lastfm": DatasetSpec("lastfm", 1892, 17_632, 92_834),
    "mind": DatasetSpec("mind", 16_026, 6923, 163_137),
    # reduced variants for tests / CI-scale runs
    "movielens-mini": DatasetSpec("movielens-mini", 400, 300, 12_000),
    "lastfm-mini": DatasetSpec("lastfm-mini", 200, 1200, 6_000),
    "mind-mini": DatasetSpec("mind-mini", 600, 500, 7_000),
}


def _user_degrees(spec: DatasetSpec, rng: np.random.Generator) -> np.ndarray:
    """Log-normal degrees scaled to hit the target interaction count."""
    raw = rng.lognormal(mean=0.0, sigma=1.0, size=spec.num_users)
    target = spec.num_interactions
    deg = np.maximum(spec.min_degree, np.round(raw * target / raw.sum())).astype(np.int64)
    # cap at half the catalogue so top-k sampling stays well-posed
    deg = np.minimum(deg, spec.num_items // 2)
    # trim/boost to land near the target total
    diff = target - int(deg.sum())
    if diff > 0:
        bump = rng.integers(0, spec.num_users, size=diff)
        np.add.at(deg, bump, 1)
        deg = np.minimum(deg, spec.num_items // 2)
    return deg


def generate_interactions(spec: DatasetSpec, seed: int = 0) -> np.ndarray:
    """Dense binary interaction matrix X (num_users, num_items) uint8."""
    rng = np.random.default_rng(seed)
    k0 = spec.latent_dim
    u = rng.standard_normal((spec.num_users, k0)).astype(np.float32)
    v = rng.standard_normal((spec.num_items, k0)).astype(np.float32)
    # Zipf popularity over a random item permutation
    ranks = rng.permutation(spec.num_items) + 1
    pop = (-spec.zipf_exponent * np.log(ranks)).astype(np.float32)

    deg = _user_degrees(spec, rng)
    x = np.zeros((spec.num_users, spec.num_items), dtype=np.uint8)

    chunk = max(1, int(2e8) // spec.num_items)  # bound temp memory ~800MB
    for start in range(0, spec.num_users, chunk):
        stop = min(start + chunk, spec.num_users)
        scores = (spec.signal / np.sqrt(k0)) * (u[start:stop] @ v.T) + pop[None, :]
        gumbel = rng.gumbel(size=scores.shape).astype(np.float32)
        noisy = scores + gumbel
        order = np.argsort(-noisy, axis=1)
        for r, i in enumerate(range(start, stop)):
            x[i, order[r, : deg[i]]] = 1
    return x


def train_test_split(
    x: np.ndarray, train_frac: float = 0.8, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-user random 80/20 split of interacted items (Sec. 6.2)."""
    rng = np.random.default_rng(seed)
    train = np.zeros_like(x)
    test = np.zeros_like(x)
    for i in range(x.shape[0]):
        items = np.flatnonzero(x[i])
        rng.shuffle(items)
        cut = max(1, int(round(train_frac * len(items))))
        cut = min(cut, len(items) - 1) if len(items) > 1 else cut
        train[i, items[:cut]] = 1
        test[i, items[cut:]] = 1
    return train, test


def load_dataset(name: str, seed: int = 0, train_frac: float = 0.8):
    """Returns (spec, train_x, test_x) as float32 arrays."""
    spec = DATASET_SPECS[name]
    x = generate_interactions(spec, seed=seed)
    train, test = train_test_split(x, train_frac=train_frac, seed=seed + 1)
    return spec, train.astype(np.float32), test.astype(np.float32)


def sparsity(x: np.ndarray) -> float:
    """Percentage of unobserved interactions (paper Table 2 convention)."""
    return 100.0 * (1.0 - x.mean())
