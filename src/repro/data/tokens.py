"""Synthetic LM token pipeline for the federated-LLM generalization and the
train driver. Zipf-distributed tokens with short-range Markov structure so a
language model has something learnable, and per-client token distributions
are *non-IID* (each federated client favours a different vocab slice — the
situation where bandit payload selection of vocab rows matters)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class TokenDataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    zipf_exponent: float = 1.1
    num_clients: int = 1
    client_concentration: float = 0.3  # lower = more non-IID across clients
    seed: int = 0


def _zipf_probs(vocab: int, s: float) -> np.ndarray:
    # host-side data gen: f64 keeps the normalized Zipf tail from underflowing
    ranks = np.arange(1, vocab + 1, dtype=np.float64)  # repro-lint: disable=dtype-width
    p = ranks ** (-s)
    return p / p.sum()


def synthetic_token_batches(
    config: TokenDataConfig, client_id: int = 0, num_batches: Optional[int] = None
) -> Iterator[dict]:
    """Yields {'tokens': (B, S+1) int32} batches; inputs=t[:, :-1], labels=t[:, 1:].

    Per-client skew: client c's unigram is a Dirichlet-perturbed Zipf with a
    client-specific random vocab permutation boost.
    """
    rng = np.random.default_rng(config.seed + 7919 * client_id)
    base = _zipf_probs(config.vocab_size, config.zipf_exponent)
    if config.num_clients > 1:
        boost = rng.dirichlet(
            np.full(config.vocab_size, config.client_concentration, np.float64)  # repro-lint: disable=dtype-width
        )
        probs = 0.5 * base + 0.5 * boost
    else:
        probs = base
    probs = probs / probs.sum()

    # short-range structure: with prob q, next token = f(prev) deterministic map
    succ = rng.integers(0, config.vocab_size, size=config.vocab_size)
    q_repeat = 0.35

    produced = 0
    while num_batches is None or produced < num_batches:
        flat = rng.choice(
            config.vocab_size,
            size=config.batch_size * (config.seq_len + 1),
            p=probs,
        ).astype(np.int32)
        toks = flat.reshape(config.batch_size, config.seq_len + 1)
        mask = rng.random(toks.shape) < q_repeat
        toks[:, 1:] = np.where(mask[:, 1:], succ[toks[:, :-1]], toks[:, 1:])
        yield {"tokens": toks}
        produced += 1
