from repro.data.synthetic import (
    DatasetSpec, DATASET_SPECS, generate_interactions, train_test_split, load_dataset,
)
from repro.data.tokens import TokenDataConfig, synthetic_token_batches

__all__ = [
    "DatasetSpec", "DATASET_SPECS", "generate_interactions", "train_test_split",
    "load_dataset", "TokenDataConfig", "synthetic_token_batches",
]
