"""In-loop round telemetry: traced scalars out of the fused FL round.

Two pieces, split along the jit boundary:

  * :class:`RoundTelemetry` — the per-round scalars only the fused step
    can see (wire bytes, gradient/update norms, commit staleness, the
    staleness-discounted step weight, psum-reduced collective bytes under
    ``shard_map``, arm-pull coverage). Computed inside
    ``server_round_step`` / ``server_round_step_async`` when the step is
    built with ``telemetry=True`` — with ``telemetry=False`` (the
    default) not a single op is added, which is what makes the
    disabled-path bit-parity contract (tests/test_obs.py) hold trivially.
  * :class:`TelemetryState` + :func:`telemetry_round` — the scan-carry
    reward/regret aggregates (the traced port of
    :class:`repro.core.regret.RegretTracker`'s pseudo-regret: per-round
    mean reward vs. the hindsight-best subset of equal size) plus the
    packing of one round's telemetry into a flat float32 row vector with
    the fixed :data:`TELEMETRY_FIELDS` order. Rows stream out of the
    compiled chunk through one *batched* ``jax.experimental.io_callback``
    per chunk; the host side (:func:`rows_to_events`) applies the
    ``telemetry_every`` rate limit and converts rows to JSONL events.

Round events (one JSON object per line)::

    {"type": "round", "t": 25, "staleness": 1, "step_weight": 0.8,
     "bytes_down": 20800.0, "bytes_up": 2080000.0, "collective_bytes": 0.0,
     "grad_norm": 12.3, "update_norm": 0.04, "reward_mean": 0.0,
     "reward_min": -1.2, "reward_max": 2.1, "regret": 0.3,
     "cum_regret": 5.1, "arms_explored": 812, "pull_max": 25}
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# the wire order of one telemetry row; the first entry MUST stay "t"
# (the host-side rate limiter keys on it)
TELEMETRY_FIELDS = (
    "t",                 # committed global round (1-based)
    "staleness",         # snapshot age s of this round's commit (0 = sync)
    "step_weight",       # staleness_discount ** s applied to the Adam step
    "bytes_down",        # this round's downlink payload wire bytes
    "bytes_up",          # this round's uplink payload wire bytes (x cohort)
    "collective_bytes",  # psum-reduced cross-device bytes (0 off-mesh)
    "grad_norm",         # ||decoded aggregated gradient||_F
    "update_norm",       # ||committed row delta||_F
    "reward_mean",       # mean bandit reward over the selected arms
    "reward_min",
    "reward_max",
    "regret",            # this round's pseudo-regret increment
    "cum_regret",        # running cumulative pseudo-regret
    "arms_explored",     # arms pulled at least once so far
    "pull_max",          # max per-arm transmission count so far
)
_INT_FIELDS = frozenset({"t", "arms_explored", "pull_max", "staleness"})


class RoundTelemetry(NamedTuple):
    """Traced per-round scalars produced inside the fused round step."""

    t: jax.Array                  # () int32
    staleness: jax.Array          # () float32
    step_weight: jax.Array        # () float32
    bytes_down: jax.Array         # () float32
    bytes_up: jax.Array           # () float32
    collective_bytes: jax.Array   # () float32
    grad_norm: jax.Array          # () float32
    update_norm: jax.Array        # () float32
    arms_explored: jax.Array      # () float32
    pull_max: jax.Array           # () float32


class TelemetryState(NamedTuple):
    """Scan-carry reward/regret aggregates (replicated under shard_map)."""

    reward_sum: jax.Array     # (M,) float32 — per-arm reward totals
    reward_count: jax.Array   # (M,) float32 — per-arm observation counts
    cum_regret: jax.Array     # () float32


def telemetry_state_init(num_arms: int) -> TelemetryState:
    return TelemetryState(
        reward_sum=jnp.zeros((num_arms,), jnp.float32),
        reward_count=jnp.zeros((num_arms,), jnp.float32),
        cum_regret=jnp.zeros((), jnp.float32),
    )


def telemetry_round(
    ts: TelemetryState,
    tel: RoundTelemetry,
    indices: jax.Array,       # (M_s,) this round's committed arms
    rewards: jax.Array,       # (M_s,) their bandit rewards
) -> Tuple[TelemetryState, jax.Array]:
    """Fold one round into the regret aggregates; pack the telemetry row.

    The regret proxy mirrors :class:`repro.core.regret.RegretTracker`
    op-for-op (record first, then hindsight means, then the top-M_s best
    mean): ``regret_t = max(0, best - mean_t)`` accumulated over rounds —
    the empirical stand-in for the paper's (unproven) sub-linear BTS
    regret claim, now computable while the scan is still running.

    Returns ``(new_state, row)`` with ``row`` a flat float32
    ``(len(TELEMETRY_FIELDS),)`` vector in :data:`TELEMETRY_FIELDS` order.
    """
    m_s = indices.shape[0]
    idx = indices.astype(jnp.int32)
    r = rewards.astype(jnp.float32)
    reward_sum = ts.reward_sum.at[idx].add(r)
    reward_count = ts.reward_count.at[idx].add(1.0)

    mean_t = jnp.mean(r)
    means = jnp.where(reward_count > 0,
                      reward_sum / jnp.maximum(reward_count, 1.0), 0.0)
    best = jnp.mean(jax.lax.top_k(means, m_s)[0])
    inc = jnp.maximum(0.0, best - mean_t)
    cum = ts.cum_regret + inc

    values = {
        "t": tel.t.astype(jnp.float32),
        "staleness": tel.staleness,
        "step_weight": tel.step_weight,
        "bytes_down": tel.bytes_down,
        "bytes_up": tel.bytes_up,
        "collective_bytes": tel.collective_bytes,
        "grad_norm": tel.grad_norm,
        "update_norm": tel.update_norm,
        "reward_mean": mean_t,
        "reward_min": jnp.min(r),
        "reward_max": jnp.max(r),
        "regret": inc,
        "cum_regret": cum,
        "arms_explored": tel.arms_explored,
        "pull_max": tel.pull_max,
    }
    row = jnp.stack([jnp.asarray(values[f], jnp.float32)
                     for f in TELEMETRY_FIELDS])
    return TelemetryState(reward_sum=reward_sum, reward_count=reward_count,
                          cum_regret=cum), row


# ------------------------------------------------------------------ #
# host side: rows -> events, sinks, schema
# ------------------------------------------------------------------ #
def rows_to_events(rows: Any, every: int = 1) -> List[Dict[str, Any]]:
    """Convert stacked telemetry rows to JSONL round events.

    ``rows`` is a ``(R, len(TELEMETRY_FIELDS))`` array (or a single row).
    ``every`` is the rate limit: only rounds with ``t % every == 0`` (plus
    ``t == 1``, so a stream is never empty) become events.
    """
    # host-side event conversion, off the traced path — f64 so round counters
    # render exactly when formatted back to int
    arr = np.asarray(rows, np.float64)  # repro-lint: disable=dtype-width
    if arr.ndim == 1:
        arr = arr[None]
    if arr.shape[-1] != len(TELEMETRY_FIELDS):
        raise ValueError(
            f"telemetry rows must have {len(TELEMETRY_FIELDS)} fields, "
            f"got shape {arr.shape}")
    events: List[Dict[str, Any]] = []
    for row in arr:
        t = int(row[0])
        if every > 1 and t != 1 and t % every != 0:
            continue
        event: Dict[str, Any] = {"type": "round"}
        for name, value in zip(TELEMETRY_FIELDS, row):
            event[name] = int(value) if name in _INT_FIELDS else float(value)
        events.append(event)
    return events


def make_row_emitter(sink, every: int = 1):
    """An ``io_callback``-shaped host function appending rows to ``sink``."""

    def emit(rows) -> None:
        for event in rows_to_events(rows, every=every):
            sink.emit(event)

    return emit


def validate_round_event(event: Any) -> List[str]:
    """Schema errors for one round-telemetry event dict ([] = valid)."""
    errors: List[str] = []
    if not isinstance(event, dict):
        return [f"round event must be a dict, got {type(event).__name__}"]
    if event.get("type") != "round":
        errors.append(f"round event type must be 'round', "
                      f"got {event.get('type')!r}")
    for name in TELEMETRY_FIELDS:
        if name not in event:
            errors.append(f"round event missing field {name!r}")
            continue
        v = event[name]
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            errors.append(f"round field {name!r} must be a number, "
                          f"got {v!r}")
            continue
        if name in _INT_FIELDS and int(v) != v:
            errors.append(f"round field {name!r} must be integral, "
                          f"got {v!r}")
    if not errors:
        if event["t"] < 1:
            errors.append(f"round t must be >= 1, got {event['t']}")
        for name in ("bytes_down", "bytes_up", "cum_regret", "regret",
                     "collective_bytes", "staleness"):
            if event[name] < 0:
                errors.append(f"round field {name!r} must be non-negative, "
                              f"got {event[name]}")
    return errors
