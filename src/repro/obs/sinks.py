"""Pluggable event sinks for telemetry streams.

A sink receives flat JSON-serializable dict events (round telemetry rows,
eval rows, span events) via ``emit`` and owns their persistence. Three
implementations cover the repo's needs:

  * :class:`InMemorySink` — a list, for tests and programmatic readers.
  * :class:`JsonlSink`    — one JSON object per line, flushed per event so
    a concurrent reader (CI schema checker, tail -f) always sees complete
    lines.
  * :class:`CsvSink`      — buffered rows written on ``close`` through
    :func:`write_csv`, the shared stable-column CSV writer that
    :class:`repro.utils.logging.MetricLogger` is rebased on.
"""
from __future__ import annotations

import csv
import json
import os
from typing import Any, Dict, List, Optional, Sequence


class Sink:
    """Interface: ``emit`` one event dict; ``close`` flushes/persists."""

    def emit(self, event: Dict[str, Any]) -> None:
        raise NotImplementedError

    def close(self) -> None:  # pragma: no cover - trivial default
        pass

    def __enter__(self) -> "Sink":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class InMemorySink(Sink):
    """Accumulate events in ``self.events`` (programmatic consumption)."""

    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(dict(event))


class JsonlSink(Sink):
    """Append events to a JSONL file, one complete line per event."""

    def __init__(self, path: str):
        self.path = path
        self._fh = None
        self.count = 0

    def emit(self, event: Dict[str, Any]) -> None:
        if self._fh is None:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            self._fh = open(self.path, "w")
        self._fh.write(json.dumps(event, default=float) + "\n")
        self._fh.flush()
        self.count += 1

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class CsvSink(Sink):
    """Buffer events and persist them as a stable-column CSV on close."""

    def __init__(self, path: str, front: Sequence[str] = ("step", "wall_s")):
        self.path = path
        self.front = tuple(front)
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(dict(event))

    def close(self) -> None:
        write_csv(self.path, self.events, front=self.front)


def csv_fieldnames(rows: Sequence[Dict[str, Any]],
                   front: Sequence[str] = ("step", "wall_s")) -> List[str]:
    """Stable column order for heterogeneous rows.

    ``front`` keys first (in the given order, when present anywhere), then
    every other key in sorted order — a function of the key *set* only, so
    the column layout cannot depend on which row happened to come first
    (eval rows and train rows carry different keys).
    """
    seen = set()
    for r in rows:
        seen.update(r.keys())
    head = [k for k in front if k in seen]
    rest = sorted(seen - set(head))
    return head + rest


def write_csv(path: str, rows: Sequence[Dict[str, Any]],
              front: Sequence[str] = ("step", "wall_s")) -> str:
    """Write heterogeneous dict rows with stable columns and ``restval=""``.

    Missing cells are written as the empty string EXPLICITLY (not by
    accident of the csv module's default), so mixed eval/train rows
    round-trip: reading the file back with ``csv.DictReader`` and dropping
    ``""`` cells reproduces the original row dicts (modulo str conversion).
    """
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fieldnames = csv_fieldnames(rows, front=front)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fieldnames, restval="")
        w.writeheader()
        w.writerows(rows)
    return path


def resolve_sink(sink: Optional[Sink]) -> Sink:
    """Default to an :class:`InMemorySink` when no sink is configured."""
    return sink if sink is not None else InMemorySink()
