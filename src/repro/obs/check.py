"""CLI schema checker for an emitted observability artifact directory.

    PYTHONPATH=src python -m repro.obs.check OUTDIR

Validates whatever the directory contains (at least one artifact must be
present):

  * ``telemetry.jsonl`` — every line parses and passes
    :func:`repro.obs.telemetry.validate_round_event`; ``t`` is strictly
    increasing; ``cum_regret`` is non-decreasing.
  * ``trace.jsonl``     — every line passes
    :func:`repro.obs.trace.validate_span_event`.
  * ``metrics.prom``    — parses as Prometheus text
    (:func:`repro.obs.prom.validate_text`) and exposes the serving
    families the engine promises (latency histogram, model version,
    snapshot age, resident bytes).

Exit code 0 with a per-file summary when everything validates; exit 1
with every error printed otherwise. CI runs this against the artifacts
``examples/serve_recs.py --dry-run --obs-out`` emits.
"""
from __future__ import annotations

import json
import os
import sys
from typing import List, Optional, Sequence

from repro.obs.prom import validate_text
from repro.obs.telemetry import validate_round_event
from repro.obs.trace import validate_span_event

TELEMETRY_FILE = "telemetry.jsonl"
TRACE_FILE = "trace.jsonl"
METRICS_FILE = "metrics.prom"
REQUIRED_SERVE_FAMILIES = (
    "frs_serve_latency_seconds",
    "frs_serve_model_version",
    "frs_serve_snapshot_age_rounds",
    "frs_serve_resident_bytes",
)


def _check_jsonl(path: str, validate, name: str) -> List[str]:
    errors: List[str] = []
    count = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                event = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"{name}:{lineno}: not JSON: {e}")
                continue
            errors.extend(f"{name}:{lineno}: {e}"
                          for e in validate(event))
            count += 1
    if count == 0:
        errors.append(f"{name}: no events")
    return errors


def check_telemetry(path: str) -> List[str]:
    errors = _check_jsonl(path, validate_round_event, TELEMETRY_FILE)
    last_t, last_cum = 0, 0.0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            try:
                event = json.loads(line)
            except json.JSONDecodeError:
                continue
            t = event.get("t")
            cum = event.get("cum_regret")
            if isinstance(t, (int, float)):
                if t <= last_t:
                    errors.append(f"{TELEMETRY_FILE}:{lineno}: t={t} not "
                                  f"increasing (previous {last_t})")
                last_t = t
            if isinstance(cum, (int, float)):
                if cum < last_cum - 1e-9:
                    errors.append(
                        f"{TELEMETRY_FILE}:{lineno}: cum_regret={cum} "
                        f"decreased (previous {last_cum})")
                last_cum = max(last_cum, cum)
    return errors


def check_dir(outdir: str) -> List[str]:
    errors: List[str] = []
    checked = 0
    tel = os.path.join(outdir, TELEMETRY_FILE)
    if os.path.exists(tel):
        errors.extend(check_telemetry(tel))
        checked += 1
    tr = os.path.join(outdir, TRACE_FILE)
    if os.path.exists(tr):
        errors.extend(_check_jsonl(tr, validate_span_event, TRACE_FILE))
        checked += 1
    prom = os.path.join(outdir, METRICS_FILE)
    if os.path.exists(prom):
        with open(prom) as f:
            errors.extend(
                f"{METRICS_FILE}: {e}"
                for e in validate_text(f.read(),
                                       require=REQUIRED_SERVE_FAMILIES))
        checked += 1
    if checked == 0:
        errors.append(f"{outdir}: no observability artifacts found "
                      f"({TELEMETRY_FILE}/{TRACE_FILE}/{METRICS_FILE})")
    return errors


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if len(argv) != 1:
        print(__doc__)
        return 2
    errors = check_dir(argv[0])
    if errors:
        for e in errors:
            print(f"FAIL {e}")
        return 1
    present = [f for f in (TELEMETRY_FILE, TRACE_FILE, METRICS_FILE)
               if os.path.exists(os.path.join(argv[0], f))]
    print(f"obs.check OK: {', '.join(present)} validate in {argv[0]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
