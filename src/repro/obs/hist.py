"""HDR-style latency histograms: fixed geometric buckets, exact merge.

One shared quantile definition for every latency reporter in the repo —
the serving engine's per-bucket ``/metrics`` histograms, the serving
benchmark's p50/p99 cells, and the ``serve_recs`` example summary all go
through :class:`LatencyHistogram`, so their percentiles are comparable by
construction (they used to disagree: ``np.percentile`` interpolates order
statistics, a bucketed histogram interpolates within a bucket).

The bucketing is high-dynamic-range in the HdrHistogram sense: upper
bounds grow geometrically by ``2 ** (1 / buckets_per_octave)`` from
``min_value`` to ``max_value`` (defaults: 1 microsecond to 1000 seconds at
8 buckets per octave, ~9% relative resolution, 240 buckets), values below
the range land in the first bucket and values above it in the overflow
bucket. Two histograms with the same geometry merge by adding counts —
the property that lets per-bucket serving histograms aggregate across
threads, engines, or hosts without approximation beyond the shared
bucketing itself.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import numpy as np


class LatencyHistogram:
    """Geometric-bucket histogram over positive values (seconds)."""

    def __init__(self, min_value: float = 1e-6, max_value: float = 1e3,
                 buckets_per_octave: int = 8):
        if not (0 < min_value < max_value):
            raise ValueError(
                f"need 0 < min_value < max_value, got {min_value}, "
                f"{max_value}")
        if buckets_per_octave < 1:
            raise ValueError(
                f"buckets_per_octave must be >= 1, got {buckets_per_octave}")
        self.min_value = float(min_value)
        self.max_value = float(max_value)
        self.buckets_per_octave = int(buckets_per_octave)
        octaves = math.log2(max_value / min_value)
        n = int(math.ceil(octaves * buckets_per_octave))
        # bucket i covers (bounds[i-1], bounds[i]]; the last slot overflows
        self.bounds = min_value * np.power(
            2.0, (np.arange(1, n + 1)) / buckets_per_octave)
        self.counts = np.zeros(n + 1, np.int64)
        self.sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    # ------------------------------------------------------------- #
    @property
    def total(self) -> int:
        return int(self.counts.sum())

    def same_geometry(self, other: "LatencyHistogram") -> bool:
        return (self.min_value == other.min_value
                and self.max_value == other.max_value
                and self.buckets_per_octave == other.buckets_per_octave)

    # ------------------------------------------------------------- #
    def record(self, value: float) -> None:
        self.record_many([value])

    def record_many(self, values: Sequence[float]) -> None:
        # host-side histogram: f64 sum stays exact far past f32's 2^24 counts
        arr = np.asarray(values, np.float64).reshape(-1)  # repro-lint: disable=dtype-width
        if arr.size == 0:
            return
        if np.any(arr < 0) or not np.all(np.isfinite(arr)):
            raise ValueError("latencies must be finite and non-negative")
        idx = np.searchsorted(self.bounds, arr, side="left")
        np.add.at(self.counts, idx, 1)
        self.sum += float(arr.sum())
        self._min = min(self._min, float(arr.min()))
        self._max = max(self._max, float(arr.max()))

    @classmethod
    def from_values(cls, values: Sequence[float],
                    **kwargs) -> "LatencyHistogram":
        h = cls(**kwargs)
        h.record_many(values)
        return h

    # ------------------------------------------------------------- #
    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """A new histogram holding both datasets (exact on counts)."""
        if not self.same_geometry(other):
            raise ValueError("cannot merge histograms with different "
                             "bucket geometry")
        out = LatencyHistogram(self.min_value, self.max_value,
                               self.buckets_per_octave)
        out.counts = self.counts + other.counts
        out.sum = self.sum + other.sum
        out._min = min(self._min, other._min)
        out._max = max(self._max, other._max)
        return out

    def copy(self) -> "LatencyHistogram":
        out = LatencyHistogram(self.min_value, self.max_value,
                               self.buckets_per_octave)
        out.counts = self.counts.copy()
        out.sum = self.sum
        out._min = self._min
        out._max = self._max
        return out

    # ------------------------------------------------------------- #
    def quantile(self, q: float) -> float:
        """The q-quantile (0 <= q <= 1), interpolated within its bucket.

        Resolution is one bucket (~``2**(1/bpo)`` relative); the result is
        clamped to the exactly-tracked [min, max] envelope so single-value
        and extreme-q reads stay sharp.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        n = self.total
        if n == 0:
            return math.nan
        target = q * n
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target, side="left"))
        i = min(i, len(self.counts) - 1)
        lo = 0.0 if i == 0 else float(self.bounds[i - 1])
        hi = float(self.bounds[min(i, len(self.bounds) - 1)])
        prev = 0 if i == 0 else int(cum[i - 1])
        in_bucket = int(self.counts[i])
        frac = 0.5 if in_bucket == 0 else (target - prev) / in_bucket
        frac = min(max(frac, 0.0), 1.0)
        val = lo + frac * (hi - lo)
        return float(min(max(val, self._min), self._max))

    def quantiles(self, qs: Sequence[float]) -> List[float]:
        return [self.quantile(q) for q in qs]

    # ------------------------------------------------------------- #
    def cumulative_buckets(self) -> List[Tuple[float, int]]:
        """Non-empty cumulative ``(upper_bound_seconds, count)`` pairs.

        The Prometheus histogram exposition shape (``le`` buckets are
        cumulative); empty buckets are elided to keep /metrics small, the
        ``+Inf`` bucket is the renderer's job.
        """
        out: List[Tuple[float, int]] = []
        cum = 0
        for i, c in enumerate(self.counts[:-1]):
            cum += int(c)
            if c > 0:
                out.append((float(self.bounds[i]), cum))
        return out
