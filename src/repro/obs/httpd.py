"""Stdlib ``/metrics`` endpoint: Prometheus exposition over HTTP.

No external web framework — a daemon-threaded ``http.server`` that calls
a render function per scrape. Serves ``/metrics`` (and ``/``) with the
Prometheus text content type; anything else is a 404.

    server, url = start_metrics_server(engine.metrics, port=9100)
    ...
    server.shutdown()

``port=0`` binds an ephemeral port (the returned URL has the real one) —
what the CI smoke uses to prove the endpoint serves parseable text.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Tuple

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def start_metrics_server(
    render: Callable[[], str],
    port: int = 0,
    host: str = "127.0.0.1",
) -> Tuple[ThreadingHTTPServer, str]:
    """Serve ``render()`` at ``http://host:port/metrics`` from a daemon
    thread. Returns ``(server, url)``; call ``server.shutdown()`` to stop.
    """

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 - http.server API
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_error(404)
                return
            try:
                body = render().encode("utf-8")
            except Exception as e:  # render must never kill the server
                self.send_error(500, f"metrics render failed: {e}")
                return
            self.send_response(200)
            self.send_header("Content-Type", CONTENT_TYPE)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):  # quiet: no per-scrape stderr spam
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True,
                              name="obs-metrics-httpd")
    thread.start()
    url = f"http://{host}:{server.server_address[1]}/metrics"
    return server, url
