"""Prometheus text exposition (format 0.0.4) rendering and parsing.

The serving engine's ``metrics()`` renders through :func:`render`; tests
and the CI schema checker round-trip the text through :func:`parse` /
:func:`validate_text`. Only the subset of the format the repo emits is
supported: ``counter``/``gauge`` samples and ``histogram`` families
(cumulative ``_bucket{le=...}`` + ``_sum`` + ``_count``), with flat
string labels.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.hist import LatencyHistogram

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')

Labels = Dict[str, str]


class Metric:
    """One metric family to render: scalar samples or histograms."""

    def __init__(self, name: str, mtype: str, help: str,
                 samples: Optional[Sequence[Tuple[Labels, float]]] = None,
                 hists: Optional[
                     Sequence[Tuple[Labels, LatencyHistogram]]] = None):
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        if mtype not in ("counter", "gauge", "histogram"):
            raise ValueError(f"bad metric type {mtype!r}")
        self.name = name
        self.mtype = mtype
        self.help = help
        self.samples = list(samples or [])
        self.hists = list(hists or [])


def _fmt_labels(labels: Labels) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if isinstance(v, int):
        return str(v)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def render(metrics: Sequence[Metric]) -> str:
    """Render metric families as Prometheus exposition text."""
    lines: List[str] = []
    for m in metrics:
        lines.append(f"# HELP {m.name} {m.help}")
        lines.append(f"# TYPE {m.name} {m.mtype}")
        if m.mtype == "histogram":
            for labels, hist in m.hists:
                for le, cum in hist.cumulative_buckets():
                    lab = dict(labels, le=_fmt_value(le))
                    lines.append(
                        f"{m.name}_bucket{_fmt_labels(lab)} {cum}")
                lab = dict(labels, le="+Inf")
                lines.append(
                    f"{m.name}_bucket{_fmt_labels(lab)} {hist.total}")
                lines.append(
                    f"{m.name}_sum{_fmt_labels(labels)} "
                    f"{_fmt_value(hist.sum)}")
                lines.append(
                    f"{m.name}_count{_fmt_labels(labels)} {hist.total}")
        else:
            for labels, value in m.samples:
                lines.append(
                    f"{m.name}{_fmt_labels(labels)} {_fmt_value(value)}")
    return "\n".join(lines) + "\n"


# ------------------------------------------------------------------ #
# parsing / validation
# ------------------------------------------------------------------ #
def _parse_value(s: str) -> float:
    if s == "+Inf":
        return math.inf
    if s == "-Inf":
        return -math.inf
    if s == "NaN":
        return math.nan
    return float(s)


def parse(text: str) -> Dict[str, Dict]:
    """Parse exposition text into ``{family: {type, help, samples}}``.

    ``samples`` maps a sample name (``foo``, ``foo_bucket``, ...) to a list
    of ``(labels, value)`` pairs. Raises ``ValueError`` on malformed lines,
    samples without a preceding ``# TYPE``, or unparseable values.
    """
    families: Dict[str, Dict] = {}
    current: Optional[str] = None
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            name = parts[2]
            families.setdefault(
                name, {"type": None, "help": None, "samples": {}})
            families[name]["help"] = parts[3] if len(parts) > 3 else ""
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                raise ValueError(f"line {lineno}: malformed TYPE: {raw!r}")
            name, mtype = parts[2], parts[3]
            families.setdefault(
                name, {"type": None, "help": None, "samples": {}})
            families[name]["type"] = mtype
            current = name
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample: {raw!r}")
        sname = m.group("name")
        labels = dict(_LABEL_RE.findall(m.group("labels") or ""))
        value = _parse_value(m.group("value"))
        family = current
        if family is None or not (
                sname == family or sname.startswith(family + "_")):
            # sample outside its TYPE block: find the owning family
            family = next(
                (f for f in families
                 if sname == f or sname.startswith(f + "_")), None)
            if family is None:
                raise ValueError(
                    f"line {lineno}: sample {sname!r} has no # TYPE family")
        families[family]["samples"].setdefault(sname, []).append(
            (labels, value))
    return families


def validate_text(text: str, require: Sequence[str] = ()) -> List[str]:
    """Schema errors for exposition text ([] = valid).

    Beyond parseability: every family must carry a TYPE; histogram
    families must expose cumulative non-decreasing buckets ending at
    ``+Inf`` with ``_count`` equal to the ``+Inf`` bucket; ``require``
    lists family names that must be present.
    """
    errors: List[str] = []
    try:
        families = parse(text)
    except ValueError as e:
        return [str(e)]
    for name in require:
        if name not in families:
            errors.append(f"missing required metric family {name!r}")
    for name, fam in families.items():
        if fam["type"] is None:
            errors.append(f"{name}: no # TYPE line")
            continue
        if fam["type"] != "histogram":
            if name not in fam["samples"] and fam["samples"]:
                errors.append(f"{name}: {fam['type']} has no bare sample")
            continue
        buckets = fam["samples"].get(f"{name}_bucket", [])
        counts = fam["samples"].get(f"{name}_count", [])
        by_series: Dict[Tuple, List[Tuple[float, float]]] = {}
        for labels, value in buckets:
            key = tuple(sorted((k, v) for k, v in labels.items()
                               if k != "le"))
            by_series.setdefault(key, []).append(
                (_parse_value(labels.get("le", "NaN")), value))
        for key, series in by_series.items():
            series.sort(key=lambda t: t[0])
            les = [le for le, _ in series]
            vals = [v for _, v in series]
            if not les or not math.isinf(les[-1]):
                errors.append(f"{name}{dict(key)}: no +Inf bucket")
                continue
            if any(b > a for b, a in zip(vals, vals[1:])):
                errors.append(f"{name}{dict(key)}: buckets not cumulative")
            cnt = next((v for labels, v in counts
                        if tuple(sorted(labels.items())) == key), None)
            if cnt is not None and cnt != vals[-1]:
                errors.append(
                    f"{name}{dict(key)}: _count {cnt} != +Inf bucket "
                    f"{vals[-1]}")
    return errors
