"""Host-side span tracing: nested spans to a JSONL event log.

``span("name", attr=...)`` wraps the host-side phases of a run — snapshot
publish, ring-chunk execution, eval, checkpoint save/restore, serving
batch assembly — and records one event per span with monotonic
timestamps, duration, nesting depth and parent name. The module-level
:func:`span` dispatches to the *installed* tracer; the default is a
:class:`NullTracer` whose ``span`` returns a shared reusable no-op
context manager, so instrumented call sites cost one attribute load and
a no-op ``__enter__``/``__exit__`` when tracing is off — nothing is
formatted, allocated per-call, or written.

Span events (one JSON object per line)::

    {"type": "span", "name": "train.chunk", "ts": 12.031, "dur": 0.482,
     "depth": 0, "parent": null, "attrs": {"t0": 0, "t1": 25}}

``ts`` is seconds on the monotonic clock relative to tracer creation.
Nesting is tracked per thread, so concurrent serving threads produce
well-formed (if interleaved) span streams.
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

SPAN_REQUIRED_KEYS = ("type", "name", "ts", "dur", "depth")


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The no-op default: ``span`` hands back one shared null context."""

    def span(self, name: str, **attrs) -> Any:
        return _NULL_SPAN

    def close(self) -> None:
        pass


class _Span:
    __slots__ = ("tracer", "name", "attrs", "t0", "depth", "parent")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        stack = self.tracer._stack()
        self.depth = len(stack)
        self.parent = stack[-1] if stack else None
        stack.append(self.name)
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc) -> bool:
        dur = time.monotonic() - self.t0
        self.tracer._stack().pop()
        event = {
            "type": "span",
            "name": self.name,
            "ts": round(self.t0 - self.tracer.t0, 6),
            "dur": round(dur, 6),
            "depth": self.depth,
            "parent": self.parent,
        }
        if self.attrs:
            event["attrs"] = self.attrs
        self.tracer._write(event)
        return False


class Tracer:
    """Collect span events; persist to ``path`` (JSONL) or ``.events``."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.t0 = time.monotonic()
        self.events: List[Dict[str, Any]] = []
        self._fh = None
        self._lock = threading.Lock()
        self._local = threading.local()

    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def _write(self, event: Dict[str, Any]) -> None:
        with self._lock:
            if self.path is None:
                self.events.append(event)
                return
            if self._fh is None:
                os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
                self._fh = open(self.path, "w")
            self._fh.write(json.dumps(event, default=float) + "\n")
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


_active: Any = NullTracer()


def install_tracer(tracer: Optional[Any]) -> Any:
    """Install the process-global tracer (None reverts to the no-op).

    Returns the previously installed tracer so callers can restore it.
    """
    global _active
    previous = _active
    _active = tracer if tracer is not None else NullTracer()
    return previous


def active_tracer() -> Any:
    return _active


def span(name: str, **attrs) -> Any:
    """A span context on the installed tracer (no-op unless installed)."""
    return _active.span(name, **attrs)


def traced(name: Optional[str] = None):
    """Decorator form: wrap a function call in a span."""

    def deco(fn):
        span_name = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with _active.span(span_name):
                return fn(*args, **kwargs)

        return wrapper

    return deco


def validate_span_event(event: Any) -> List[str]:
    """Schema errors for one span event dict ([] = valid)."""
    errors: List[str] = []
    if not isinstance(event, dict):
        return [f"span event must be a dict, got {type(event).__name__}"]
    for key in SPAN_REQUIRED_KEYS:
        if key not in event:
            errors.append(f"span event missing key {key!r}")
    if errors:
        return errors
    if event["type"] != "span":
        errors.append(f"span event type must be 'span', got "
                      f"{event['type']!r}")
    if not isinstance(event["name"], str) or not event["name"]:
        errors.append("span name must be a non-empty string")
    for key in ("ts", "dur"):
        v = event[key]
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
            errors.append(f"span {key} must be a non-negative number, "
                          f"got {v!r}")
    d = event["depth"]
    if not isinstance(d, int) or isinstance(d, bool) or d < 0:
        errors.append(f"span depth must be a non-negative int, got {d!r}")
    parent = event.get("parent")
    if parent is not None and not isinstance(parent, str):
        errors.append(f"span parent must be null or a string, got {parent!r}")
    return errors
