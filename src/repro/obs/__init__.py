"""Observability layer: in-loop round telemetry, span tracing, metrics.

Zero-overhead-when-disabled by construction: every hook in the training
and serving paths is guarded by a *Python* flag checked at trace/build
time, so with :class:`ObsConfig` ``enabled=False`` (or no config at all)
the compiled programs are identical to a repo without this package —
enforced bit-for-bit by ``tests/test_obs.py`` for all four backends.

Modules:

  * :mod:`repro.obs.config`    — :class:`ObsConfig`, the single switch.
  * :mod:`repro.obs.telemetry` — :class:`RoundTelemetry` traced scalars
    computed inside the fused round step, the regret-tracking scan carry,
    and the JSONL round-event schema.
  * :mod:`repro.obs.sinks`     — pluggable event sinks (jsonl/csv/memory).
  * :mod:`repro.obs.trace`     — host-side nested span tracing (JSONL).
  * :mod:`repro.obs.hist`      — HDR-style latency histograms shared by
    the serving engine, the serving bench and the examples.
  * :mod:`repro.obs.prom`      — Prometheus text exposition + parser.
  * :mod:`repro.obs.httpd`     — stdlib ``/metrics`` endpoint.
  * :mod:`repro.obs.check`     — CLI validating an emitted artifact dir.
"""
from repro.obs.config import ObsConfig
from repro.obs.hist import LatencyHistogram
from repro.obs.sinks import CsvSink, InMemorySink, JsonlSink, Sink
from repro.obs.telemetry import (
    TELEMETRY_FIELDS, RoundTelemetry, TelemetryState, rows_to_events,
    telemetry_round, telemetry_state_init, validate_round_event,
)
from repro.obs.trace import NullTracer, Tracer, install_tracer, span, traced

__all__ = [
    "ObsConfig", "LatencyHistogram",
    "Sink", "InMemorySink", "JsonlSink", "CsvSink",
    "TELEMETRY_FIELDS", "RoundTelemetry", "TelemetryState",
    "telemetry_state_init", "telemetry_round", "rows_to_events",
    "validate_round_event",
    "Tracer", "NullTracer", "install_tracer", "span", "traced",
]
