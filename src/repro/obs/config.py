"""The observability switch: one config object spanning all layers.

``ObsConfig`` rides on :class:`repro.federated.simulation.FLSimConfig`
(``obs=``) and :class:`repro.serve.ServingEngine` (``obs=``). The hard
contract: with ``enabled=False`` (or no config at all) every consumer
skips its instrumentation at Python/trace time, so the compiled training
programs and the serving read path are bit-identical to a build without
the obs layer — enforced by ``tests/test_obs.py`` for all four backends.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.obs.sinks import InMemorySink, Sink
from repro.obs.trace import Tracer


@dataclass
class ObsConfig:
    # master switch: False compiles every telemetry op out (bit-parity)
    enabled: bool = False
    # emit a round event every N committed rounds (host-side rate limit on
    # the batched io_callback stream; 1 = every round)
    telemetry_every: int = 1
    # round-telemetry sink; None lazily defaults to an InMemorySink
    sink: Optional[Sink] = None
    # span-trace JSONL path; None disables host span tracing
    trace_path: Optional[str] = None
    # jax.profiler.trace output dir wrapped around the scan chunks of one
    # training run; None disables profiling
    profile_dir: Optional[str] = None
    _tracer: Optional[Tracer] = field(
        default=None, repr=False, compare=False)

    def validate(self) -> None:
        if self.telemetry_every < 1:
            raise ValueError(
                f"telemetry_every must be >= 1, got {self.telemetry_every}")

    def resolve_sink(self) -> Sink:
        """The configured sink, defaulting (and caching) an in-memory one."""
        if self.sink is None:
            self.sink = InMemorySink()
        return self.sink

    def resolve_tracer(self) -> Optional[Tracer]:
        """A (cached) Tracer for ``trace_path``; None when tracing is off."""
        if self.trace_path is None:
            return None
        if self._tracer is None:
            self._tracer = Tracer(self.trace_path)
        return self._tracer

    def close(self) -> None:
        """Flush file-backed sinks and the tracer (idempotent)."""
        if self.sink is not None:
            self.sink.close()
        if self._tracer is not None:
            self._tracer.close()
