"""Round-based federated simulation of FCF / FCF-BTS / FCF-Random (Sec. 6).

Each FL iteration t:
  1. server (bandit) selects the payload subset and publishes Q*        | Alg.1
  2. a cohort of Theta users is sampled (simulating the asynchronous    |
     arrival of exactly-Theta updates that triggers a global commit),   |
  3. each user solves its private p_i from (Q*, x_i) and returns the    |
     item gradients; the simulation computes the cohort in one vmap'd   |
     jit call but the server only ever sees the aggregate,              |
  4. server commits: sparse Adam on selected rows, reward + BTS update. |

Evaluation (Sec. 6.2): every ``eval_every`` rounds, a fixed user sample
downloads the *full* global model (the paper's inference-time download),
solves p_i on train data and computes normalized P/R/F1/MAP@10 on the
held-out 20%; the reported trajectory applies the paper's trailing-10
smoothing at read-out time.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cf.local import local_update
from repro.cf.metrics import RecMetrics, evaluate_users
from repro.cf.model import CFConfig, cf_init
from repro.cf.server import FCFServer, FCFServerConfig
from repro.core.payload import make_selector
from repro.utils.logging import MetricLogger, get_logger

log = get_logger("repro.fl")


@dataclass
class FLSimConfig:
    strategy: str = "bts"            # bts | random | full | magnitude
    keep_fraction: float = 0.1       # payload kept per round (0.1 = 90% cut)
    rounds: int = 1000
    theta: int = 100                 # users per global commit (paper Sec. 6.1)
    num_factors: int = 25
    l2: float = 1.0
    alpha: float = 4.0
    lr: float = 0.01
    beta1: float = 0.1
    beta2: float = 0.99
    gamma: float = 0.999
    mu_theta: float = 0.0
    tau_theta: float = 10_000.0
    reward_mode: str = "geometric"
    reward_feedback: str = "data_term"   # "raw" = paper-literal feedback
    reward_norm: bool = True             # per-round reward standardization
    eval_every: int = 25
    eval_users: int = 512
    seed: int = 0


@dataclass
class SimResult:
    final: Dict[str, float]
    history: MetricLogger
    bytes_down: int
    bytes_up: int
    rounds: int
    selection_counts: np.ndarray

    def smoothed(self, key: str, window: int = 10) -> float:
        return self.history.rolling_mean(key, window)


def run_fcf_simulation(
    train_x: np.ndarray,
    test_x: np.ndarray,
    config: FLSimConfig,
    csv_path: Optional[str] = None,
) -> SimResult:
    num_users, num_items = train_x.shape
    key = jax.random.PRNGKey(config.seed)
    k_init, k_users, k_eval = jax.random.split(key, 3)

    cf_cfg = CFConfig(
        num_users=num_users, num_items=num_items,
        num_factors=config.num_factors, l2=config.l2, alpha=config.alpha,
    )
    model = cf_init(cf_cfg, k_init)

    selector = make_selector(
        config.strategy, num_arms=num_items, dim=config.num_factors,
        keep_fraction=config.keep_fraction, gamma=config.gamma,
        beta2=config.beta2, mu_theta=config.mu_theta,
        tau_theta=config.tau_theta, reward_mode=config.reward_mode,
        reward_norm=config.reward_norm,
        seed=config.seed + 13,
    )
    server = FCFServer(
        item_factors=model.item_factors, selector=selector,
        config=FCFServerConfig(theta=config.theta,
                               reward_feedback=config.reward_feedback,
                               l2=config.l2),
    )
    server.config.adam = server.config.adam._replace(
        lr=config.lr, beta1=config.beta1, beta2=config.beta2)

    train_j = jnp.asarray(train_x, jnp.float32)
    test_j = jnp.asarray(test_x, jnp.float32)

    # fixed evaluation cohort (same across strategies given the same seed)
    eval_n = min(config.eval_users, num_users)
    eval_ids = jax.random.choice(k_eval, num_users, (eval_n,), replace=False)
    eval_train = train_j[eval_ids]
    eval_test = test_j[eval_ids]

    history = MetricLogger(csv_path)
    rng = np.random.default_rng(config.seed + 31)

    for t in range(1, config.rounds + 1):
        q_star = server.begin_round()
        cohort = rng.choice(num_users, size=min(config.theta, num_users), replace=False)
        x_sub = train_j[jnp.asarray(cohort)][:, server.selected]    # (Theta, M_s)
        _, grads = local_update(q_star, x_sub, cf_cfg)
        server.receive(grads, num_users=len(cohort))

        if t % config.eval_every == 0 or t == config.rounds:
            m = evaluate_users(
                server.item_factors, eval_train, eval_test,
                l2=config.l2, alpha=config.alpha,
            )
            history.log(t, **m.as_dict())

    final = {
        k: history.rolling_mean(k, 10)
        for k in ("precision", "recall", "f1", "map")
    }
    if csv_path:
        history.to_csv()
    return SimResult(
        final=final, history=history,
        bytes_down=server.bytes_down, bytes_up=server.bytes_up,
        rounds=server.rounds_committed,
        selection_counts=selector.selection_counts(),
    )
