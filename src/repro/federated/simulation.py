"""Round-based federated simulation of FCF / FCF-BTS / FCF-Random (Sec. 6).

Functional-core round engine. Each FL iteration t (Alg. 1):
  1. server (bandit) selects the payload subset and publishes Q*,
  2. a cohort of Theta users is sampled (simulating the asynchronous
     arrival of exactly-Theta updates that triggers a global commit),
  3. each user solves its private p_i from (Q*, x_i) and returns the
     item gradients; the server only ever sees the cohort aggregate,
  4. server commits: scatter-based sparse Adam on the selected rows,
     reward + BTS posterior update.

The whole round is ONE pure function (:func:`repro.cf.server.server_round_step`)
and the training loop is compiled end-to-end:

  * ``backend="scan"`` (default): cohort indices for all rounds are
    pre-sampled, the loop runs as ``jax.lax.scan`` over the fused step in
    chunks of ``eval_every`` rounds, with evaluation between chunks
    ("periodic chunked evaluation"). One compile, zero per-round Python
    dispatch — the engine for thousand-round experiment grids.
  * ``backend="python"``: the same jitted step driven round-by-round from
    Python. Kept as the reference implementation for equivalence testing
    (same PRNG seed => bit-identical selections, Q trajectory and byte
    counters) and as the dispatch-overhead baseline for
    ``benchmarks/round_engine.py``.
  * ``backend="async"``: the staleness-bounded async cohort engine
    (:func:`repro.cf.server.server_round_step_async`) — every round
    publishes a fresh encoded snapshot into a bounded ring and commits a
    cohort that solved against a snapshot up to ``max_staleness`` rounds
    old (the paper's deployment model, where exactly-Theta updates arrive
    asynchronously and may lag the global model). The staleness schedule is
    pre-sampled like the cohorts, so the whole async trajectory is one
    ``lax.scan``; ``max_staleness=0`` is bit-identical to ``backend="scan"``
    at equal cohort blocking. Composes with the sharded engine: set
    ``mesh_shards`` to run the async rounds under ``shard_map`` (the ring
    and pending buffers replicate — payload-sized — while the (M, K)
    tables row-shard exactly as in ``backend="shard"``).

Sweep entry points (:func:`run_seed_sweep`, :func:`run_strategy_sweep`)
vectorize the scan engine with ``jax.vmap`` over per-seed server states, so a
multi-rebuild experiment cell runs as a single compiled program.

Evaluation (Sec. 6.2): every ``eval_every`` rounds, a fixed user sample
downloads the *full* global model (the paper's inference-time download),
solves p_i on train data and computes normalized P/R/F1/MAP@10 on the
held-out 20%; the reported trajectory applies the paper's trailing-10
smoothing at read-out time.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cf.metrics import RecMetrics, evaluate_users
from repro.cf.model import CFConfig, cf_init
from repro.cf.server import (
    FCFServerConfig, RoundAux, ServerState, ShardContext, server_init,
    server_round_step, server_round_step_async,
)
from repro.checkpoint.io import (
    checkpoint_step, latest_verified_checkpoint, load_checkpoint,
    save_checkpoint,
)
from repro.compress import (
    CodecConfig, direction_configs, validate_config, wire_bytes,
)
from repro.faults import (
    FaultConfig, FaultSchedule, SimulatedCrash, build_fault_schedule,
    fault_state_init, round_faults_xs,
)
from repro.core.selector import (
    STRATEGIES, SelectorConfig, selector_counts,
)
from repro.obs.config import ObsConfig
from repro.obs.telemetry import (
    make_row_emitter, telemetry_round, telemetry_state_init,
)
from repro.obs.trace import install_tracer, span
from repro.optim.adam import AdamConfig
from repro.optim.state_compress import (
    MomentCodecConfig, validate_config as validate_moment_config,
)
from repro.utils.logging import MetricLogger, get_logger

log = get_logger("repro.fl")

BACKENDS = ("scan", "python", "shard", "async")
STALENESS_MODES = ("uniform", "max")


@dataclass
class FLSimConfig:
    strategy: str = "bts"            # bts | random | full | magnitude
    keep_fraction: float = 0.1       # payload kept per round (0.1 = 90% cut)
    rounds: int = 1000
    theta: int = 100                 # users per global commit (paper Sec. 6.1)
    num_factors: int = 25
    l2: float = 1.0
    alpha: float = 4.0
    lr: float = 0.01
    beta1: float = 0.1
    beta2: float = 0.99
    gamma: float = 0.999
    mu_theta: float = 0.0
    tau_theta: float = 10_000.0
    reward_mode: str = "geometric"
    reward_feedback: str = "data_term"   # "raw" = paper-literal feedback
    reward_norm: bool = True             # per-round reward standardization
    # payload wire format (repro.compress): fp32 | fp16 | int8 | int4 | topk
    codec: str = "fp32"
    # optimizer-state storage (repro.optim.state_compress): how Adam's
    # per-row moments live in server memory. fp32/fp32 (the default) is the
    # frozen path — programs bit-identical to every historical run. Other
    # choices (m: fp32|bf16|int8; v: fp32|bf16|int8|factored) shrink the
    # resident optimizer state (benchmarks/optimizer_state.py).
    moment_m_dtype: str = "fp32"
    moment_v_dtype: str = "fp32"
    # int8 moment writes round stochastically (unbiased) when True
    moment_stochastic_rounding: bool = True
    codec_topk_fraction: float = 0.25    # topk: fraction of dim kept per row
    codec_error_feedback: bool = True    # topk: carry the EF residual
    codec_int4_error_feedback: bool = False  # int4: carry the EF residual
    eval_every: int = 25
    eval_users: int = 512
    # evaluate the eval cohort in user-chunks of this size (None = one shot);
    # bounds the (B, M) score matrix at web-scale M
    eval_user_chunk: Optional[int] = None
    # item-block size for the fused chunked scorer during periodic eval
    # (kernels.wire_topn — no (B, M) score matrix). None = auto: engage at
    # block 4096 whenever eval_user_chunk is set, else keep the one-shot
    # dense path. Bit-identical either way (tested in test_serving.py).
    eval_item_chunk: Optional[int] = None
    # "scan" (default engine) | "python" (reference) | "shard" (shard_map
    # data-parallel rounds over a ("data",) device mesh) | "async"
    # (staleness-bounded async cohort queue; composes with mesh_shards)
    backend: str = "scan"
    # backend="async": a commit may land on a snapshot up to this many
    # rounds stale (ring depth = max_staleness + 1); 0 = synchronous
    max_staleness: int = 0
    # backend="async": client-phase block count per commit (the async
    # engine's cohort blocking — max_staleness=0 with blocks_per_commit=B is
    # bit-identical to backend="scan" with cohort_shards=B). Under
    # mesh_shards=D the mesh dictates one block per device: any other
    # explicit value is rejected at build time.
    blocks_per_commit: int = 1
    # backend="async": per-round staleness draw. "uniform" samples
    # s ~ U{0..max_staleness} (independent reporting lags); "max" pins
    # s = max_staleness — the saturation regime where the queue is always
    # full and every commit is maximally stale. Both clamp s <= t-1.
    staleness_mode: str = "uniform"
    # backend="async": Adam step discount**s for an s-stale commit
    staleness_discount: float = 0.8
    # client-phase block count: the cohort solve runs in this many equal user
    # blocks whose partial gradients are reduced in fixed order (see
    # server_round_step). The round's float semantics depend on this number
    # ONLY — backend="shard" over D devices is bit-identical to
    # backend="scan" with cohort_shards=D.
    cohort_shards: int = 1
    # backend="shard": devices on the "data" mesh axis (None = all local
    # devices). Overrides cohort_shards (one cohort block per device).
    mesh_shards: Optional[int] = None
    record_selections: bool = False      # surface per-round indices/rewards
    # serving publish hook, called at every eval boundary with
    # (round, server_state). repro.serve.ServingEngine.publisher() returns
    # one that installs the state's freshest encoded ring snapshot
    # (backend="async") — or an encoded full table otherwise — as the live
    # serving model without ever round-tripping through a dense fp32 Q.
    snapshot_hook: Optional[Callable[[int, ServerState], None]] = None
    # observability (repro.obs.ObsConfig): in-loop round telemetry streamed
    # through a batched io_callback, host span tracing, optional profiler
    # hook. None or enabled=False adds ZERO ops — trajectories stay
    # bit-identical (tests/test_obs.py). Single-run engines only; the
    # vmapped sweeps reject an enabled config.
    obs: Optional[ObsConfig] = None
    # fault injection (repro.faults.FaultConfig): deterministic pre-sampled
    # client dropout / straggler timeouts / wire-row corruption / simulated
    # host crash, threaded through the compiled engines as scan xs. None or
    # enabled=False adds ZERO ops — trajectories stay bit-identical
    # (tests/test_faults.py). Single-run engines only; mutually exclusive
    # with an enabled obs config (both re-plumb the same scan programs).
    faults: Optional[FaultConfig] = None
    # round-checkpoint directory: at every eval boundary the full ServerState
    # is written with atomic temp+rename and a sha256 sidecar
    # (repro.checkpoint.io). None disables checkpointing.
    checkpoint_dir: Optional[str] = None
    # crash-resume: a checkpoint FILE to resume from, or a DIRECTORY whose
    # newest hash-verified checkpoint is used. Training skips every round
    # the checkpoint already committed; because cohorts, staleness and
    # faults are pre-sampled schedules, the resumed trajectory is
    # bit-identical to an uninterrupted run (tests/test_faults.py). A
    # resumed config should clear faults.crash_round (or the run re-crashes
    # at the same round).
    resume_from: Optional[str] = None
    seed: int = 0


@dataclass
class SimResult:
    final: Dict[str, float]
    history: MetricLogger
    bytes_down: int
    bytes_up: int
    rounds: int
    selection_counts: np.ndarray
    # per-round (rounds, M_s) selected indices / rewards, populated only
    # when config.record_selections (equivalence tests, selection audits)
    selections: Optional[np.ndarray] = None
    rewards: Optional[np.ndarray] = None
    # the raw final server pytree (traced byte counters included)
    server_state: Optional[ServerState] = field(default=None, repr=False)
    # snapshot_hook invocations that raised (training continues; a serving
    # publish failure must never abort the round loop)
    hook_failures: int = 0

    def smoothed(self, key: str, window: int = 10) -> float:
        return self.history.rolling_mean(key, window)


# ===================================================================== #
# setup
# ===================================================================== #
class _SimSetup(NamedTuple):
    cf_cfg: CFConfig
    sel_cfg: SelectorConfig
    srv_cfg: FCFServerConfig
    codec_cfg: CodecConfig
    state0: ServerState
    cohorts: np.ndarray        # (rounds, B) int32 pre-sampled cohort ids
    staleness: np.ndarray      # (rounds,) int32 pre-sampled snapshot ages
    eval_train: jax.Array      # (E, M)
    eval_test: jax.Array       # (E, M)
    # pre-sampled fault schedule (repro.faults), None when faults are off
    fault_sched: Optional[FaultSchedule] = None


def _num_select(config: FLSimConfig, num_items: int) -> int:
    if config.strategy == "full":
        return num_items
    return max(1, int(round(config.keep_fraction * num_items)))


def _chunk_bounds(rounds: int, eval_every: int) -> List[Tuple[int, int]]:
    """[(start, end)] chunks whose right edges are the evaluation rounds."""
    points = sorted({t for t in range(eval_every, rounds + 1, eval_every)}
                    | {rounds})
    bounds, start = [], 0
    for p in points:
        bounds.append((start, p))
        start = p
    return bounds


def _build(train_j: jax.Array, test_j: jax.Array,
           config: FLSimConfig) -> _SimSetup:
    """Pure-data setup shared by every backend: states, cohorts, eval split.

    PRNG discipline matches the legacy stateful path: PRNGKey(seed) splits
    into (init, users, eval); the selection stream is PRNGKey(seed+13) split
    once per round; cohorts come from numpy default_rng(seed+31); the async
    staleness schedule from default_rng(seed+47).
    """
    if config.strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {config.strategy!r}")
    if config.backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, "
                         f"got {config.backend!r}")
    is_async = config.backend == "async"
    if config.max_staleness < 0:
        raise ValueError(
            f"max_staleness must be >= 0, got {config.max_staleness}")
    if config.max_staleness > 0 and not is_async:
        raise ValueError(
            "max_staleness > 0 requires backend='async' (the synchronous "
            "backends commit the snapshot they just published)")
    if is_async and config.staleness_mode not in STALENESS_MODES:
        raise ValueError(
            f"staleness_mode must be one of {STALENESS_MODES}, "
            f"got {config.staleness_mode!r}")
    if is_async and config.blocks_per_commit < 1:
        raise ValueError(
            f"blocks_per_commit must be >= 1, got {config.blocks_per_commit}")
    if config.obs is not None:
        config.obs.validate()
    fault_cfg = config.faults
    fault_on = fault_cfg is not None and fault_cfg.enabled
    if fault_cfg is not None:
        fault_cfg.validate()
    if fault_on and config.obs is not None and config.obs.enabled:
        raise ValueError(
            "config.faults and config.obs cannot both be enabled: both "
            "re-plumb the compiled round scans, and their composition is "
            "untested — run the faulted trajectory without telemetry")
    if is_async and config.mesh_shards is not None \
            and config.blocks_per_commit not in (1, config.mesh_shards):
        raise ValueError(
            f"backend='async' with mesh_shards={config.mesh_shards} runs "
            f"one cohort block per device; blocks_per_commit="
            f"{config.blocks_per_commit} conflicts (leave it at 1 or set "
            f"it equal to mesh_shards)")
    num_users, num_items = train_j.shape
    key = jax.random.PRNGKey(config.seed)
    k_init, _k_users, k_eval = jax.random.split(key, 3)

    cf_cfg = CFConfig(
        num_users=num_users, num_items=num_items,
        num_factors=config.num_factors, l2=config.l2, alpha=config.alpha,
    )
    sel_cfg = SelectorConfig(
        strategy=config.strategy, num_arms=num_items,
        num_select=_num_select(config, num_items), dim=config.num_factors,
        gamma=config.gamma, beta2=config.beta2, mu_theta=config.mu_theta,
        tau_theta=config.tau_theta, reward_mode=config.reward_mode,
        reward_norm=config.reward_norm,
    )
    moment_cfg = None
    if (config.moment_m_dtype, config.moment_v_dtype) != ("fp32", "fp32"):
        moment_cfg = MomentCodecConfig(
            m_dtype=config.moment_m_dtype, v_dtype=config.moment_v_dtype,
            stochastic_rounding=config.moment_stochastic_rounding)
        validate_moment_config(moment_cfg)
    srv_cfg = FCFServerConfig(
        theta=config.theta,
        adam=AdamConfig(lr=config.lr, beta1=config.beta1,
                        beta2=config.beta2, eps=1e-8),
        reward_feedback=config.reward_feedback, l2=config.l2,
        staleness_discount=config.staleness_discount,
        moment=moment_cfg,
    )
    codec_cfg = CodecConfig(
        name=config.codec, topk_fraction=config.codec_topk_fraction,
        error_feedback=config.codec_error_feedback,
        int4_error_feedback=config.codec_int4_error_feedback,
    )
    validate_config(codec_cfg)
    model = cf_init(cf_cfg, k_init)
    state0 = server_init(
        model.item_factors, sel_cfg,
        key=jax.random.PRNGKey(config.seed + 13),
        config=srv_cfg, codec_cfg=codec_cfg,
        async_slots=(config.max_staleness + 1) if is_async else None,
        force_residual=fault_on and fault_cfg.corrupt_rate > 0.0)
    if fault_on:
        state0 = state0._replace(faults=fault_state_init())

    cohort_n = min(config.theta, num_users)
    rng = np.random.default_rng(config.seed + 31)
    cohorts = np.stack([
        rng.choice(num_users, size=cohort_n, replace=False)
        for _ in range(config.rounds)
    ]).astype(np.int32)
    staleness = _staleness_schedule(config)
    fault_sched = None
    if fault_on:
        fault_sched = build_fault_schedule(
            fault_cfg, config.rounds, cohort_n, sel_cfg.num_select,
            config.seed)

    eval_n = min(config.eval_users, num_users)
    eval_ids = jax.random.choice(k_eval, num_users, (eval_n,), replace=False)
    return _SimSetup(
        cf_cfg=cf_cfg, sel_cfg=sel_cfg, srv_cfg=srv_cfg,
        codec_cfg=codec_cfg, state0=state0,
        cohorts=cohorts, staleness=staleness,
        eval_train=train_j[eval_ids], eval_test=test_j[eval_ids],
        fault_sched=fault_sched,
    )


def _staleness_schedule(config: FLSimConfig) -> np.ndarray:
    """Pre-sampled per-round snapshot ages for the async engine.

    Round t's commit lands on the snapshot published at round t - s_t. The
    schedule is data, exactly like the cohort schedule: "uniform" draws
    independent reporting lags s ~ U{0..S}, "max" pins every commit at the
    staleness bound (queue saturated). Either way s_t <= t-1, so the first
    rounds never reference snapshots that do not exist yet. All-zero for the
    synchronous backends (and for max_staleness=0, where the async engine
    reduces to the scan engine bit-for-bit).
    """
    rounds, s_max = config.rounds, config.max_staleness
    if config.backend != "async" or s_max == 0:
        return np.zeros((rounds,), np.int32)
    if config.staleness_mode == "max":
        s = np.full((rounds,), s_max, np.int64)
    else:
        rng = np.random.default_rng(config.seed + 47)
        s = rng.integers(0, s_max + 1, size=rounds)
    return np.minimum(s, np.arange(rounds)).astype(np.int32)


def _blocked_cohort_x(train_j: jax.Array, ids: jax.Array, shards: int,
                      num_users: int, survivors: Optional[jax.Array] = None):
    """Lazy blocked cohort slice for the round step.

    ``ids`` is the flat (possibly padded) cohort id vector this caller owns
    (the full padded cohort on a single device, one block of it per device
    under ``shard_map``). Returns ``idx -> (C_local, b, M_s)`` where padded
    user rows are zeroed — an all-zero x row solves to p=0 and contributes
    exactly zero to every aggregate, so padding never changes the math.

    ``survivors`` ((total,) f32, the fault layer's padded per-slot keep
    vector) additionally zeroes dropped/straggling users' rows — the same
    exact-no-op mechanism as padding, composed multiplicatively with the
    static pad mask. ``None`` compiles the historical closure untouched.
    """
    total = ids.shape[0]
    c_local = shards
    b = total // shards

    def cohort_x(idx):
        # one fused (user-row x item-column) gather once the payload subset
        # is known, instead of a (B, M) copy per round
        x = train_j[ids[:, None], idx[None, :]]              # (total, M_s)
        if num_users < total:
            mask = (jnp.arange(total) < num_users).astype(x.dtype)
            x = x * mask[:, None]
        if survivors is not None:
            x = x * survivors.astype(x.dtype)[:, None]
        return x.reshape(c_local, b, idx.shape[0])

    return cohort_x


def _pad_cohort(cohort: jax.Array, shards: int) -> jax.Array:
    """Pad a flat (B,) cohort id vector to a multiple of ``shards``.

    Pad entries reuse user id 0; their interaction rows are masked to zero
    by :func:`_blocked_cohort_x` so they are exact no-ops.
    """
    b_total = cohort.shape[0]
    b = -(-b_total // shards)
    return jnp.pad(cohort, (0, shards * b - b_total))


def _make_round_fn(train_j: jax.Array, setup: _SimSetup,
                   cohort_shards: int = 1, telemetry: bool = False,
                   fault_on: bool = False):
    """(state, cohort_ids (B,)) -> (state, RoundAux): one fused FL round.

    With ``fault_on`` (static) the returned step additionally consumes this
    round's :class:`repro.faults.RoundFaults` slice: dropped/straggling
    users are zeroed out of the cohort (exact no-op rows) and the gradient
    renormalizes over the traced survivor count; the ``fault_on=False``
    program is byte-for-byte the historical one.
    """
    sel_cfg, srv_cfg, cf_cfg = setup.sel_cfg, setup.srv_cfg, setup.cf_cfg

    if fault_on:
        def faulted_round_fn(state: ServerState, cohort: jax.Array, rf):
            num_users = cohort.shape[0]
            ids = _pad_cohort(cohort, cohort_shards)
            cohort_x = _blocked_cohort_x(train_j, ids, cohort_shards,
                                         num_users, survivors=rf.survivors)
            n_eff = jnp.sum(rf.survivors)
            return server_round_step(
                state, cohort_x, sel_cfg=sel_cfg, config=srv_cfg,
                cf_cfg=cf_cfg, codec_cfg=setup.codec_cfg, num_users=n_eff,
                telemetry=telemetry, faults=rf)

        return faulted_round_fn

    def round_fn(state: ServerState, cohort: jax.Array):
        num_users = cohort.shape[0]
        ids = _pad_cohort(cohort, cohort_shards)
        cohort_x = _blocked_cohort_x(train_j, ids, cohort_shards, num_users)
        return server_round_step(
            state, cohort_x, sel_cfg=sel_cfg, config=srv_cfg, cf_cfg=cf_cfg,
            codec_cfg=setup.codec_cfg, num_users=num_users,
            telemetry=telemetry)

    return round_fn


def _make_async_round_fn(train_j: jax.Array, setup: _SimSetup, blocks: int,
                         telemetry: bool = False, fault_on: bool = False):
    """(state, cohort (B,), staleness ()) -> (state, aux): one async round.

    ``fault_on`` mirrors :func:`_make_round_fn`: the faulted step takes a
    trailing :class:`repro.faults.RoundFaults` argument.
    """
    sel_cfg, srv_cfg, cf_cfg = setup.sel_cfg, setup.srv_cfg, setup.cf_cfg

    if fault_on:
        def faulted_round_fn(state: ServerState, cohort: jax.Array,
                             staleness: jax.Array, rf):
            num_users = cohort.shape[0]
            ids = _pad_cohort(cohort, blocks)
            cohort_x = _blocked_cohort_x(train_j, ids, blocks, num_users,
                                         survivors=rf.survivors)
            n_eff = jnp.sum(rf.survivors)
            return server_round_step_async(
                state, cohort_x, staleness, sel_cfg=sel_cfg, config=srv_cfg,
                cf_cfg=cf_cfg, codec_cfg=setup.codec_cfg, num_users=n_eff,
                telemetry=telemetry, faults=rf)

        return faulted_round_fn

    def round_fn(state: ServerState, cohort: jax.Array,
                 staleness: jax.Array):
        num_users = cohort.shape[0]
        ids = _pad_cohort(cohort, blocks)
        cohort_x = _blocked_cohort_x(train_j, ids, blocks, num_users)
        return server_round_step_async(
            state, cohort_x, staleness, sel_cfg=sel_cfg, config=srv_cfg,
            cf_cfg=cf_cfg, codec_cfg=setup.codec_cfg, num_users=num_users,
            telemetry=telemetry)

    return round_fn


def make_sharded_round_runner(train_j: jax.Array, setup: _SimSetup,
                              config: FLSimConfig, record: bool = False,
                              obs: Optional[ObsConfig] = None):
    """Compile the FL round scan as a ``shard_map`` program over a device mesh.

    Returns ``(run_chunk, state0)``: ``run_chunk(state, cohorts (R, B) np)``
    scans R data-parallel rounds, ``state0`` is the initial server state with
    its (M, K) tables row-sharded over the ("data",) mesh (everything else
    replicated). Each device holds M/D rows of Q / Adam moments / BTS reward
    buffers / codec residual and solves one cohort block of ceil(B/D) users
    per round; per round only payload-sized tensors cross the interconnect
    (encoded Q* candidates, partial gradients, selected-row gathers).
    Trajectories are bit-identical to ``backend="scan"`` with
    ``cohort_shards=D`` (see :func:`repro.cf.server.server_round_step`).

    With ``config.backend == "async"`` the same mesh runs the async engine:
    the scan additionally consumes the (R,) staleness schedule (replicated),
    the snapshot ring and pending-attribution buffers replicate alongside
    the selector posteriors (they are payload-sized), and the returned
    ``run_chunk(state, cohorts, staleness)`` takes the schedule slice —
    a stale block is just a block solved against an older Q*, so the
    collective schedule is exactly the synchronous one.

    ``obs`` (an *enabled* :class:`ObsConfig`) additionally threads the
    replicated telemetry aggregates through the scan carry and returns the
    per-round telemetry rows from the compiled program; ``run_chunk``
    emits them host-side after each chunk (the rows come back replicated,
    so the host emission is mesh-safe without putting an ``io_callback``
    inside ``shard_map``). ``obs=None`` leaves the original programs
    byte-for-byte untouched.
    """
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_data_mesh
    from repro.launch.sharding import fcf_state_pspecs, to_shardings
    from repro.utils.compat import shard_map

    d = config.mesh_shards or len(jax.devices())
    m = setup.cf_cfg.num_items
    if m % d:
        raise ValueError(
            f"backend='shard' row-shards the (M, K) tables: num_items={m} "
            f"must divide evenly over {d} devices")
    mesh = make_data_mesh(d)
    b_total = setup.cohorts.shape[1]
    b = -(-b_total // d)                  # users per device block
    shard_ctx = ShardContext(axis="data", num_shards=d, rows_per_shard=m // d)
    sel_cfg, srv_cfg, cf_cfg = setup.sel_cfg, setup.srv_cfg, setup.cf_cfg
    padded = d * b != b_total

    state_specs = fcf_state_pspecs(setup.state0)
    state0 = jax.device_put(setup.state0, to_shardings(mesh, state_specs))
    is_async = config.backend == "async"
    aux_specs = RoundAux(indices=P(), rewards=P()) if record else None
    telemetry = obs is not None
    fault_on = config.faults is not None and config.faults.enabled

    def _local_cohort_x(ids, didx, train_rep, survivors=None):
        # ``survivors`` is the full replicated (d*b,) padded keep vector;
        # each device slices out its own block so the zeroing matches the
        # single-device blocked closure exactly
        def cohort_x(idx):
            x = train_rep[ids[:, None], idx[None, :]]        # (b, M_s)
            if padded:
                pos = didx * b + jnp.arange(b)
                x = x * (pos < b_total).astype(x.dtype)[:, None]
            if survivors is not None:
                local = jax.lax.dynamic_slice_in_dim(survivors, didx * b, b)
                x = x * local.astype(x.dtype)[:, None]
            return x[None]                                   # (1, b, M_s)
        return cohort_x

    if telemetry:
        # telemetry variants: the replicated TelemetryState rides the scan
        # carry, every round's packed row is a replicated (15,) ys output.
        # The non-telemetry programs below stay byte-for-byte untouched —
        # that, not cleverness, is what makes the disabled-path bit-parity
        # contract trivially true for the sharded engine too.
        tel0 = telemetry_state_init(sel_cfg.num_arms)
        tel_specs = jax.tree.map(lambda _: P(), tel0)
        emitter = make_row_emitter(obs.resolve_sink(), obs.telemetry_every)

        if is_async:
            def chunk(state, tel, cohorts_blk, stale, train_rep):
                def body(carry, xs):
                    st, ts = carry
                    cohort_l, s_t = xs
                    cohort_x = _local_cohort_x(
                        cohort_l.reshape(-1), jax.lax.axis_index("data"),
                        train_rep)
                    st, aux = server_round_step_async(
                        st, cohort_x, s_t, sel_cfg=sel_cfg, config=srv_cfg,
                        cf_cfg=cf_cfg, codec_cfg=setup.codec_cfg,
                        num_users=b_total, shard=shard_ctx, telemetry=True)
                    ts, row = telemetry_round(
                        ts, aux.telemetry, aux.indices, aux.rewards)
                    ys = aux._replace(telemetry=()) if record else None
                    return (st, ts), (ys, row)

                (state, tel), (ys, rows) = jax.lax.scan(
                    body, (state, tel), (cohorts_blk, stale))
                return state, tel, ys, rows

            run = jax.jit(shard_map(
                chunk, mesh=mesh,
                in_specs=(state_specs, tel_specs,
                          P(None, "data", None), P(), P()),
                out_specs=(state_specs, tel_specs, aux_specs, P()),
                check_vma=False))
        else:
            def chunk(state, tel, cohorts_blk, train_rep):
                def body(carry, cohort_l):
                    st, ts = carry
                    cohort_x = _local_cohort_x(
                        cohort_l.reshape(-1), jax.lax.axis_index("data"),
                        train_rep)
                    st, aux = server_round_step(
                        st, cohort_x, sel_cfg=sel_cfg, config=srv_cfg,
                        cf_cfg=cf_cfg, codec_cfg=setup.codec_cfg,
                        num_users=b_total, shard=shard_ctx, telemetry=True)
                    ts, row = telemetry_round(
                        ts, aux.telemetry, aux.indices, aux.rewards)
                    ys = aux._replace(telemetry=()) if record else None
                    return (st, ts), (ys, row)

                (state, tel), (ys, rows) = jax.lax.scan(
                    body, (state, tel), cohorts_blk)
                return state, tel, ys, rows

            run = jax.jit(shard_map(
                chunk, mesh=mesh,
                in_specs=(state_specs, tel_specs, P(None, "data", None), P()),
                out_specs=(state_specs, tel_specs, aux_specs, P()),
                check_vma=False))

        tel_holder = [jax.device_put(tel0, to_shardings(mesh, tel_specs))]

        def run_chunk(state, cohorts, staleness=None):
            cohorts = np.asarray(cohorts)
            r = cohorts.shape[0]
            ids = np.pad(cohorts, ((0, 0), (0, d * b - b_total)))
            blocked = jnp.asarray(ids.reshape(r, d, b).astype(np.int32))
            if is_async:
                stale = jnp.asarray(np.asarray(staleness), jnp.int32)
                state, tel, ys, rows = run(
                    state, tel_holder[0], blocked, stale, train_j)
            else:
                state, tel, ys, rows = run(
                    state, tel_holder[0], blocked, train_j)
            tel_holder[0] = tel
            emitter(np.asarray(rows))
            return state, ys

        return run_chunk, state0

    if fault_on and is_async:
        # faulted variants: the RoundFaults xs ride the scan replicated
        # (P() pytree-prefix spec — survivors/corrupt are payload-sized),
        # every device slices its own survivor block and the replicated
        # survivor sum renormalizes the gradient identically on all shards.
        # The fault_on=False programs below stay byte-for-byte untouched.
        def chunk(state, cohorts_blk, stale, rf, train_rep):
            def body(st, xs):
                cohort_l, s_t, rf_t = xs
                cohort_x = _local_cohort_x(
                    cohort_l.reshape(-1), jax.lax.axis_index("data"),
                    train_rep, survivors=rf_t.survivors)
                n_eff = jnp.sum(rf_t.survivors)
                st, aux = server_round_step_async(
                    st, cohort_x, s_t, sel_cfg=sel_cfg, config=srv_cfg,
                    cf_cfg=cf_cfg, codec_cfg=setup.codec_cfg,
                    num_users=n_eff, shard=shard_ctx, faults=rf_t)
                return st, (aux if record else None)

            return jax.lax.scan(body, state, (cohorts_blk, stale, rf))

        run = jax.jit(shard_map(
            chunk, mesh=mesh,
            in_specs=(state_specs, P(None, "data", None), P(), P(), P()),
            out_specs=(state_specs, aux_specs), check_vma=False))
    elif fault_on:
        def chunk(state, cohorts_blk, rf, train_rep):
            def body(st, xs):
                cohort_l, rf_t = xs
                cohort_x = _local_cohort_x(
                    cohort_l.reshape(-1), jax.lax.axis_index("data"),
                    train_rep, survivors=rf_t.survivors)
                n_eff = jnp.sum(rf_t.survivors)
                st, aux = server_round_step(
                    st, cohort_x, sel_cfg=sel_cfg, config=srv_cfg,
                    cf_cfg=cf_cfg, codec_cfg=setup.codec_cfg,
                    num_users=n_eff, shard=shard_ctx, faults=rf_t)
                return st, (aux if record else None)

            return jax.lax.scan(body, state, (cohorts_blk, rf))

        run = jax.jit(shard_map(
            chunk, mesh=mesh,
            in_specs=(state_specs, P(None, "data", None), P(), P()),
            out_specs=(state_specs, aux_specs), check_vma=False))
    elif is_async:
        def chunk(state, cohorts_blk, stale, train_rep):
            # cohorts_blk (R, 1, b) local; stale (R,) + train_rep replicated
            def body(st, xs):
                cohort_l, s_t = xs
                cohort_x = _local_cohort_x(
                    cohort_l.reshape(-1), jax.lax.axis_index("data"),
                    train_rep)
                st, aux = server_round_step_async(
                    st, cohort_x, s_t, sel_cfg=sel_cfg, config=srv_cfg,
                    cf_cfg=cf_cfg, codec_cfg=setup.codec_cfg,
                    num_users=b_total, shard=shard_ctx)
                return st, (aux if record else None)

            return jax.lax.scan(body, state, (cohorts_blk, stale))

        run = jax.jit(shard_map(
            chunk, mesh=mesh,
            in_specs=(state_specs, P(None, "data", None), P(), P()),
            out_specs=(state_specs, aux_specs), check_vma=False))
    else:
        def chunk(state, cohorts_blk, train_rep):
            # local views: cohorts_blk (R, 1, b); train_rep replicated (N, M)
            def body(st, cohort_l):
                cohort_x = _local_cohort_x(
                    cohort_l.reshape(-1), jax.lax.axis_index("data"),
                    train_rep)
                st, aux = server_round_step(
                    st, cohort_x, sel_cfg=sel_cfg, config=srv_cfg,
                    cf_cfg=cf_cfg, codec_cfg=setup.codec_cfg,
                    num_users=b_total, shard=shard_ctx)
                return st, (aux if record else None)

            return jax.lax.scan(body, state, cohorts_blk)

        run = jax.jit(shard_map(
            chunk, mesh=mesh,
            in_specs=(state_specs, P(None, "data", None), P()),
            out_specs=(state_specs, aux_specs), check_vma=False))

    def run_chunk(state, cohorts, staleness=None, rf=None):
        cohorts = np.asarray(cohorts)
        r = cohorts.shape[0]
        ids = np.pad(cohorts, ((0, 0), (0, d * b - b_total)))
        blocked = jnp.asarray(ids.reshape(r, d, b).astype(np.int32))
        if is_async:
            stale = jnp.asarray(np.asarray(staleness), jnp.int32)
            if fault_on:
                return run(state, blocked, stale, rf, train_j)
            return run(state, blocked, stale, train_j)
        if fault_on:
            return run(state, blocked, rf, train_j)
        return run(state, blocked, train_j)

    return run_chunk, state0


_EVAL_ITEM_CHUNK = 4096     # auto item-block when eval_user_chunk is set


def _evaluate(q: jax.Array, eval_train: jax.Array, eval_test: jax.Array,
              config: FLSimConfig) -> RecMetrics:
    """Full-model eval, optionally chunked over users (bounded memory).

    Chunk results combine exactly: each chunk mean is re-weighted by its
    count of valid (non-empty-test) users before averaging. When user
    chunking is on, scoring also reroutes through the fused chunked top-k
    scorer (``evaluate_users(item_chunk=...)``) so neither axis of the
    (B, M) score matrix is materialized — bit-identical to the dense path
    (same mask sentinel, same top_k tie order).
    """
    chunk = config.eval_user_chunk
    n = eval_train.shape[0]
    item_chunk = config.eval_item_chunk
    if item_chunk is None and chunk is not None:
        item_chunk = _EVAL_ITEM_CHUNK
    if chunk is None or chunk >= n:
        return evaluate_users(q, eval_train, eval_test,
                              l2=config.l2, alpha=config.alpha,
                              item_chunk=item_chunk)
    sums = np.zeros(4)
    weight = 0.0
    for s in range(0, n, chunk):
        tr, te = eval_train[s:s + chunk], eval_test[s:s + chunk]
        m = evaluate_users(q, tr, te, l2=config.l2, alpha=config.alpha,
                           item_chunk=item_chunk)
        valid = float((np.asarray(te).sum(axis=-1) > 0).sum())
        sums += valid * np.array([float(m.precision), float(m.recall),
                                  float(m.f1), float(m.map)])
        weight += valid
    vals = sums / max(weight, 1.0)
    return RecMetrics(*vals)


def _finalize(setup: _SimSetup, config: FLSimConfig, state: ServerState,
              history: MetricLogger, aux_chunks: List,
              csv_path: Optional[str], hook_failures: int = 0) -> SimResult:
    final = {
        k: history.rolling_mean(k, 10)
        for k in ("precision", "recall", "f1", "map")
    }
    if csv_path:
        history.to_csv()
    rounds = int(state.t)
    # exact byte accounting: the per-round payload is shape-constant, so the
    # totals are rounds x constants. (The traced float32 counters in the
    # state are approximate once totals pass the float32 exact-integer range
    # ~2^24; in-graph consumers needing exact totals at that scale should
    # derive them from state.t x the per-round constants instead.) The
    # per-round constants come from compress.wire_bytes — the same function
    # the traced in-state counters use — so the two can never disagree.
    down_cfg, up_cfg = direction_configs(setup.codec_cfg)
    per_round_down = wire_bytes(
        down_cfg, setup.sel_cfg.num_select, setup.cf_cfg.num_factors)
    per_round_up = wire_bytes(
        up_cfg, setup.sel_cfg.num_select, setup.cf_cfg.num_factors) \
        * setup.cohorts.shape[1]
    selections = rewards = None
    if aux_chunks:
        selections = np.concatenate(
            [np.asarray(a.indices) for a in aux_chunks])
        rewards = np.concatenate([np.asarray(a.rewards) for a in aux_chunks])
    bytes_down = rounds * per_round_down
    bytes_up = rounds * per_round_up
    if config.faults is not None and config.faults.enabled:
        # under faults the uplink is no longer shape-constant per round
        # (survivor renormalization + checksum words), so report the traced
        # in-state totals instead of rounds x constants
        bytes_down = int(float(state.bytes_down))
        bytes_up = int(float(state.bytes_up))
    return SimResult(
        final=final, history=history,
        bytes_down=bytes_down,
        bytes_up=bytes_up,
        rounds=rounds,
        selection_counts=np.asarray(
            selector_counts(setup.sel_cfg, state.sel)),
        selections=selections, rewards=rewards, server_state=state,
        hook_failures=hook_failures,
    )


# ===================================================================== #
# single-run engines
# ===================================================================== #
def run_fcf_simulation(
    train_x: np.ndarray,
    test_x: np.ndarray,
    config: FLSimConfig,
    csv_path: Optional[str] = None,
) -> SimResult:
    """Run one FL simulation with the backend named by ``config.backend``.

    With an enabled ``config.obs``, every committed round's telemetry
    (:mod:`repro.obs.telemetry`) streams to the configured sink: the scan
    engines emit one batched ``io_callback`` per compiled chunk, the
    sharded engine returns the replicated rows and emits host-side, the
    python engine emits per round. Host spans (train_chunk / eval /
    publish) go to ``obs.trace_path`` when set, and ``obs.profile_dir``
    wraps the whole training loop in ``jax.profiler.trace``. Disabled or
    absent, none of this exists in the compiled programs.
    """
    train_j = jnp.asarray(train_x, jnp.float32)
    test_j = jnp.asarray(test_x, jnp.float32)
    setup = _build(train_j, test_j, config)
    record = config.record_selections
    obs = config.obs if (config.obs is not None
                         and config.obs.enabled) else None
    prev_tracer = None
    if obs is not None and obs.resolve_tracer() is not None:
        prev_tracer = install_tracer(obs.resolve_tracer())
    try:
        return _run_single(train_j, setup, config, record, obs, csv_path)
    finally:
        if obs is not None:
            try:
                jax.effects_barrier()   # drain pending telemetry callbacks
            except Exception:
                pass
            if obs.resolve_tracer() is not None:
                install_tracer(prev_tracer)


def _run_single(train_j, setup, config, record, obs, csv_path) -> SimResult:
    from jax.experimental import io_callback

    fault_cfg = config.faults
    fault_on = fault_cfg is not None and fault_cfg.enabled
    crash_round = fault_cfg.crash_round if fault_on else None
    start_round = 0
    if config.resume_from is not None:
        path = config.resume_from
        if os.path.isdir(path):
            found = latest_verified_checkpoint(path)
            if found is None:
                raise FileNotFoundError(
                    f"no verified checkpoint to resume from in {path!r}")
            path = found
        start_round = checkpoint_step(path)
        setup = setup._replace(
            state0=load_checkpoint(path, like=setup.state0))
        log.info("resuming from %s at round %d", path, start_round)
    pad_total = None
    if fault_on:
        use_mesh_pad = config.backend == "shard" or (
            config.backend == "async" and config.mesh_shards is not None)
        if use_mesh_pad:
            shards_n = config.mesh_shards or len(jax.devices())
        elif config.backend == "async":
            shards_n = config.blocks_per_commit
        else:
            shards_n = config.cohort_shards
        b_total = setup.cohorts.shape[1]
        pad_total = shards_n * (-(-b_total // shards_n))

    history = MetricLogger(csv_path)
    state = setup.state0
    aux_chunks: List = []
    hook_failures = 0
    emitter = None
    tel_holder = None
    if obs is not None:
        emitter = make_row_emitter(obs.resolve_sink(), obs.telemetry_every)
        tel_holder = [telemetry_state_init(setup.sel_cfg.num_arms)]
    profiler = None
    if obs is not None and obs.profile_dir is not None:
        profiler = jax.profiler.trace(obs.profile_dir)
        profiler.__enter__()

    try:
        if config.backend in ("scan", "shard", "async"):
            is_async = config.backend == "async"
            # async shards the same way the sync engine does — but only when
            # a mesh is asked for (mesh_shards); plain async is single-device
            use_mesh = config.backend == "shard" or (
                is_async and config.mesh_shards is not None)
            if use_mesh:
                run_chunk, state = make_sharded_round_runner(
                    train_j, setup, config, record=record, obs=obs)
            elif is_async:
                round_fn = _make_async_round_fn(
                    train_j, setup, config.blocks_per_commit,
                    telemetry=obs is not None, fault_on=fault_on)

                if obs is not None:
                    def scan_chunk(st, tel, cohorts, stale):
                        def body(carry, xs):
                            s, ts = carry
                            cohort, s_t = xs
                            s, aux = round_fn(s, cohort, s_t)
                            ts, row = telemetry_round(
                                ts, aux.telemetry, aux.indices, aux.rewards)
                            ys = (aux._replace(telemetry=())
                                  if record else None)
                            return (s, ts), (ys, row)

                        (st, tel), (ys, rows) = jax.lax.scan(
                            body, (st, tel), (cohorts, stale))
                        # one BATCHED host callback per compiled chunk; the
                        # host side applies the telemetry_every rate limit
                        io_callback(emitter, None, rows, ordered=True)
                        return st, tel, ys

                    compiled_async = jax.jit(scan_chunk)

                    def run_chunk(st, cohorts, staleness=None):
                        st, tel_holder[0], ys = compiled_async(
                            st, tel_holder[0], jnp.asarray(cohorts),
                            jnp.asarray(np.asarray(staleness), jnp.int32))
                        return st, ys
                elif fault_on:
                    def scan_chunk(st, cohorts, stale, rf):
                        def body(s, xs):
                            cohort, s_t, rf_t = xs
                            s, aux = round_fn(s, cohort, s_t, rf_t)
                            return s, (aux if record else None)
                        return jax.lax.scan(body, st, (cohorts, stale, rf))

                    compiled_async = jax.jit(scan_chunk)

                    def run_chunk(st, cohorts, staleness=None, rf=None):
                        return compiled_async(
                            st, jnp.asarray(cohorts),
                            jnp.asarray(np.asarray(staleness), jnp.int32),
                            rf)
                else:
                    def scan_chunk(st, cohorts, stale):
                        def body(s, xs):
                            cohort, s_t = xs
                            s, aux = round_fn(s, cohort, s_t)
                            return s, (aux if record else None)
                        return jax.lax.scan(body, st, (cohorts, stale))

                    compiled_async = jax.jit(scan_chunk)

                    def run_chunk(st, cohorts, staleness=None):
                        return compiled_async(
                            st, jnp.asarray(cohorts),
                            jnp.asarray(np.asarray(staleness), jnp.int32))
            else:
                round_fn = _make_round_fn(train_j, setup,
                                          config.cohort_shards,
                                          telemetry=obs is not None,
                                          fault_on=fault_on)

                if obs is not None:
                    def scan_chunk(st, tel, cohorts):
                        def body(carry, cohort):
                            s, ts = carry
                            s, aux = round_fn(s, cohort)
                            ts, row = telemetry_round(
                                ts, aux.telemetry, aux.indices, aux.rewards)
                            ys = (aux._replace(telemetry=())
                                  if record else None)
                            return (s, ts), (ys, row)

                        (st, tel), (ys, rows) = jax.lax.scan(
                            body, (st, tel), cohorts)
                        io_callback(emitter, None, rows, ordered=True)
                        return st, tel, ys

                    compiled = jax.jit(scan_chunk)

                    def run_chunk(st, cohorts, staleness=None):
                        st, tel_holder[0], ys = compiled(
                            st, tel_holder[0], jnp.asarray(cohorts))
                        return st, ys
                elif fault_on:
                    def scan_chunk(st, cohorts, rf):
                        def body(s, xs):
                            cohort, rf_t = xs
                            s, aux = round_fn(s, cohort, rf_t)
                            return s, (aux if record else None)
                        return jax.lax.scan(body, st, (cohorts, rf))

                    compiled = jax.jit(scan_chunk)

                    def run_chunk(st, cohorts, staleness=None, rf=None):
                        return compiled(st, jnp.asarray(cohorts), rf)
                else:
                    def scan_chunk(st, cohorts):
                        def body(s, cohort):
                            s, aux = round_fn(s, cohort)
                            return s, (aux if record else None)
                        return jax.lax.scan(body, st, cohorts)

                    compiled = jax.jit(scan_chunk)

                    def run_chunk(st, cohorts, staleness=None):
                        return compiled(st, jnp.asarray(cohorts))

            for start, end in _chunk_bounds(config.rounds,
                                            config.eval_every):
                if end <= start_round:
                    continue    # resume: already committed + checkpointed
                lo = max(start, start_round)
                hi = end
                crash = None
                if crash_round is not None and lo < crash_round <= end:
                    # the host "dies" while executing crash_round: rounds
                    # [lo, crash_round-1] run first and are then LOST —
                    # state never escapes this frame, so resume can only
                    # start from the last checkpoint
                    crash, hi = crash_round, crash_round - 1
                aux = None
                if hi > lo:
                    with span("train_chunk", start=lo, end=hi,
                              backend=config.backend):
                        args = [setup.cohorts[lo:hi]]
                        if is_async:
                            args.append(setup.staleness[lo:hi])
                        kw = {}
                        if fault_on:
                            kw["rf"] = round_faults_xs(
                                setup.fault_sched, lo, hi, pad_to=pad_total)
                        state, aux = run_chunk(state, *args, **kw)
                if crash is not None:
                    raise SimulatedCrash(crash, config.checkpoint_dir)
                if record:
                    aux_chunks.append(aux)
                with span("eval", round=end):
                    m = _evaluate(state.q, setup.eval_train,
                                  setup.eval_test, config)
                history.log(end, **m.as_dict())
                if config.checkpoint_dir is not None:
                    save_checkpoint(config.checkpoint_dir, end, state)
                if config.snapshot_hook is not None:
                    try:
                        with span("publish", round=end):
                            config.snapshot_hook(end, state)
                    except Exception:
                        hook_failures += 1
                        log.exception(
                            "snapshot_hook raised at round %d; training "
                            "continues (the previously published model "
                            "stays live)", end)
        else:  # "python": the per-round-dispatch reference loop
            round_fn = _make_round_fn(train_j, setup, config.cohort_shards,
                                      telemetry=obs is not None,
                                      fault_on=fault_on)
            step = jax.jit(round_fn)
            tel_step = jax.jit(telemetry_round) if obs is not None else None
            for t in range(start_round + 1, config.rounds + 1):
                if crash_round is not None and t == crash_round:
                    raise SimulatedCrash(crash_round, config.checkpoint_dir)
                if fault_on:
                    rf_t = jax.tree.map(
                        lambda a: a[0],
                        round_faults_xs(setup.fault_sched, t - 1, t,
                                        pad_to=pad_total))
                    state, aux = step(
                        state, jnp.asarray(setup.cohorts[t - 1]), rf_t)
                else:
                    state, aux = step(
                        state, jnp.asarray(setup.cohorts[t - 1]))
                if obs is not None:
                    tel_holder[0], row = tel_step(
                        tel_holder[0], aux.telemetry, aux.indices,
                        aux.rewards)
                    emitter(np.asarray(row))
                    aux = aux._replace(telemetry=())
                if record:
                    aux_chunks.append(jax.tree.map(lambda a: a[None], aux))
                if t % config.eval_every == 0 or t == config.rounds:
                    with span("eval", round=t):
                        m = _evaluate(state.q, setup.eval_train,
                                      setup.eval_test, config)
                    history.log(t, **m.as_dict())
                    if config.checkpoint_dir is not None:
                        save_checkpoint(config.checkpoint_dir, t, state)
                    if config.snapshot_hook is not None:
                        try:
                            with span("publish", round=t):
                                config.snapshot_hook(t, state)
                        except Exception:
                            hook_failures += 1
                            log.exception(
                                "snapshot_hook raised at round %d; "
                                "training continues (the previously "
                                "published model stays live)", t)
    finally:
        if profiler is not None:
            profiler.__exit__(None, None, None)

    return _finalize(setup, config, state, history, aux_chunks, csv_path,
                     hook_failures=hook_failures)


# ===================================================================== #
# vmapped sweep entry points
# ===================================================================== #
def run_seed_sweep(
    train_x: np.ndarray,
    test_x: np.ndarray,
    config: FLSimConfig,
    seeds: Sequence[int],
) -> List[SimResult]:
    """Run one config across many seeds as a single vmapped scan program.

    ``train_x``/``test_x`` are either a single (N, M) matrix shared by every
    seed, or stacked (S, N, M) per-seed matrices (the experiment grid's
    rebuild seeds regenerate the dataset too). Every seed gets its own model
    init, selection PRNG stream, cohort schedule and eval cohort (identical
    to what ``run_fcf_simulation`` would use for that seed); the round loop
    executes as ``vmap(scan(server_round_step))`` so the whole rebuild axis
    of an experiment cell costs one compile + one device program.
    """
    if not seeds:
        return []
    if config.obs is not None and config.obs.enabled:
        raise ValueError(
            "config.obs telemetry is single-run only (one stream per "
            "trajectory); run_seed_sweep vmaps the round engine over seeds "
            "— disable obs or use run_fcf_simulation per seed")
    if config.faults is not None and config.faults.enabled:
        raise ValueError(
            "config.faults is single-run only (per-trajectory fault "
            "schedules and crash/resume semantics); run_seed_sweep vmaps "
            "the round engine over seeds — disable faults or use "
            "run_fcf_simulation per seed")
    train_np = np.asarray(train_x)
    test_np = np.asarray(test_x)
    per_seed_data = train_np.ndim == 3
    if per_seed_data and train_np.shape[0] != len(seeds):
        raise ValueError(
            f"stacked data has {train_np.shape[0]} slices for "
            f"{len(seeds)} seeds")

    def data_for(i):
        if per_seed_data:
            return (jnp.asarray(train_np[i], jnp.float32),
                    jnp.asarray(test_np[i], jnp.float32))
        return (jnp.asarray(train_np, jnp.float32),
                jnp.asarray(test_np, jnp.float32))

    trains = []
    setups = []
    for i, s in enumerate(seeds):
        train_j, test_j = data_for(i)
        trains.append(train_j)
        setups.append(_build(train_j, test_j, replace(config, seed=int(s))))
    setup0 = setups[0]
    sel_cfg, srv_cfg, cf_cfg = setup0.sel_cfg, setup0.srv_cfg, setup0.cf_cfg
    codec_cfg = setup0.codec_cfg
    record = config.record_selections

    state = jax.tree.map(lambda *xs: jnp.stack(xs),
                         *[s.state0 for s in setups])
    cohorts = np.stack([s.cohorts for s in setups])          # (S, R, B)
    eval_train = jnp.stack([s.eval_train for s in setups])   # (S, E, M)
    eval_test = jnp.stack([s.eval_test for s in setups])
    train_batched = jnp.stack(trains) if per_seed_data else trains[0]

    def scan_chunk(st, ch, train_j):
        def body(s, cohort):
            def cohort_x(idx):
                return train_j[cohort[:, None], idx[None, :]]
            s, aux = server_round_step(
                s, cohort_x, sel_cfg=sel_cfg, config=srv_cfg, cf_cfg=cf_cfg,
                codec_cfg=codec_cfg)
            return s, (aux if record else None)
        return jax.lax.scan(body, st, ch)

    run_chunk = jax.jit(jax.vmap(
        scan_chunk, in_axes=(0, 0, 0 if per_seed_data else None)))
    if config.eval_user_chunk is None:
        eval_vmapped = jax.jit(jax.vmap(
            lambda q, tr, te: evaluate_users(q, tr, te, l2=config.l2,
                                             alpha=config.alpha)))

        def eval_all(q_stack):
            return eval_vmapped(q_stack, eval_train, eval_test)
    else:
        # memory-bounded chunked eval: per-seed python loop (the vmapped
        # one-shot eval would materialize the full (S, E, M) score tensor,
        # defeating the point of eval_user_chunk)
        def eval_all(q_stack):
            per_seed = [
                _evaluate(q_stack[i], eval_train[i], eval_test[i], config)
                for i in range(len(seeds))
            ]
            return RecMetrics(*[
                jnp.stack([jnp.asarray(float(getattr(m, k)))
                           for m in per_seed])
                for k in ("precision", "recall", "f1", "map")
            ])

    histories = [MetricLogger() for _ in seeds]
    aux_chunks: List = []
    for start, end in _chunk_bounds(config.rounds, config.eval_every):
        state, aux = run_chunk(state, jnp.asarray(cohorts[:, start:end]),
                               train_batched)
        if record:
            aux_chunks.append(aux)
        metrics = eval_all(state.q)
        for i, h in enumerate(histories):
            h.log(end, **{k: float(getattr(metrics, k)[i])
                          for k in ("precision", "recall", "f1", "map")})

    results = []
    for i, s in enumerate(seeds):
        state_i = jax.tree.map(lambda a: a[i], state)
        aux_i = [jax.tree.map(lambda a: a[i], a) for a in aux_chunks]
        results.append(_finalize(setups[i], config, state_i, histories[i],
                                 aux_i, csv_path=None))
    return results


def run_strategy_sweep(
    train_x: np.ndarray,
    test_x: np.ndarray,
    config: FLSimConfig,
    strategies: Sequence[str] = STRATEGIES,
    seeds: Sequence[int] = (0,),
    codecs: Optional[Sequence[str]] = None,
) -> Dict:
    """Sweep strategies (x codecs) x seeds: one vmapped program per cell.

    Strategies carry differently-shaped selector states (and ``full`` a
    different payload width), so the strategy axis is a Python loop over
    compiled seed sweeps rather than a vmap axis; likewise codecs carry
    differently-shaped wire/residual state.

    With ``codecs=None`` (default) every strategy runs ``config.codec`` and
    the result is ``{strategy: [SimResult per seed]}`` — the historical
    shape. With an explicit codec list the result gains the codec axis:
    ``{strategy: {codec: [SimResult per seed]}}``.
    """
    if codecs is None:
        return {
            s: run_seed_sweep(train_x, test_x, replace(config, strategy=s),
                              seeds)
            for s in strategies
        }
    return {
        s: {
            c: run_seed_sweep(
                train_x, test_x, replace(config, strategy=s, codec=c), seeds)
            for c in codecs
        }
        for s in strategies
    }
