from repro.federated.simulation import (
    FLSimConfig,
    SimResult,
    run_fcf_simulation,
    run_seed_sweep,
    run_strategy_sweep,
)

__all__ = [
    "FLSimConfig", "run_fcf_simulation", "SimResult",
    "run_seed_sweep", "run_strategy_sweep",
]
