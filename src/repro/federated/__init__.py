from repro.federated.simulation import FLSimConfig, run_fcf_simulation, SimResult

__all__ = ["FLSimConfig", "run_fcf_simulation", "SimResult"]
