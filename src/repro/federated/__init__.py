from repro.federated.simulation import (
    FLSimConfig,
    SimResult,
    make_sharded_round_runner,
    run_fcf_simulation,
    run_seed_sweep,
    run_strategy_sweep,
)

__all__ = [
    "FLSimConfig", "run_fcf_simulation", "SimResult",
    "make_sharded_round_runner", "run_seed_sweep", "run_strategy_sweep",
]
