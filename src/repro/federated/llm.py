"""Federated LLM fine-tuning with bandit-selected vocab-row payloads.

The paper's generalization (Sec. 1: "can be generalized to advanced deep
learning-based FL recommendation systems"): for a language model the
item-dependent payload is the (vocab x d_model) embedding/unembedding pair —
exactly the Q matrix of FCF with items = vocab rows. Each round:

  1. the selector (BTS / random / full) picks M_s vocab rows,
  2. clients receive the transformer body + ONLY those embedding rows,
  3. each client runs local SGD steps on its non-IID token stream,
  4. clients return body deltas + the selected rows' embedding deltas,
  5. the server aggregates, applies the update, computes Eq. 13 rewards on
     the per-row embedding deltas, and updates the bandit posterior.

Rows not selected stay at their server values on the client (the client's
local model is the server model patched with the fresh rows) — mirroring the
paper's "users perform the standard model update on the subset".

Payload accounting reports the embedding traffic (the item-dependent part)
and the body traffic (constant in vocab) separately, like Table 1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.payload import PayloadSelector, make_selector
from repro.data.tokens import TokenDataConfig, synthetic_token_batches
from repro.models.lm import init_train_state, lm_loss
from repro.utils.logging import MetricLogger, get_logger

log = get_logger("repro.fedllm")


@dataclass
class FedLLMConfig:
    strategy: str = "bts"
    keep_fraction: float = 0.1
    rounds: int = 20
    num_clients: int = 4
    clients_per_round: int = 2
    local_steps: int = 4
    local_lr: float = 0.1
    server_lr: float = 1.0        # FedAvg-style server application
    batch_size: int = 4
    seq_len: int = 32
    gamma: float = 0.999
    seed: int = 0


def _split_vocab_tables(params) -> Tuple[Dict, Dict]:
    """Split params into (vocab tables, body). Tables: embed + unembed."""
    tables = {k: params[k] for k in ("embed", "unembed") if k in params}
    body = {k: v for k, v in params.items() if k not in tables}
    return tables, body


def _tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def _tree_add_scaled(a, b, s):
    return jax.tree.map(lambda x, y: x + s * y, a, b)


def _local_sgd(params, cfg: ModelConfig, batches, lr: float):
    """Plain local SGD steps (clients are resource constrained — no Adam)."""
    loss_fn = jax.jit(
        lambda p, b: jax.value_and_grad(lambda q: lm_loss(q, cfg, b))(p),
        static_argnames=())
    total = 0.0
    for b in batches:
        loss, grads = loss_fn(params, b)
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        total += float(loss)
    return params, total / max(len(batches), 1)


def run_federated_llm(
    model_cfg: ModelConfig,
    fed_cfg: FedLLMConfig,
    csv_path: Optional[str] = None,
) -> Dict:
    """Simulate federated fine-tuning; returns summary metrics + accounting."""
    key = jax.random.PRNGKey(fed_cfg.seed)
    state = init_train_state(model_cfg, key)
    global_params = state.params

    vocab = model_cfg.vocab_size
    d = model_cfg.d_model
    selector = make_selector(
        fed_cfg.strategy, num_arms=vocab, dim=d,
        keep_fraction=fed_cfg.keep_fraction, gamma=fed_cfg.gamma,
        seed=fed_cfg.seed + 1)

    data_cfg = TokenDataConfig(
        vocab_size=vocab, seq_len=fed_cfg.seq_len,
        batch_size=fed_cfg.batch_size, num_clients=fed_cfg.num_clients,
        seed=fed_cfg.seed)

    # held-out eval stream (IID mixture)
    eval_batches = list(synthetic_token_batches(
        TokenDataConfig(vocab_size=vocab, seq_len=fed_cfg.seq_len,
                        batch_size=fed_cfg.batch_size, seed=fed_cfg.seed + 99),
        num_batches=4))
    eval_batches = [{k: jnp.asarray(v) for k, v in b.items()}
                    for b in eval_batches]
    eval_loss_fn = jax.jit(lambda p, b: lm_loss(p, model_cfg, b))

    def eval_loss(params):
        return float(np.mean([float(eval_loss_fn(params, b))
                              for b in eval_batches]))

    rng = np.random.default_rng(fed_cfg.seed + 7)
    history = MetricLogger(csv_path)
    bytes_item_dep = 0            # vocab-table traffic (the paper's payload)
    bytes_body = 0
    itemsize = 4

    for t in range(1, fed_cfg.rounds + 1):
        selected = selector.select()
        sel_np = np.asarray(selected)
        cohort = rng.choice(fed_cfg.num_clients,
                            size=fed_cfg.clients_per_round, replace=False)

        tables, body = _split_vocab_tables(global_params)
        # accounting: body down + selected rows down, same back up
        n_tables = len(tables)
        bytes_item_dep += 2 * n_tables * len(sel_np) * d * itemsize \
            * len(cohort)
        from repro.utils.tree import tree_size_bytes
        bytes_body += 2 * tree_size_bytes(body) * len(cohort)

        agg_delta = None
        emb_row_grads = jnp.zeros((len(sel_np), d), jnp.float32)
        mean_client_loss = 0.0
        for c in cohort:
            batches = [
                {k: jnp.asarray(v) for k, v in b.items()}
                for b in synthetic_token_batches(
                    data_cfg, client_id=int(c),
                    num_batches=fed_cfg.local_steps)
            ]
            local_params, closs = _local_sgd(
                global_params, model_cfg, batches, fed_cfg.local_lr)
            mean_client_loss += closs / len(cohort)
            delta = _tree_sub(local_params, global_params)

            # payload restriction: zero out unselected vocab rows in the delta
            mask = jnp.zeros((vocab, 1), jnp.float32).at[selected].set(1.0)
            for tab in ("embed", "unembed"):
                if tab in delta:
                    delta[tab]["table"] = delta[tab]["table"] * mask
            emb_tab = delta.get("unembed", delta["embed"])["table"]
            emb_row_grads = emb_row_grads + emb_tab[selected].astype(jnp.float32)

            agg_delta = delta if agg_delta is None else jax.tree.map(
                jnp.add, agg_delta, delta)

        agg_delta = jax.tree.map(lambda x: x / len(cohort), agg_delta)
        global_params = _tree_add_scaled(global_params, agg_delta,
                                         fed_cfg.server_lr)
        # bandit feedback on the aggregated selected-row deltas (Eq. 13)
        selector.observe(selected, emb_row_grads / len(cohort))

        ev = eval_loss(global_params)
        history.log(t, eval_loss=ev, client_loss=mean_client_loss,
                    bytes_item_dep=bytes_item_dep, bytes_body=bytes_body)

    if csv_path:
        history.to_csv()
    full_item_bytes = 2 * len(_split_vocab_tables(global_params)[0]) \
        * vocab * d * itemsize * fed_cfg.clients_per_round * fed_cfg.rounds
    return {
        "final_eval_loss": history.last("eval_loss"),
        "first_eval_loss": history.series("eval_loss")[0],
        "bytes_item_dep": bytes_item_dep,
        "bytes_body": bytes_body,
        "bytes_item_dep_full_equivalent": full_item_bytes,
        "item_payload_reduction_pct":
            100.0 * (1.0 - bytes_item_dep / max(full_item_bytes, 1)),
        "selection_counts": selector.selection_counts(),
        "history": history,
    }
