"""Federated LLM fine-tuning with bandit-selected vocab-row payloads.

The paper's generalization (Sec. 1: "can be generalized to advanced deep
learning-based FL recommendation systems"): for a language model the
item-dependent payload is the (vocab x d_model) embedding/unembedding pair —
exactly the Q matrix of FCF with items = vocab rows. Each round:

  1. the selector (BTS / random / full) picks M_s vocab rows,
  2. clients receive the transformer body + ONLY those embedding rows,
  3. each client runs local SGD steps on its non-IID token stream,
  4. clients return body deltas + the selected rows' embedding deltas,
  5. the server aggregates, applies the update, computes Eq. 13 rewards on
     the per-row embedding deltas, and updates the bandit posterior.

Rows not selected stay at their server values on the client (the client's
local model is the server model patched with the fresh rows) — mirroring the
paper's "users perform the standard model update on the subset".

Payload accounting reports the embedding traffic (the item-dependent part)
and the body traffic (constant in vocab) separately, like Table 1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import (
    CodecConfig, direction_configs, encode_with_residual, is_stateful,
    roundtrip, wire_bytes,
)
from repro.configs.base import ModelConfig
from repro.core.payload import PayloadSelector, make_selector
from repro.data.tokens import TokenDataConfig, synthetic_token_batches
from repro.kernels import ops
from repro.models.lm import init_train_state, lm_loss
from repro.utils.logging import MetricLogger, get_logger

log = get_logger("repro.fedllm")


@dataclass
class FedLLMConfig:
    strategy: str = "bts"
    keep_fraction: float = 0.1
    rounds: int = 20
    num_clients: int = 4
    clients_per_round: int = 2
    local_steps: int = 4
    local_lr: float = 0.1
    server_lr: float = 1.0        # FedAvg-style server application
    batch_size: int = 4
    seq_len: int = 32
    gamma: float = 0.999
    # wire format for the vocab-row payload (repro.compress codec name).
    # Lossy codecs are physically applied: clients train on dequantized
    # rows and the server aggregates dequantized deltas.
    codec: str = "fp32"
    seed: int = 0


def _split_vocab_tables(params) -> Tuple[Dict, Dict]:
    """Split params into (vocab tables, body). Tables: embed + unembed."""
    tables = {k: params[k] for k in ("embed", "unembed") if k in params}
    body = {k: v for k, v in params.items() if k not in tables}
    return tables, body


def _tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def _tree_add_scaled(a, b, s):
    return jax.tree.map(lambda x, y: x + s * y, a, b)


def _local_sgd(params, cfg: ModelConfig, batches, lr: float):
    """Plain local SGD steps (clients are resource constrained — no Adam)."""
    loss_fn = jax.jit(
        lambda p, b: jax.value_and_grad(lambda q: lm_loss(q, cfg, b))(p),
        static_argnames=())
    total = 0.0
    for b in batches:
        loss, grads = loss_fn(params, b)
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        total += float(loss)
    return params, total / max(len(batches), 1)


def run_federated_llm(
    model_cfg: ModelConfig,
    fed_cfg: FedLLMConfig,
    csv_path: Optional[str] = None,
) -> Dict:
    """Simulate federated fine-tuning; returns summary metrics + accounting."""
    key = jax.random.PRNGKey(fed_cfg.seed)
    state = init_train_state(model_cfg, key)
    global_params = state.params

    vocab = model_cfg.vocab_size
    d = model_cfg.d_model
    selector = make_selector(
        fed_cfg.strategy, num_arms=vocab, dim=d,
        keep_fraction=fed_cfg.keep_fraction, gamma=fed_cfg.gamma,
        codec=fed_cfg.codec, seed=fed_cfg.seed + 1)

    data_cfg = TokenDataConfig(
        vocab_size=vocab, seq_len=fed_cfg.seq_len,
        batch_size=fed_cfg.batch_size, num_clients=fed_cfg.num_clients,
        seed=fed_cfg.seed)

    # held-out eval stream (IID mixture)
    eval_batches = list(synthetic_token_batches(
        TokenDataConfig(vocab_size=vocab, seq_len=fed_cfg.seq_len,
                        batch_size=fed_cfg.batch_size, seed=fed_cfg.seed + 99),
        num_batches=4))
    eval_batches = [{k: jnp.asarray(v) for k, v in b.items()}
                    for b in eval_batches]
    eval_loss_fn = jax.jit(lambda p, b: lm_loss(p, model_cfg, b))

    def eval_loss(params):
        return float(np.mean([float(eval_loss_fn(params, b))
                              for b in eval_batches]))

    rng = np.random.default_rng(fed_cfg.seed + 7)
    history = MetricLogger(csv_path)
    bytes_item_dep = 0            # vocab-table traffic (the paper's payload)
    bytes_body = 0
    # payload codec: the vocab-row traffic moves in this wire format, in
    # both directions (topk resolves to fp32 down / sparsified up)
    codec_cfg = CodecConfig(name=fed_cfg.codec)
    down_cfg, up_cfg = direction_configs(codec_cfg)
    # error-feedback residual per vocab table for stateful uplink codecs
    # (mirrors ServerState.codec in the CF engine)
    residuals = {}
    if is_stateful(up_cfg):
        residuals = {tab: jnp.zeros((vocab, d), jnp.float32)
                     for tab in _split_vocab_tables(global_params)[0]}

    for t in range(1, fed_cfg.rounds + 1):
        selected = selector.select()
        sel_np = np.asarray(selected)
        cohort = rng.choice(fed_cfg.num_clients,
                            size=fed_cfg.clients_per_round, replace=False)

        tables, body = _split_vocab_tables(global_params)
        # accounting: body down + selected rows down, rows back up — all
        # row traffic priced by compress.wire_bytes (single source of truth)
        n_tables = len(tables)
        bytes_item_dep += n_tables * len(cohort) * (
            wire_bytes(down_cfg, len(sel_np), d)
            + wire_bytes(up_cfg, len(sel_np), d))
        from repro.utils.tree import tree_size_bytes
        bytes_body += 2 * tree_size_bytes(body) * len(cohort)

        # downlink: with a lossy codec the client's local model is the
        # server model with the *decoded wire image* of the fresh rows
        # patched over it — for int8 exactly the fused dequantize+scatter
        # kernel (one pass per row); other codecs via encode/decode
        client_params = global_params
        if down_cfg.name != "fp32":
            client_params = dict(global_params)
            for tab in tables:
                table = global_params[tab]["table"]
                if down_cfg.name == "int8":
                    codes, scales = ops.gather_quantize_rows(table, selected)
                    patched = ops.dequant_scatter_set_rows(
                        jnp.array(table), selected, codes, scales)
                else:
                    rows_hat = roundtrip(
                        down_cfg, table[selected]).astype(table.dtype)
                    patched = ops.scatter_set_rows(
                        jnp.array(table), selected, rows_hat)
                client_params[tab] = {**global_params[tab], "table": patched}

        agg_delta = None
        mean_client_loss = 0.0
        for c in cohort:
            batches = [
                {k: jnp.asarray(v) for k, v in b.items()}
                for b in synthetic_token_batches(
                    data_cfg, client_id=int(c),
                    num_batches=fed_cfg.local_steps)
            ]
            local_params, closs = _local_sgd(
                client_params, model_cfg, batches, fed_cfg.local_lr)
            mean_client_loss += closs / len(cohort)
            # the client reports movement from the model it actually
            # received (client_params, i.e. the decoded downlink) — it
            # never saw the server's exact rows, so a lossy downlink must
            # not leak its quantization error into the uplink delta
            delta = _tree_sub(local_params, client_params)

            # payload restriction: zero out unselected vocab rows
            mask = jnp.zeros((vocab, 1), jnp.float32).at[selected].set(1.0)
            for tab in ("embed", "unembed"):
                if tab in delta:
                    delta[tab]["table"] = delta[tab]["table"] * mask

            agg_delta = delta if agg_delta is None else jax.tree.map(
                jnp.add, agg_delta, delta)

        agg_delta = jax.tree.map(lambda x: x / len(cohort), agg_delta)

        # uplink codec on the aggregated selected rows (the wire image each
        # client's update passes through, as in cf.server_round_step) —
        # with the EF residual re-injecting previously dropped mass
        if up_cfg.name != "fp32":
            for tab in ("embed", "unembed"):
                if tab not in agg_delta:
                    continue
                table = agg_delta[tab]["table"]
                rows = table[selected].astype(jnp.float32)
                if is_stateful(up_cfg):
                    _, rows_hat, new_res = encode_with_residual(
                        up_cfg, rows, residuals[tab][selected])
                    residuals[tab] = residuals[tab].at[selected].set(new_res)
                else:
                    rows_hat = roundtrip(up_cfg, rows)
                agg_delta[tab]["table"] = jnp.zeros_like(table).at[
                    selected].set(rows_hat.astype(table.dtype))

        global_params = _tree_add_scaled(global_params, agg_delta,
                                         fed_cfg.server_lr)
        # bandit feedback on the aggregated selected-row deltas (Eq. 13),
        # as decoded on the server side
        emb_tab = agg_delta.get("unembed", agg_delta["embed"])["table"]
        selector.observe(selected, emb_tab[selected].astype(jnp.float32))

        ev = eval_loss(global_params)
        history.log(t, eval_loss=ev, client_loss=mean_client_loss,
                    bytes_item_dep=bytes_item_dep, bytes_body=bytes_body)

    if csv_path:
        history.to_csv()
    # full-payload fp32 equivalent (the dense no-selection, no-codec wire)
    full_item_bytes = 2 * len(_split_vocab_tables(global_params)[0]) \
        * wire_bytes(CodecConfig(name="fp32"), vocab, d) \
        * fed_cfg.clients_per_round * fed_cfg.rounds
    return {
        "final_eval_loss": history.last("eval_loss"),
        "first_eval_loss": history.series("eval_loss")[0],
        "bytes_item_dep": bytes_item_dep,
        "bytes_body": bytes_body,
        "bytes_item_dep_full_equivalent": full_item_bytes,
        "item_payload_reduction_pct":
            100.0 * (1.0 - bytes_item_dep / max(full_item_bytes, 1)),
        "selection_counts": selector.selection_counts(),
        "history": history,
    }
