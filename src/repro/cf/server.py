"""FL server for FCF — Algorithm 1.

The server owns:
  * the global model Q (item factors, (M, K)),
  * a per-row Adam state (Eq. 4 with Adam, per the paper),
  * a PayloadSelector (bts / random / full / magnitude),
  * the Theta-threshold gradient accumulator (Algorithm 1 line 12).

Round protocol (one call to ``begin_round`` + >=1 ``receive`` + auto-commit):
  1. begin_round(): bandit selects M_s items; server exposes Q*        (l. 8-10)
  2. clients send back aggregated gradients for Q*                     (l. 11)
  3. once accumulated #user-updates >= Theta: Adam-update Q rows,
     update v, compute rewards, update bandit posterior               (l. 12-20)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.payload import PayloadSelector
from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update_rows


@dataclass
class FCFServerConfig:
    theta: int = 100              # federated updates needed per global update
    adam: AdamConfig = field(default_factory=lambda: AdamConfig(
        lr=0.01, beta1=0.1, beta2=0.99, eps=1e-8))  # paper Table 3
    # Bandit feedback (beyond-paper fix, ablatable): each user's Eq. 6
    # gradient carries a +2λq_j term; aggregated over Θ users the feedback
    # becomes  data_term + 2λΘ·q_j.  The λ part is popularity-INDEPENDENT
    # noise ∝ |q_j| that swamps the informative data term at early rounds —
    # measured corr(reward, popularity) = -0.35 at t=1, locking the bandit
    # onto uninformative items (worse than FCF-Random on MIND-scale data).
    # The server knows λ, Θ and Q*, so it subtracts 2λΘ·q_j from the
    # FEEDBACK ONLY (the model update keeps the paper's exact Eq. 4);
    # no extra client information is used.  "raw" reproduces the paper.
    reward_feedback: str = "data_term"          # "data_term" | "raw"
    l2: float = 1.0


@dataclass
class FCFServer:
    item_factors: jax.Array            # (M, K) global model Q^T
    selector: PayloadSelector
    config: FCFServerConfig = field(default_factory=FCFServerConfig)

    opt_state: Optional[AdamState] = None
    _selected: Optional[jax.Array] = None          # current round's item ids
    _grad_accum: Optional[jax.Array] = None        # (M_s, K) accumulated grads
    _updates_accum: int = 0                        # NumberGradientUpdates
    rounds_committed: int = 0
    bytes_down: int = 0                            # payload accounting
    bytes_up: int = 0

    def __post_init__(self):
        if self.opt_state is None:
            self.opt_state = adam_init(self.item_factors, per_row=True)

    # ---------------------------------------------------------------- #
    def begin_round(self) -> jax.Array:
        """Select the payload subset and return Q* rows (Alg. 1 lines 8-10)."""
        self._selected = self.selector.select()
        q_star = self.item_factors[self._selected]
        self.bytes_down += q_star.size * q_star.dtype.itemsize
        return q_star

    @property
    def selected(self) -> jax.Array:
        assert self._selected is not None, "call begin_round() first"
        return self._selected

    def receive(self, grad_rows: jax.Array, num_users: int) -> bool:
        """Accumulate a cohort's aggregated gradient (Alg. 1 line 11).

        Returns True if this receipt triggered a global-model commit.
        """
        assert self._selected is not None, "call begin_round() first"
        # each participating user uplinks its own (M_s, K) gradient
        self.bytes_up += grad_rows.size * grad_rows.dtype.itemsize * num_users
        if self._grad_accum is None:
            self._grad_accum = grad_rows
        else:
            self._grad_accum = self._grad_accum + grad_rows
        self._updates_accum += num_users
        if self._updates_accum >= self.config.theta:
            self._commit()
            return True
        return False

    # ---------------------------------------------------------------- #
    def _commit(self) -> None:
        """Global update + bandit feedback (Alg. 1 lines 13-19)."""
        idx, grads = self._selected, self._grad_accum
        q_star = self.item_factors[idx]
        # line 13: Q <- Q - eta * sum_i grad_i (Adam-adapted, Eq. 4)
        self.item_factors, self.opt_state = adam_update_rows(
            grads, idx, self.opt_state, self.item_factors, self.config.adam
        )
        # lines 14-18: v update, rewards, BTS posterior, prev-grad buffer
        feedback = grads
        if self.config.reward_feedback == "data_term":
            feedback = grads - 2.0 * self.config.l2 * self._updates_accum \
                * q_star
        self.selector.observe(idx, feedback)
        self.rounds_committed += 1
        self._grad_accum = None
        self._updates_accum = 0

    # ---------------------------------------------------------------- #
    @property
    def num_items(self) -> int:
        return self.item_factors.shape[0]

    @property
    def num_factors(self) -> int:
        return self.item_factors.shape[1]
