"""FL server for FCF — Algorithm 1, functional core + legacy shim.

Primary API (jit/scan/vmap-safe):

  * :class:`ServerState` — the entire server as a pure pytree: global model
    Q, per-row Adam state, selector state, PRNG key, round counter, and
    byte counters carried as traced scalars.
  * :func:`server_init` — build a fresh state.
  * :func:`server_round_step` — ONE fused FL round (Alg. 1 lines 8-19):
    select -> gather Q* (Pallas payload gather) -> cohort local solve ->
    fused item gradients -> scatter-based sparse Adam commit -> reward /
    BTS posterior update. Pure ``(state, cohort_x) -> (state, aux)``, so the
    simulation can drive thousands of rounds through ``jax.lax.scan`` and
    vectorize whole sweeps with ``jax.vmap``.
  * :func:`server_round_step_async` — the staleness-bounded async round:
    every round PUBLISHES a fresh encoded snapshot Q* into a bounded ring
    buffer (``ServerState.snapshots``, wire images so depth-S bounding costs
    S payload-sized buffers, not S full tables) and COMMITS a cohort that
    solved against the snapshot of ``staleness`` rounds ago — via a
    staleness-discounted Adam step and a delay-corrected bandit reward
    attributed to the stale pull (the paper's deployment model, where users
    report back asynchronously). ``staleness=0`` reduces bit-for-bit to the
    synchronous step.

:class:`FCFServer` is the original mutable, Python-driven server kept as a
backwards-compatible shim (incremental ``begin_round``/``receive`` protocol
with Theta-threshold accumulation across multiple cohort receipts); it now
also routes its payload download through the kernel gather.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.cf.local import solve_user_factors
from repro.cf.model import CFConfig
from repro.compress import (
    CHECKSUM_BYTES_PER_ROW, CodecConfig, QuantWire, codec_state_init, decode,
    direction_configs, encode, encode_with_residual, is_stateful,
    row_checksums, verify_rows, wire_bytes,
)
from repro.faults import fault_state_update, flip_row_bits
from repro.core.payload import PayloadSelector
from repro.core.selector import (
    AsyncSelectorState, SelectorConfig, SelectorState, async_selector_init,
    pending_lookup, pending_record, pull_stats, selector_init,
    selector_observe, selector_select,
)
from repro.kernels import ops
from repro.obs.telemetry import RoundTelemetry
from repro.utils.compat import optimization_barrier
from repro.optim.adam import (
    AdamConfig, AdamState, adam_init, adam_update_rows,
    adam_update_rows_scattered,
)
from repro.optim.state_compress import MomentCodecConfig, needs_sr_key

# fold_in salt deriving the per-commit stochastic-rounding key from the
# round's selection key (only when the moment config statically needs one,
# so fp32 programs never see the extra fold)
_MOMENT_KEY_SALT = 0x6d71    # "mq"


class FCFServerConfig(NamedTuple):
    theta: int = 100              # federated updates needed per global update
    adam: AdamConfig = AdamConfig(
        lr=0.01, beta1=0.1, beta2=0.99, eps=1e-8)   # paper Table 3
    # Bandit feedback (beyond-paper fix, ablatable): each user's Eq. 6
    # gradient carries a +2λq_j term; aggregated over Θ users the feedback
    # becomes  data_term + 2λΘ·q_j.  The λ part is popularity-INDEPENDENT
    # noise ∝ |q_j| that swamps the informative data term at early rounds —
    # measured corr(reward, popularity) = -0.35 at t=1, locking the bandit
    # onto uninformative items (worse than FCF-Random on MIND-scale data).
    # The server knows λ, Θ and Q*, so it subtracts 2λΘ·q_j from the
    # FEEDBACK ONLY (the model update keeps the paper's exact Eq. 4);
    # no extra client information is used.  "raw" reproduces the paper.
    reward_feedback: str = "data_term"          # "data_term" | "raw"
    l2: float = 1.0
    # async engine: a commit against a snapshot s rounds stale scales its
    # Adam step by discount**s (FedAsync-style exponential damping; 1.0
    # disables damping, 0.0 makes stale commits step-free). s=0 commits are
    # always undamped (discount**0 == 1.0 exactly). 0.8 measured best on the
    # movielens-mini staleness curves (benchmarks/async_cohorts.py): heavy
    # damping (0.5) costs more P@10 than the staleness it guards against on
    # a smooth simulated cohort stream.
    staleness_discount: float = 0.8
    # optimizer-state storage (repro.optim.state_compress): how Adam's
    # per-row moments live in memory. None (and the all-fp32 config) is the
    # frozen fp32 path — bit-identical programs to every historical run.
    # Compressed options (bf16 / int8-with-per-row-scales / SM3-factored v)
    # shrink the resident optimizer state below the model itself at
    # 10M-item scale; static config, never part of the scan carry.
    moment: Optional[MomentCodecConfig] = None


class ServerState(NamedTuple):
    """The whole FL server as a pure pytree (scan carry / vmap axis)."""

    q: jax.Array            # (M, K) global model Q^T
    opt: AdamState          # per-row Adam moments + timesteps
    sel: SelectorState      # strategy-specific selector state
    key: jax.Array          # PRNG key driving the selection stream
    t: jax.Array            # () int32 — committed global rounds
    # cumulative payload bytes as traced float32 scalars. NOTE: float32 is
    # exact only up to 2^24; past that the running totals round to the local
    # ulp. The payload is shape-constant per round, so exact totals are
    # always recoverable as t x per-round bytes (what SimResult reports).
    bytes_down: jax.Array   # () float32 — cumulative payload downlink bytes
    bytes_up: jax.Array     # () float32 — cumulative payload uplink bytes
    # payload codec state: the (M, K) error-feedback residual for stateful
    # codecs (topk uplink sparsification), the empty pytree () otherwise —
    # either way a fixed-shape scan carry / vmap axis
    codec: Any = ()
    # async engine only: bounded ring of the last max_staleness+1 ENCODED
    # downlink snapshots (wire pytree leaves with a leading (slots,) axis —
    # S int8 snapshots cost S payload-sized wire images, not S full (M, K)
    # tables). The empty pytree () for the synchronous backends.
    snapshots: Any = ()
    # fault layer only (repro.faults): a FaultState of cumulative degradation
    # counters — dropped clients, stragglers, checksum-rejected rows,
    # retransmit bytes — carried as traced scalars exactly like the byte
    # counters. The empty pytree () whenever fault injection is off, which
    # keeps the carry structure (and every compiled program) identical to a
    # faultless build.
    faults: Any = ()


class RoundAux(NamedTuple):
    """Per-round outputs surfaced by the fused step (scan ``ys``)."""

    indices: jax.Array      # (M_s,) selected arms
    rewards: jax.Array      # (M_s,) bandit rewards (zeros for non-learners)
    # RoundTelemetry when the step is built with telemetry=True, else the
    # empty pytree — the default keeps the pytree structure (and therefore
    # every compiled program and shard out_spec) identical to a build
    # without the obs layer
    telemetry: Any = ()


class ShardContext(NamedTuple):
    """Static description of one FL round's data-parallel execution.

    Inside ``shard_map`` over a 1-D ``(axis,)`` device mesh, every (M, K)
    table (global model Q, Adam moments, BTS reward buffers, codec residual)
    is row-sharded into ``rows_per_shard = M // num_shards`` blocks, the
    cohort is split into ``num_shards`` user blocks (one per device), and all
    small control state (selector posteriors, PRNG key, byte counters) is
    replicated. See :func:`server_round_step` for the collective schedule.
    """

    axis: str               # mesh axis name the tables/cohort shard over
    num_shards: int         # D — devices on the axis
    rows_per_shard: int     # M // D rows of each (M, K) table per device


def shard_row_ops(shard: ShardContext) -> ops.RowOps:
    """Collective-aware row ops over row-sharded (M, K) tables.

    gather: each shard block-gathers a full (M_s, K) candidate (clamped
    local indices, one kernel pass over its own rows), the candidates are
    all-gathered, and the owner-select keeps each row from the one shard
    that holds it — pure data movement, so the assembled rows are bit-equal
    to a single-device gather. scatter_set: shard-local drop-scatter of the
    rows this shard owns (no collective; every shard already holds the full
    (M_s, K) update replicated).
    """
    def gather(table: jax.Array, idx: jax.Array) -> jax.Array:
        cand = ops.gather_rows_block(table, _local_idx(shard, idx))
        # barrier per the RowOps contract: consumers must see the same
        # materialized producer graph as the single-device gather
        return optimization_barrier(assemble_rows(shard, idx, cand))

    def scatter_set(table: jax.Array, idx: jax.Array,
                    rows: jax.Array) -> jax.Array:
        return ops.scatter_set_rows_block(table, _local_idx(shard, idx), rows)

    return ops.RowOps(gather=gather, scatter_set=scatter_set)


def _local_idx(shard: ShardContext, idx: jax.Array) -> jax.Array:
    """Global payload indices -> this shard's local row coordinates."""
    d = jax.lax.axis_index(shard.axis)
    return idx.astype(jnp.int32) - d * shard.rows_per_shard


def assemble_rows(shard: ShardContext, idx: jax.Array,
                  candidate: jax.Array) -> jax.Array:
    """All-gather per-shard candidate blocks and keep each row's owner copy.

    ``candidate`` is this shard's (M_s, ...) block-gather result (rows it
    does not own are clamp artifacts). The all-gather moves the candidate in
    whatever format it is in — for the int8 downlink that is the quantized
    wire image, 4x fewer bytes on the interconnect than fp32 rows — and the
    owner-select is exact (selection, not summation), so the assembled block
    is bit-identical to the single-device gather.
    """
    gathered = jax.lax.all_gather(candidate, shard.axis, axis=0)  # (D, M_s, .)
    owner = (idx.astype(jnp.int32) // shard.rows_per_shard)[None, :, None]
    return jnp.take_along_axis(gathered, owner, axis=0)[0]


def snapshot_ring_init(
    codec_cfg: CodecConfig, slots: int, num_rows: int, dim: int
) -> Any:
    """All-zero ring of ``slots`` encoded downlink snapshots.

    Leaves mirror the downlink wire format with a leading (slots,) axis, so
    the ring is a fixed-shape scan carry whose size is ``slots`` payload
    wire images (codes + scales for int8, halves for fp16, ...). Zero slots
    are never decoded: the async staleness schedule clamps s <= t-1, so
    every slot is published before it is first committed against.
    """
    down_cfg, _ = direction_configs(codec_cfg)
    proto = encode(down_cfg, jnp.zeros((num_rows, dim), jnp.float32))
    return jax.tree.map(
        lambda leaf: jnp.zeros((slots,) + leaf.shape, leaf.dtype), proto)


def _ring_put(ring: Any, slot: jax.Array, wire: Any) -> Any:
    """Overwrite ring ``slot`` (traced index) with a fresh wire image."""
    return jax.tree.map(
        lambda r, w: jax.lax.dynamic_update_index_in_dim(r, w, slot, 0),
        ring, wire)


def _ring_get(ring: Any, slot: jax.Array) -> Any:
    """The wire image stored in ring ``slot`` (traced index)."""
    return jax.tree.map(
        lambda r: jax.lax.dynamic_index_in_dim(r, slot, 0, keepdims=False),
        ring)


class EncodedSnapshot(NamedTuple):
    """One published downlink snapshot, still in its wire format.

    The serving publish artifact: ``wire`` holds the encoded payload rows
    exactly as the async engine pushed them into the ring (int8 codes +
    per-row scales, fp16 halves, ...), ``indices`` names the global item
    rows they cover, ``t`` is the publish round. Consumers that keep their
    model in wire format (:class:`repro.serve.ServingModel`) install these
    rows without ever decoding to fp32 — per-row encoding makes the row
    patch bit-identical to re-encoding the patched dense table.
    """

    t: jax.Array            # () int32 — publish round
    indices: jax.Array      # (M_s,) int32 — global rows the wire covers
    wire: Any               # downlink wire pytree for those rows


def latest_snapshot(state: ServerState) -> EncodedSnapshot:
    """The freshest ring entry of an async-engine state (no decode).

    After round ``t`` commits, the newest published snapshot lives in ring
    slot ``rem(t-1, slots)`` and its pull is recorded in the selector's
    pending-attribution buffer — both are popped here as-is. Requires a
    state built with ``server_init(async_slots=...)`` that has run at least
    one round (slot 0 is all-zero before the first publish).
    """
    sel_async = state.sel
    assert isinstance(sel_async, AsyncSelectorState), (
        "latest_snapshot needs a state built with "
        "server_init(async_slots=...)")
    slots = sel_async.pending.t.shape[0]
    slot = jax.lax.rem(state.t - 1, slots)
    idx, t_pub = pending_lookup(sel_async.pending, slot)
    return EncodedSnapshot(
        t=t_pub, indices=idx, wire=_ring_get(state.snapshots, slot))


def server_init(
    item_factors: jax.Array,
    sel_cfg: SelectorConfig,
    key: jax.Array,
    config: FCFServerConfig = FCFServerConfig(),
    codec_cfg: CodecConfig = CodecConfig(),
    async_slots: Optional[int] = None,
    force_residual: bool = False,
) -> ServerState:
    """Fresh server state around an initialized global model.

    ``async_slots`` (= ``max_staleness + 1``) equips the state for the
    async engine: the selector is wrapped with a pending-attribution buffer
    and the encoded-snapshot ring is allocated. ``None`` (synchronous)
    leaves both as empty pytrees.

    ``force_residual`` allocates the (M, K) error-feedback residual even for
    stateless codecs — required by the fault layer's corruption path, where
    checksum-rejected rows are retained in the residual for retransmit no
    matter which codec runs the uplink.
    """
    # config is static hyper-parameters — only the moment-storage choice
    # shapes the state pytree (compressed AdamState leaves)
    sel: Any = selector_init(sel_cfg)
    snapshots: Any = ()
    if async_slots is not None:
        sel = async_selector_init(sel_cfg, async_slots)
        snapshots = snapshot_ring_init(
            codec_cfg, async_slots, sel_cfg.num_select,
            item_factors.shape[1])
    return ServerState(
        q=item_factors,
        opt=adam_init(item_factors, per_row=True, moment=config.moment),
        sel=sel,
        key=key,
        t=jnp.zeros((), jnp.int32),
        bytes_down=jnp.zeros((), jnp.float32),
        bytes_up=jnp.zeros((), jnp.float32),
        codec=codec_state_init(
            codec_cfg, item_factors.shape[0], item_factors.shape[1],
            force_residual=force_residual),
        snapshots=snapshots,
    )


def _downlink_wire(state_q: jax.Array, idx: jax.Array, down_cfg: CodecConfig,
                   shard: Optional[ShardContext]):
    """Gather + encode the payload rows Q* into their wire image.

    Single device: one kernel pass over the resident table (fused
    gather+quantize for int8). Sharded: each device encodes the candidate
    rows of its own block *first* and only then all-gathers, so the
    collective moves the wire image (int8 codes + per-row scales for int8,
    fp16 halves for fp16) instead of fp32 rows — the "all-gather the
    selected-and-compressed rows, not the table" schedule. Encoding is
    per-row, so owner-selected rows are bit-identical to a single-device
    encode.
    """
    if shard is None:
        if down_cfg.name == "int8":
            # hot path: fused gather+quantize kernel (one HBM trip per row)
            return QuantWire(*ops.gather_quantize_rows(state_q, idx))
        return encode(down_cfg, ops.gather_rows(state_q, idx))
    local = _local_idx(shard, idx)
    if down_cfg.name == "int8":
        wire_local = QuantWire(*ops.gather_quantize_rows_block(state_q, local))
    else:
        wire_local = encode(down_cfg, ops.gather_rows_block(state_q, local))
    return jax.tree.map(lambda leaf: assemble_rows(shard, idx, leaf),
                        wire_local)


def server_round_step(
    state: ServerState,
    cohort_x,                      # (B, M) cohort rows, or idx -> cohort blocks
    *,
    sel_cfg: SelectorConfig,
    config: FCFServerConfig,
    cf_cfg: CFConfig,
    codec_cfg: CodecConfig = CodecConfig(),
    num_users: Optional[int] = None,
    shard: Optional[ShardContext] = None,
    telemetry: bool = False,
    faults: Any = None,
) -> Tuple[ServerState, RoundAux]:
    """One fused FL round (Alg. 1 lines 8-19) as a pure function.

    ``faults`` (a :class:`repro.faults.RoundFaults`, default ``None``)
    activates this round's slice of the pre-sampled fault schedule: the
    driver has already zeroed dropped/straggling users out of ``cohort_x``
    and passes the traced survivor count as ``num_users`` (gradient
    renormalization over survivors); here the wire-corruption schedule
    drives the checksum reject path in the commit core, the per-user uplink
    cost grows by the checksum word, and the cumulative degradation
    counters on ``state.faults`` advance. ``None`` compiles the historical
    program byte-for-byte.

    ``telemetry`` (static) additionally surfaces a :class:`RoundTelemetry`
    of traced in-step scalars on ``RoundAux.telemetry`` — wire bytes,
    gradient/update norms, arm-pull coverage, and (under ``shard_map``) the
    psum-reduced per-round collective bytes. The default ``False`` adds no
    ops at all: the obs layer's disabled-path bit-parity contract.

    The cohort of B users stands in for the asynchronous arrival of exactly
    Theta federated updates that triggers a global commit; the server only
    ever sees the aggregated gradient (the paper's privacy model).

    ``cohort_x`` is either the dense (B, M) cohort slice of the interaction
    matrix, or a callable mapping the selected indices (M_s,) to the cohort's
    column subset directly — the lazy form lets the driver fuse the
    user-row/item-column gather into one indexed read instead of
    materializing (B, M) per round (a real cost at web-scale M). The callable
    may return either a flat (B, M_s) block or pre-blocked (C, b, M_s) user
    blocks; padded user rows (all-zero x) contribute exactly zero to every
    aggregate, so drivers pad the cohort to equal blocks and pass the true
    cohort size as ``num_users``.

    CLIENT PHASE BLOCKING. The cohort solve + item gradients are computed
    per user block, and the per-block partial gradients are reduced in fixed
    block order behind a ``lax.optimization_barrier`` (the barrier pins the
    reduction boundary so XLA cannot refuse the blocks' materialization and
    re-fuse the sum into a differently-ordered accumulation). This makes the
    round's float semantics a function of the *block structure only*: a
    single device scanning C blocks and a ``shard_map`` mesh solving one
    block per device over C devices produce bit-identical trajectories —
    the all-gather of partials followed by the same ordered sum is exactly
    an order-fixed psum.

    Bit-parity caveat: the contract is enforced (by tier-1 test) for the
    fp32/fp16/int8 codecs across every strategy. The int4/topk *programs*
    fuse their unpack/sparsify chains into the moment-update loops, and
    XLA:CPU's FMA-contraction choice inside those fusions can differ
    between the sharded and single-device programs — trajectories then
    agree to float32 contraction ulps (~1e-7 relative) rather than
    bit-for-bit. Selections and wire bytes remain identical.

    SHARDED EXECUTION (``shard`` set, inside ``shard_map``): the (M, K)
    tables in ``state`` (Q, Adam moments, BTS reward buffers, codec
    residual) are row-sharded over ``shard.axis``; selection and all small
    state are replicated. Per round only payload-sized tensors cross the
    interconnect: the encoded Q* candidates (all-gather), the (M_s, K)
    partial gradients (all-gather == ordered psum), and the row gathers of
    the Adam/reward/residual tables; every scatter commit is shard-local.

    ``codec_cfg`` names the wire format for the item-dependent payload
    (:mod:`repro.compress`). Every transmitted tensor physically goes
    through encode->decode, so clients solve against the *decoded* Q* and
    the server commits the *decoded* gradients — quality degradation from
    lossy codecs is real, not just accounted. The int8 downlink routes
    through the fused gather+quantize Pallas kernel; stateful codecs carry
    their error-feedback residual in ``state.codec`` (residual rows are
    gathered/scattered with the payload kernels alongside Q). In the
    simulation the cohort-aggregated uplink gradient is encoded once — the
    wire image of the aggregate each of the ``B`` users' updates passes
    through — and the per-user byte accounting multiplies that row cost
    by ``B``, exactly like the dense accounting did.
    """
    down_cfg, up_cfg = direction_configs(codec_cfg)
    m_s = sel_cfg.num_select
    kdim = state.q.shape[1]
    key, k_sel = jax.random.split(state.key)

    # lines 8-10: select the payload subset, gather + encode + "transmit" Q*;
    # clients decode the wire image, so q_star below is what they compute on
    idx, sel = selector_select(sel_cfg, state.sel, k_sel)
    q_star = decode(down_cfg, _downlink_wire(state.q, idx, down_cfg, shard),
                    kdim)                                    # (M_s, K)
    q_star = optimization_barrier(q_star)
    bytes_down = state.bytes_down + wire_bytes(down_cfg, m_s, kdim)

    # lines 11-18: cohort solve, uplink, Adam commit, reward feedback.
    # The stochastic-rounding dither key only exists when the moment config
    # statically requires one — fp32 programs trace no extra PRNG ops.
    moment_key = (jax.random.fold_in(k_sel, _MOMENT_KEY_SALT)
                  if needs_sr_key(config.moment) else None)
    has_corrupt = faults is not None and not isinstance(faults.corrupt, tuple)
    q_new, opt, sel, codec_state, rewards, num_users, stats, intact = \
        _commit_against(
            state, sel, idx, q_star, cohort_x, sel_cfg=sel_cfg, config=config,
            cf_cfg=cf_cfg, up_cfg=up_cfg, num_users=num_users, shard=shard,
            want_stats=telemetry,
            corrupt=faults.corrupt if has_corrupt else None,
            moment_key=moment_key)
    per_user_bytes = wire_bytes(up_cfg, m_s, kdim)
    if has_corrupt:
        per_user_bytes += m_s * CHECKSUM_BYTES_PER_ROW
    bytes_up = state.bytes_up + per_user_bytes * num_users

    fault_state = state.faults
    if faults is not None:
        rejected = (jnp.zeros((), jnp.float32) if intact is None
                    else jnp.sum(~intact).astype(jnp.float32))
        fault_state = fault_state_update(
            state.faults, faults.dropped, faults.stragglers, rejected,
            rejected * float(wire_bytes(up_cfg, 1, kdim)
                             + CHECKSUM_BYTES_PER_ROW))

    new_state = ServerState(
        q=q_new, opt=opt, sel=sel, key=key, t=state.t + 1,
        bytes_down=bytes_down, bytes_up=bytes_up, codec=codec_state,
        snapshots=state.snapshots, faults=fault_state,
    )
    aux_tel: Any = ()
    if telemetry:
        aux_tel = _round_telemetry(
            new_state, sel_cfg, down_cfg, up_cfg, m_s, kdim, num_users,
            shard, stats,
            staleness=jnp.zeros((), jnp.float32),
            step_weight=jnp.ones((), jnp.float32))
    return new_state, RoundAux(indices=idx, rewards=rewards,
                               telemetry=aux_tel)


def _round_telemetry(
    new_state: ServerState,
    sel_cfg: SelectorConfig,
    down_cfg: CodecConfig,
    up_cfg: CodecConfig,
    m_s: int,
    kdim: int,
    num_users,
    shard: Optional[ShardContext],
    stats,
    *,
    staleness: jax.Array,
    step_weight: jax.Array,
) -> RoundTelemetry:
    """Assemble one round's :class:`RoundTelemetry` (telemetry=True only).

    ``collective_bytes`` prices what each shard puts on the interconnect
    per round — its encoded Q* candidate block plus its fp32 partial
    gradient block, both (M_s,)-sized — psum-reduced over the mesh axis so
    every shard reports the same mesh-total. 0 off-mesh.
    """
    if shard is None:
        collective = jnp.zeros((), jnp.float32)
    else:
        per_shard = jnp.float32(
            wire_bytes(down_cfg, m_s, kdim) + m_s * kdim * 4)
        collective = jax.lax.psum(per_shard, shard.axis)
    arms_explored, pull_max = pull_stats(sel_cfg, new_state.sel)
    grad_norm, update_norm = stats
    return RoundTelemetry(
        t=new_state.t,
        staleness=jnp.asarray(staleness, jnp.float32),
        step_weight=jnp.asarray(step_weight, jnp.float32),
        bytes_down=jnp.float32(wire_bytes(down_cfg, m_s, kdim)),
        bytes_up=jnp.float32(wire_bytes(up_cfg, m_s, kdim))
        * jnp.asarray(num_users, jnp.float32),
        collective_bytes=collective,
        grad_norm=grad_norm,
        update_norm=update_norm,
        arms_explored=arms_explored,
        pull_max=pull_max,
    )


def _commit_against(
    state: ServerState,
    sel: SelectorState,
    idx: jax.Array,                # (M_s,) payload rows the cohort solved on
    q_star: jax.Array,             # (M_s, K) decoded snapshot they solved with
    cohort_x,                      # (B, M) rows, or idx -> cohort blocks
    *,
    sel_cfg: SelectorConfig,
    config: FCFServerConfig,
    cf_cfg: CFConfig,
    up_cfg: CodecConfig,
    num_users: Optional[int],
    shard: Optional[ShardContext],
    t_obs: Optional[jax.Array] = None,
    step_weight: Optional[jax.Array] = None,
    want_stats: bool = False,
    corrupt: Optional[jax.Array] = None,
    moment_key: Optional[jax.Array] = None,
):
    """Alg. 1 lines 11-18 against a given (idx, Q*) pair — the commit core.

    Shared verbatim by the synchronous and async round steps: the sync step
    passes the snapshot it just published (``t_obs=None``, no step weight);
    the async step passes a *stale* snapshot popped from the ring plus its
    pull round (delay-corrected reward) and the staleness discount for the
    Adam step. Returns ``(q, opt, sel, codec_state, rewards, num_users,
    stats, intact)`` with ``stats`` a traced ``(grad_norm, update_norm)``
    pair when ``want_stats`` (telemetry) is on and ``None`` otherwise — the
    extra row gathers behind the norms are only ever traced when requested,
    so the default program is unchanged.

    ``corrupt`` ((M_s,) bool, the fault layer's pre-sampled wire-corruption
    schedule) activates payload integrity verification: the encoded uplink
    wire gets a per-row checksum, the scheduled rows have one bit flipped in
    transit, and rows whose received checksum mismatches are REJECTED — the
    model/moment/reward commit treats them as never received (exact no-op
    rows via ``row_mask``) while the error-feedback residual retains their
    full effective gradient for retransmit next round. Requires a state
    built with ``server_init(force_residual=True)`` so the residual exists
    for stateless codecs too. ``intact`` is the (M_s,) bool accept mask
    (``None`` when ``corrupt`` is ``None``, which compiles the historical
    program byte-for-byte).
    """
    row_ops = ops.default_row_ops() if shard is None else shard_row_ops(shard)
    kdim = state.q.shape[1]

    # line 11: every cohort user solves p_i on-device and uplinks gradients;
    # the server receives the cohort aggregate, assembled block-by-block
    if callable(cohort_x):
        x_blocks = cohort_x(idx)                 # (C, b, M_s) or (B, M_s)
    else:
        x_blocks = jnp.take(cohort_x, idx, axis=1)           # (B, M_s)
    if x_blocks.ndim == 2:
        x_blocks = x_blocks[None]                            # one block
    if num_users is None:
        num_users = x_blocks.shape[0] * x_blocks.shape[1]
    parts = []
    for i in range(x_blocks.shape[0]):
        p_i = solve_user_factors(q_star, x_blocks[i],
                                 l2=cf_cfg.l2, alpha=cf_cfg.alpha)
        # data term only (l2=0): the ridge term is applied once, below, with
        # the true cohort size — padded all-zero user rows solve to p=0 and
        # contribute exactly zero here
        parts.append(ops.fcf_item_gradients(
            q_star, p_i, x_blocks[i], alpha=cf_cfg.alpha, l2=0.0))
    parts = jnp.stack(parts)                                 # (C, M_s, K)
    if shard is not None:
        # ordered psum: all-gather the per-device partials and reduce in
        # fixed block order — bit-stable against the single-device scan
        # over the same blocks (a raw lax.psum orders by topology)
        parts = jax.lax.all_gather(parts, shard.axis, axis=0, tiled=True)
    parts = optimization_barrier(parts)
    grads = (jnp.sum(parts, axis=0)
             + 2.0 * cf_cfg.l2 * num_users * q_star)         # (M_s, K)

    # uplink encode (+ error feedback for stateful codecs): the server only
    # ever sees the decoded wire image of the aggregated gradient
    codec_state = state.codec
    intact = None
    if corrupt is not None:
        # payload integrity path: checksum the encoded wire, flip the
        # scheduled rows' bits in transit, reject rows whose received image
        # no longer matches. Rejected rows keep their full effective
        # gradient in the residual so the next round's encode retransmits
        # them; accepted rows behave exactly like the faultless codec path.
        res_rows = row_ops.gather(codec_state, idx)          # (M_s, K)
        eff = grads + res_rows
        wire = encode(up_cfg, eff)
        decoded = decode(up_cfg, wire, kdim)
        sums = row_checksums(wire)
        received = flip_row_bits(wire, corrupt)
        intact = verify_rows(received, sums)                 # (M_s,) bool
        keep = intact[:, None]
        grads_hat = jnp.where(keep, decoded, 0.0)
        if is_stateful(up_cfg):
            new_res = jnp.where(keep, eff - decoded, eff)
        else:
            new_res = jnp.where(keep, jnp.zeros_like(eff), eff)
        codec_state = row_ops.scatter_set(codec_state, idx, new_res)
    elif is_stateful(up_cfg):
        res_rows = row_ops.gather(codec_state, idx)          # (M_s, K)
        _, grads_hat, new_res = encode_with_residual(up_cfg, grads, res_rows)
        codec_state = row_ops.scatter_set(codec_state, idx, new_res)
    else:
        grads_hat = decode(up_cfg, encode(up_cfg, grads), kdim)
    grads_hat = optimization_barrier(grads_hat)

    # line 13: sparse Adam commit on the selected rows (scatter kernels;
    # shard-local scatters against the row-sharded tables when sharded),
    # step-discounted by staleness under the async engine
    q_new, opt = adam_update_rows_scattered(
        grads_hat, idx, state.opt, state.q, config.adam, row_ops=row_ops,
        row_weights=step_weight, row_mask=intact,
        moment=config.moment, moment_key=moment_key)

    # lines 14-18: reward feedback + posterior update — on the decoded
    # gradients (the only thing a codec-running server would have), delay-
    # corrected to the pull round when the feedback arrived stale
    feedback = grads_hat
    if config.reward_feedback == "data_term":
        feedback = optimization_barrier(
            grads_hat - 2.0 * config.l2 * num_users * q_star)
    sel, rewards = selector_observe(sel_cfg, sel, idx, feedback,
                                    row_ops=row_ops, t_obs=t_obs,
                                    row_mask=intact)
    stats = None
    if want_stats:
        delta = row_ops.gather(q_new, idx) - row_ops.gather(state.q, idx)
        stats = (jnp.linalg.norm(grads_hat), jnp.linalg.norm(delta))
    return q_new, opt, sel, codec_state, rewards, num_users, stats, intact


def server_round_step_async(
    state: ServerState,
    cohort_x,                      # (B, M) cohort rows, or idx -> cohort blocks
    staleness: jax.Array,          # () int32 — this commit's snapshot age
    *,
    sel_cfg: SelectorConfig,
    config: FCFServerConfig,
    cf_cfg: CFConfig,
    codec_cfg: CodecConfig = CodecConfig(),
    num_users: Optional[int] = None,
    shard: Optional[ShardContext] = None,
    telemetry: bool = False,
    faults: Any = None,
) -> Tuple[ServerState, RoundAux]:
    """One staleness-bounded ASYNC round: publish fresh, commit stale.

    ``faults`` mirrors :func:`server_round_step`'s fault hook: the
    corruption schedule gates the commit core's checksum reject path (the
    stale commit's wire rows are the ones corrupted — faults hit arriving
    traffic, whatever round it was pulled in), survivors/``num_users`` were
    applied by the driver, and the degradation counters advance on
    ``state.faults``. ``None`` compiles the historical program
    byte-for-byte.

    ``telemetry`` (static) mirrors :func:`server_round_step`'s flag; the
    async telemetry additionally reports this commit's snapshot age and
    the ``staleness_discount ** s`` step weight it applied.

    The paper's deployment model has users reporting back asynchronously;
    this step simulates it with the cohort block as the async unit. Each
    round the server

      1. PUBLISHES: pulls a fresh payload subset, encodes Q* into its wire
         image and pushes it into the bounded snapshot ring
         (``state.snapshots``, ``slots = max_staleness + 1``), recording the
         pull in the selector's pending-attribution buffer;
      2. COMMITS: pops the snapshot published ``staleness`` rounds ago —
         the cohort that reports back this round solved against THAT
         (possibly stale) Q* — and runs the exact synchronous commit core
         against it, with two async corrections: the Adam step is scaled by
         ``staleness_discount ** s`` (:func:`adam_update_rows_scattered`'s
         per-row weights) and the bandit reward is attributed to the arm
         pulls of the snapshot round (``selector_observe(t_obs=...)``).

    ``staleness`` must satisfy ``0 <= s <= min(max_staleness, t-1)`` — the
    driver's schedule guarantees it, so every popped slot was pushed first.
    Clients decode the ring's wire image, so a stale int8 snapshot is the
    same lossy tensor a real stale client would hold.

    With ``staleness == 0`` every round, the popped snapshot is the one
    just pushed, the discount is exactly 1.0 and ``t_obs`` equals the
    current round: the trajectory is bit-identical to
    :func:`server_round_step` at equal cohort blocking (tier-1 contract,
    ``tests/test_async_cohorts.py``). Under ``shard_map`` the ring and
    pending buffer are replicated (payload-sized) while the tables stay
    row-sharded — a stale block is just a block solved against an older Q*,
    so the sharded collective schedule is unchanged.

    Sharded-async parity caveat (same class as the sync engine's int4/topk
    note in :func:`server_round_step`): at ``staleness=0`` the sharded async
    program is bit-identical to the single-device async scan for every
    strategy and codec, and stays bit-identical at s > 0 for int8. For the
    raw-fp32 downlink at s > 0, XLA:CPU's contraction choices around the
    ring slice differ between the two programs and trajectories agree to
    float32 ulps (~1e-9 absolute on Q) rather than bit-for-bit; selections
    and wire bytes remain identical. Enforced by
    ``tests/test_async_cohorts.py``'s fake-device subprocess matrix.
    """
    down_cfg, up_cfg = direction_configs(codec_cfg)
    m_s = sel_cfg.num_select
    kdim = state.q.shape[1]
    sel_async = state.sel
    assert isinstance(sel_async, AsyncSelectorState), (
        "server_round_step_async needs a state built with "
        "server_init(async_slots=...)")
    slots = sel_async.pending.t.shape[0]
    key, k_sel = jax.random.split(state.key)

    # publish: fresh pull, encode, push wire + pending attribution. The
    # barrier pins the wire image's producer graph at the push — the popped
    # snapshot must decode from the same materialized bits no matter which
    # round (or which shard program) consumes it.
    idx, inner = selector_select(sel_cfg, sel_async.inner, k_sel)
    t_now = state.t + 1
    slot_now = jax.lax.rem(t_now - 1, slots)
    wire_now = optimization_barrier(
        _downlink_wire(state.q, idx, down_cfg, shard))
    ring = _ring_put(state.snapshots, slot_now, wire_now)
    pending = pending_record(sel_async.pending, slot_now, idx, t_now)
    bytes_down = state.bytes_down + wire_bytes(down_cfg, m_s, kdim)

    # commit: pop the snapshot `staleness` rounds back and solve against it
    s = jnp.asarray(staleness, jnp.int32)
    slot_old = jax.lax.rem(t_now - 1 - s, slots)
    idx_s, t_s = pending_lookup(pending, slot_old)
    q_star = decode(down_cfg, _ring_get(ring, slot_old), kdim)
    q_star = optimization_barrier(q_star)
    step_weight = jnp.full(
        (m_s,),
        jnp.power(jnp.float32(config.staleness_discount),
                  s.astype(jnp.float32)))
    moment_key = (jax.random.fold_in(k_sel, _MOMENT_KEY_SALT)
                  if needs_sr_key(config.moment) else None)
    has_corrupt = faults is not None and not isinstance(faults.corrupt, tuple)
    q_new, opt, inner, codec_state, rewards, num_users, stats, intact = \
        _commit_against(
            state, inner, idx_s, q_star, cohort_x, sel_cfg=sel_cfg,
            config=config, cf_cfg=cf_cfg, up_cfg=up_cfg, num_users=num_users,
            shard=shard, t_obs=t_s, step_weight=step_weight,
            want_stats=telemetry,
            corrupt=faults.corrupt if has_corrupt else None,
            moment_key=moment_key)
    per_user_bytes = wire_bytes(up_cfg, m_s, kdim)
    if has_corrupt:
        per_user_bytes += m_s * CHECKSUM_BYTES_PER_ROW
    bytes_up = state.bytes_up + per_user_bytes * num_users

    fault_state = state.faults
    if faults is not None:
        rejected = (jnp.zeros((), jnp.float32) if intact is None
                    else jnp.sum(~intact).astype(jnp.float32))
        fault_state = fault_state_update(
            state.faults, faults.dropped, faults.stragglers, rejected,
            rejected * float(wire_bytes(up_cfg, 1, kdim)
                             + CHECKSUM_BYTES_PER_ROW))

    new_state = state._replace(
        q=q_new, opt=opt,
        sel=AsyncSelectorState(inner=inner, pending=pending),
        key=key, t=t_now, bytes_down=bytes_down, bytes_up=bytes_up,
        codec=codec_state, snapshots=ring, faults=fault_state,
    )
    aux_tel: Any = ()
    if telemetry:
        aux_tel = _round_telemetry(
            new_state, sel_cfg, down_cfg, up_cfg, m_s, kdim, num_users,
            shard, stats,
            staleness=s.astype(jnp.float32), step_weight=step_weight[0])
    return new_state, RoundAux(indices=idx_s, rewards=rewards,
                               telemetry=aux_tel)


# ===================================================================== #
# Legacy mutable shim (incremental receive protocol)
# ===================================================================== #
@dataclass
class FCFServer:
    """Mutable Python-driven server (legacy shim over the pure pieces).

    Unlike :func:`server_round_step` (one fused call per round), this keeps
    the incremental protocol: ``begin_round()`` exposes Q*, any number of
    ``receive`` calls accumulate cohort gradients, and the Theta-threshold
    triggers the commit — matching a real deployment's asynchronous arrivals.
    """

    item_factors: jax.Array            # (M, K) global model Q^T
    selector: PayloadSelector
    config: FCFServerConfig = field(default_factory=FCFServerConfig)

    opt_state: Optional[AdamState] = None
    _selected: Optional[jax.Array] = None          # current round's item ids
    _grad_accum: Optional[jax.Array] = None        # (M_s, K) accumulated grads
    _updates_accum: int = 0                        # NumberGradientUpdates
    rounds_committed: int = 0
    bytes_down: int = 0                            # payload accounting
    bytes_up: int = 0

    def __post_init__(self):
        if self.opt_state is None:
            from repro.optim.state_compress import is_compressed
            if is_compressed(self.config.moment):
                raise ValueError(
                    "the legacy FCFServer shim only supports fp32 optimizer "
                    "state; compressed moment configs need the fused round "
                    "engine (server_init / server_round_step)")
            self.opt_state = adam_init(self.item_factors, per_row=True)

    # ---------------------------------------------------------------- #
    def begin_round(self) -> jax.Array:
        """Select the payload subset and return Q* rows (Alg. 1 lines 8-10)."""
        self._selected = self.selector.select()
        q_star = ops.gather_rows(self.item_factors, self._selected)
        self.bytes_down += q_star.size * q_star.dtype.itemsize
        return q_star

    @property
    def selected(self) -> jax.Array:
        assert self._selected is not None, "call begin_round() first"
        return self._selected

    def receive(self, grad_rows: jax.Array, num_users: int) -> bool:
        """Accumulate a cohort's aggregated gradient (Alg. 1 line 11).

        Returns True if this receipt triggered a global-model commit.
        """
        assert self._selected is not None, "call begin_round() first"
        # each participating user uplinks its own (M_s, K) gradient
        self.bytes_up += grad_rows.size * grad_rows.dtype.itemsize * num_users
        if self._grad_accum is None:
            self._grad_accum = grad_rows
        else:
            self._grad_accum = self._grad_accum + grad_rows
        self._updates_accum += num_users
        if self._updates_accum >= self.config.theta:
            self._commit()
            return True
        return False

    # ---------------------------------------------------------------- #
    def _commit(self) -> None:
        """Global update + bandit feedback (Alg. 1 lines 13-19)."""
        idx, grads = self._selected, self._grad_accum
        q_star = ops.gather_rows(self.item_factors, idx)
        # line 13: Q <- Q - eta * sum_i grad_i (Adam-adapted, Eq. 4)
        self.item_factors, self.opt_state = adam_update_rows(
            grads, idx, self.opt_state, self.item_factors, self.config.adam
        )
        # lines 14-18: v update, rewards, BTS posterior, prev-grad buffer
        feedback = grads
        if self.config.reward_feedback == "data_term":
            feedback = grads - 2.0 * self.config.l2 * self._updates_accum \
                * q_star
        self.selector.observe(idx, feedback)
        self.rounds_committed += 1
        self._grad_accum = None
        self._updates_accum = 0

    # ---------------------------------------------------------------- #
    @property
    def num_items(self) -> int:
        return self.item_factors.shape[0]

    @property
    def num_factors(self) -> int:
        return self.item_factors.shape[1]
