"""Recommendation metrics (Sec. 6.2): Precision/Recall/F1/MAP@10, normalized
by the theoretically best achievable value per user (Flanagan et al. S2-S5
convention), aggregated over the evaluated user cohort.

All functions are jit-safe and batched over users.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class RecMetrics(NamedTuple):
    precision: jax.Array
    recall: jax.Array
    f1: jax.Array
    map: jax.Array

    def as_dict(self):
        return {
            "precision": float(self.precision), "recall": float(self.recall),
            "f1": float(self.f1), "map": float(self.map),
        }


def theoretical_best(test_counts: jax.Array, top_k: int = 10) -> RecMetrics:
    """Best achievable @top_k when recommending straight from the test set.

    A perfect ranking places min(|test|, k) relevant items first:
      precision* = min(t, k) / k,   recall* = min(t, k) / t,   AP* = 1.
    """
    t = test_counts.astype(jnp.float32)
    cap = jnp.minimum(t, float(top_k))
    prec = cap / top_k
    rec = jnp.where(t > 0, cap / jnp.maximum(t, 1.0), 0.0)
    f1 = jnp.where(prec + rec > 0, 2 * prec * rec / (prec + rec), 0.0)
    ap = jnp.where(t > 0, 1.0, 0.0)
    return RecMetrics(prec, rec, f1, ap)


def _metrics_at_k(rel: jax.Array, test_counts: jax.Array, top_k: int) -> RecMetrics:
    """Per-user raw metrics from the relevance pattern of the top-k list.

    rel: (B, top_k) binary — 1 if the k-th recommended item is in the test set.
    """
    t = test_counts.astype(jnp.float32)
    hits = jnp.sum(rel, axis=-1)
    prec = hits / top_k
    rec = jnp.where(t > 0, hits / jnp.maximum(t, 1.0), 0.0)
    f1 = jnp.where(prec + rec > 0, 2 * prec * rec / (prec + rec), 0.0)
    # MAP@k: mean over users of AP@k = sum_k P@k * rel_k / min(t, k)
    ranks = jnp.arange(1, top_k + 1, dtype=jnp.float32)
    cum_hits = jnp.cumsum(rel, axis=-1)
    p_at_k = cum_hits / ranks
    ap = jnp.sum(p_at_k * rel, axis=-1) / jnp.maximum(jnp.minimum(t, float(top_k)), 1.0)
    ap = jnp.where(t > 0, ap, 0.0)
    return RecMetrics(prec, rec, f1, ap)


@partial(jax.jit, static_argnames=("top_k",))
def ranked_metrics_from_indices(
    idx: jax.Array,           # (B, top_k) ranked item ids (train already masked)
    test_x: jax.Array,        # (B, M) binary test interactions (ground truth)
    top_k: int = 10,
) -> RecMetrics:
    """Normalized metrics from an already-ranked top-k id list.

    The scores themselves never enter the metrics — only the ranked ids do —
    so any scorer that reproduces ``ranked_metrics``'s ranking (e.g. the
    fused chunked scorer in :mod:`repro.kernels.payload_score`, which shares
    the ``NEG_INF`` mask sentinel and ``lax.top_k`` tie order) yields
    bit-identical metrics without materializing the (B, M) score matrix.
    """
    rel = jnp.take_along_axis(test_x, idx, axis=-1)        # (B, top_k)
    test_counts = jnp.sum(test_x, axis=-1)

    raw = _metrics_at_k(rel, test_counts, top_k)
    best = theoretical_best(test_counts, top_k)

    valid = (test_counts > 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(valid), 1.0)

    def norm_mean(r, b):
        ratio = jnp.where(b > 0, r / jnp.maximum(b, 1e-12), 0.0)
        return jnp.sum(ratio * valid) / denom

    return RecMetrics(
        precision=norm_mean(raw.precision, best.precision),
        recall=norm_mean(raw.recall, best.recall),
        f1=norm_mean(raw.f1, best.f1),
        map=norm_mean(raw.map, best.map),
    )


@partial(jax.jit, static_argnames=("top_k",))
def ranked_metrics(
    scores: jax.Array,        # (B, M) recommendation scores
    train_x: jax.Array,       # (B, M) binary train interactions (masked out)
    test_x: jax.Array,        # (B, M) binary test interactions (ground truth)
    top_k: int = 10,
) -> RecMetrics:
    """Normalized metrics, averaged over users with non-empty test sets."""
    masked = jnp.where(train_x > 0, NEG_INF, scores)
    _, idx = jax.lax.top_k(masked, top_k)                  # (B, top_k)
    return ranked_metrics_from_indices(idx, test_x, top_k=top_k)


def evaluate_users(
    item_factors: jax.Array,  # (M, K) full global model (inference download)
    train_x: jax.Array,       # (B, M)
    test_x: jax.Array,        # (B, M)
    l2: float = 1.0,
    alpha: float = 4.0,
    top_k: int = 10,
    item_chunk: int | None = None,
) -> RecMetrics:
    """End-to-end on-device evaluation: solve p_i from train data against the
    downloaded global model, score all items, rank, compute normalized metrics
    on the held-out 20% (Sec. 6.2).

    ``item_chunk`` routes scoring through the fused chunked top-k path
    (:func:`repro.kernels.wire_topn` over an fp32 wire view of the table),
    which never materializes the dense (B, M) fp32 score matrix — the fix for
    large-M eval. Chunking cannot change a score (each dot reduces over K
    only) and the chunk merge preserves ``lax.top_k``'s tie order, so the
    result is bit-identical to the dense path (tested in test_serving.py).
    """
    from repro.cf.local import solve_user_factors

    p = solve_user_factors(item_factors, train_x, l2=l2, alpha=alpha)
    if item_chunk is None:
        scores = p @ item_factors.T
        return ranked_metrics(scores, train_x, test_x, top_k=top_k)

    from repro.compress import CodecConfig, DenseWire
    from repro.kernels import wire_topn

    wire = DenseWire(values=item_factors.astype(jnp.float32))
    _, idx = wire_topn(CodecConfig(name="fp32"), wire, p,
                       item_factors.shape[1], top_k, train_mask=train_x,
                       block_m=item_chunk)
    return ranked_metrics_from_indices(idx, test_x, top_k=top_k)
