"""TopList baseline (Sec. 6): recommend the most popular training items to
every user. Non-personalized, non-federated — the naive payload 'optimizer'
(ship nothing, use a static list)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cf.metrics import RecMetrics, ranked_metrics


def toplist_ranking(train_counts: jax.Array, list_len: int = 100) -> jax.Array:
    """Items ranked by training-set interaction frequency. (list_len,) ids."""
    _, idx = jax.lax.top_k(train_counts.astype(jnp.float32), list_len)
    return idx


def toplist_scores(train_counts: jax.Array) -> jax.Array:
    """Popularity as a score vector shared by all users: (M,)."""
    return train_counts.astype(jnp.float32)


def evaluate_toplist(
    train_counts: jax.Array,  # (M,) global training popularity
    train_x: jax.Array,       # (B, M) per-user train interactions
    test_x: jax.Array,        # (B, M)
    top_k: int = 10,
    mask_train: bool = False,
) -> RecMetrics:
    """TopList metrics. ``mask_train=False`` matches the paper's static
    100-most-popular list shared by all users (Sec. 6.2)."""
    b = train_x.shape[0]
    scores = jnp.broadcast_to(toplist_scores(train_counts)[None, :], train_x.shape)
    if not mask_train:
        train_mask = jnp.zeros_like(train_x)
    else:
        train_mask = train_x
    return ranked_metrics(scores, train_mask, test_x, top_k=top_k)
