"""Collaborative Filtering model (Sec. 2.1) — the base recommender.

X ~ P^T Q with P in R^{K x N} (user factors, private, on device) and
Q in R^{K x M} (item factors, the *global model* whose payload the paper
optimizes). We store Q transposed as (M, K): row j = item j's factor q_j.
Row-major item layout makes payload row-gather/scatter contiguous, which is
also what the Pallas payload_gather kernel assumes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CFConfig(NamedTuple):
    num_users: int
    num_items: int
    num_factors: int = 25     # K (paper Table 3)
    l2: float = 1.0           # lambda
    alpha: float = 4.0        # implicit-confidence weight: c = 1 + alpha*x
    init_scale: float = 0.01


class CFModel(NamedTuple):
    item_factors: jax.Array   # (M, K) — the global model Q^T
    # user factors are NOT stored server-side: they are private and exactly
    # recomputable on-device from (Q, x_i) via the closed-form solve (Eq. 3).


def cf_init(config: CFConfig, key: jax.Array) -> CFModel:
    q = config.init_scale * jax.random.normal(
        key, (config.num_items, config.num_factors), jnp.float32
    )
    return CFModel(item_factors=q)


def predict_scores(user_factors: jax.Array, item_factors: jax.Array) -> jax.Array:
    """x_hat = p_i^T q_j for a batch of users: (B, K) x (M, K) -> (B, M)."""
    return user_factors @ item_factors.T
