from repro.cf.model import CFConfig, CFModel, cf_init
from repro.cf.local import solve_user_factors, item_gradients, local_update
from repro.cf.server import (
    EncodedSnapshot, FCFServer, FCFServerConfig, RoundAux, ServerState,
    ShardContext, latest_snapshot, server_init, server_round_step,
    shard_row_ops,
)
from repro.cf.metrics import (
    RecMetrics, evaluate_users, ranked_metrics, ranked_metrics_from_indices,
    theoretical_best,
)
from repro.cf.toplist import toplist_ranking

__all__ = [
    "CFConfig", "CFModel", "cf_init",
    "solve_user_factors", "item_gradients", "local_update",
    "FCFServer", "FCFServerConfig",
    "EncodedSnapshot", "ServerState", "RoundAux", "ShardContext",
    "latest_snapshot", "server_init", "server_round_step", "shard_row_ops",
    "RecMetrics", "evaluate_users", "ranked_metrics",
    "ranked_metrics_from_indices", "theoretical_best", "toplist_ranking",
]
