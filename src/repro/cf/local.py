"""Client-side (on-device) FCF computation — Sec. 2.2, Eqs. 3, 5, 6.

Everything here sees only (a) the user's own interaction row x_i and (b) the
item factors the server chose to transmit (full Q or the payload subset Q*).
The functions are batched over a cohort of users with vmap-style semantics so
the simulation can process Theta users per round in one jit call; in a real
deployment each user runs the B=1 slice.

Implicit-feedback algebra used throughout (binary x, c = 1 + alpha*x):
  Q C^i Q^T = Q Q^T + alpha * (Q^T diag(x_i) Q)   [only interacted items]
  Q C^i x_i = (1 + alpha) * Q^T x_i                [since x in {0,1}]
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cf.model import CFConfig


@lru_cache(maxsize=None)
def _tri_maps(k: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Upper-triangle index maps for the symmetric K x K mirror trick.

    Returns ``(iu, il, tri_of_flat)``: the upper-triangle coordinates and the
    flattened (K*K,) gather map that mirrors a packed K(K+1)/2 triangle back
    to the full symmetric matrix. Cached per K so repeated retraces of the
    round step and the eval path (both route through
    :func:`solve_user_factors`) stop rebuilding the O(K^2) numpy maps on
    every trace.
    """
    iu, il = np.triu_indices(k)
    tri_of = np.zeros((k, k), np.int32)
    tri_of[iu, il] = np.arange(iu.size)
    tri_of[il, iu] = tri_of[iu, il]
    return iu, il, tri_of.reshape(-1)


@partial(jax.jit, static_argnames=("l2", "alpha"))
def solve_user_factors(
    item_factors: jax.Array,   # (M_s, K) transmitted item factors (rows of Q^T)
    x: jax.Array,              # (B, M_s) binary interactions restricted to them
    l2: float = 1.0,
    alpha: float = 4.0,
) -> jax.Array:
    """Exact per-user solve (Eq. 3), batched: returns (B, K) user factors.

    p_i* = (Q C^i Q^T + lambda I)^(-1) Q C^i x_i

    The per-user correction alpha * sum_j x_ij q_j q_j^T is symmetric, so it
    is assembled as ONE (B, M_s) x (M_s, K(K+1)/2) matmul over the upper
    triangle of the q_j outer products and mirrored afterwards — ~2x fewer
    flops than the naive (b, m, k, l) einsum and a BLAS-friendly shape. This
    is the flop hot spot of every FL round (and of evaluation).
    """
    q = item_factors
    k = q.shape[-1]
    gram = q.T @ q                                     # (K, K), shared term
    # upper-triangle outer products: (M_s, K(K+1)/2)
    iu, il, tri_of_flat = _tri_maps(k)
    qq_tri = q[:, iu] * q[:, il]
    corr_tri = x @ qq_tri                              # (B, K(K+1)/2)
    # mirror to the full symmetric (B, K, K) via the cached gather map
    corr = corr_tri[:, tri_of_flat].reshape(x.shape[0], k, k)
    lhs = gram[None] + alpha * corr + l2 * jnp.eye(k, dtype=q.dtype)[None]
    rhs = (1.0 + alpha) * (x @ q)                      # (B, K)
    # lhs = Q^T Q + alpha*sum x q q^T + l2 I is SPD by construction, so a
    # batched Cholesky + two triangular solves (~3x cheaper than LU)
    chol = jnp.linalg.cholesky(lhs)
    y = jax.lax.linalg.triangular_solve(
        chol, rhs[..., None], left_side=True, lower=True)
    p = jax.lax.linalg.triangular_solve(
        chol, y, left_side=True, lower=True, transpose_a=True)
    return p[..., 0]


@partial(jax.jit, static_argnames=("l2", "alpha"))
def item_gradients(
    item_factors: jax.Array,   # (M_s, K)
    user_factors: jax.Array,   # (B, K)
    x: jax.Array,              # (B, M_s)
    l2: float = 1.0,
    alpha: float = 4.0,
) -> jax.Array:
    """Aggregated item gradients over the user cohort (Eqs. 5-6): (M_s, K).

    Per user i, item j:
      dJ_i/dq_j = -2 c_ij (x_ij - p_i^T q_j) p_i + 2 lambda q_j
    Summed over the B users in the cohort (the server only ever sees the sum,
    preserving the paper's aggregate-only privacy model):
      grad = -2 * (C . E)^T P + 2 lambda B q
    with E = X - P Q^T the residual and C = 1 + alpha X the confidence.
    """
    b = x.shape[0]
    err = x - user_factors @ item_factors.T            # (B, M_s)
    cw = 1.0 + alpha * x                               # confidence c_ij
    weighted = cw * err                                # (B, M_s)
    grad = -2.0 * (weighted.T @ user_factors)          # (M_s, K)
    grad = grad + 2.0 * l2 * b * item_factors
    return grad


def local_update(
    item_factors: jax.Array,
    x: jax.Array,
    config: CFConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Full client round: solve p_i (Eq. 3) then gradients (Eq. 6).

    Returns (user_factors (B, K), aggregated item gradients (M_s, K)).
    """
    p = solve_user_factors(item_factors, x, l2=config.l2, alpha=config.alpha)
    g = item_gradients(item_factors, p, x, l2=config.l2, alpha=config.alpha)
    return p, g
