"""Static-analysis engine core: source model, findings, suppressions.

The engine is deliberately small: a :class:`Project` is a set of parsed
Python files plus the repo root they are relative to; a rule is any object
with a ``name``, a ``description`` and a ``check(project)`` generator; the
engine runs every rule and filters the findings through per-line / per-file
suppression comments. Everything contract-specific lives in
:mod:`repro.analysis.rules`.

Suppressions::

    x = np.array(data)        # repro-lint: disable=dtype-width -- host stats
    # repro-lint: disable-file=traced-purity -- host-only driver module

``disable=`` applies to findings on its own line (or on the line above,
so multi-line calls can carry the comment on their first line);
``disable-file=`` anywhere in the file applies to the whole file. A
suppression must name the rule(s) it silences — there is no bare
"disable everything" form, so every exemption stays attributable. The
``--`` tail is an optional free-form justification; CI treats an
undocumented suppression the same as a documented one, but review should
not.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

# paths containing any of these fragments are never linted by default —
# the rule-fixture corpus deliberately violates every rule
DEFAULT_EXCLUDES = ("__pycache__", "analysis_fixtures")

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable|disable-file)=([A-Za-z0-9_,\- ]+)")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str        # rule name, e.g. "dtype-width"
    path: str        # repo-relative posix path
    line: int        # 1-based source line (0 = whole-file finding)
    message: str

    def key(self) -> str:
        """Baseline identity: stable across pure line-number drift."""
        return f"{self.rule}::{self.path}::{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class SourceFile:
    """One parsed source file with its suppression table."""

    path: str                   # absolute
    relpath: str                # repo-relative posix
    text: str
    tree: ast.Module
    # line -> rules silenced on that line; "disable-file" lands in file_rules
    line_rules: Dict[int, Set[str]] = field(default_factory=dict)
    file_rules: Set[str] = field(default_factory=set)

    @classmethod
    def parse(cls, path: str, relpath: str) -> "SourceFile":
        with open(path, "r", encoding="utf-8") as f:
            text = f.read()
        tree = ast.parse(text, filename=path)
        sf = cls(path=path, relpath=relpath, text=text, tree=tree)
        for lineno, line in enumerate(text.splitlines(), start=1):
            m = _SUPPRESS_RE.search(line)
            if not m:
                continue
            # "-- justification" tail is free-form commentary, not a rule
            spec = m.group(2).split("--")[0]
            rules = {r.strip() for r in spec.split(",") if r.strip()}
            if m.group(1) == "disable-file":
                sf.file_rules |= rules
            else:
                sf.line_rules.setdefault(lineno, set()).update(rules)
        return sf

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_rules:
            return True
        # a disable= comment silences its own line and the line below it,
        # so a multi-line expression can carry the comment just above
        for probe in (line, line - 1):
            if rule in self.line_rules.get(probe, set()):
                return True
        return False


@dataclass
class Project:
    """The lint unit: parsed files + the root their relpaths hang off."""

    root: str
    files: List[SourceFile]
    parse_errors: List[Finding] = field(default_factory=list)

    def by_relpath(self, relpath: str) -> Optional[SourceFile]:
        for f in self.files:
            if f.relpath == relpath:
                return f
        return None

    def matching(self, prefix: str) -> List[SourceFile]:
        return [f for f in self.files if f.relpath.startswith(prefix)]


def _iter_py_files(path: str, excludes: Sequence[str]) -> Iterator[str]:
    if os.path.isfile(path):
        yield path
        return
    for dirpath, dirnames, filenames in os.walk(path):
        dirnames[:] = sorted(
            d for d in dirnames
            if not any(e in os.path.join(dirpath, d) for e in excludes))
        for name in sorted(filenames):
            if name.endswith(".py"):
                full = os.path.join(dirpath, name)
                if not any(e in full for e in excludes):
                    yield full


def load_project(
    paths: Sequence[str],
    root: Optional[str] = None,
    excludes: Sequence[str] = DEFAULT_EXCLUDES,
) -> Project:
    """Parse every ``.py`` under ``paths`` into a :class:`Project`.

    ``root`` anchors the repo-relative paths findings and baselines use;
    it defaults to the current working directory (CI runs from the repo
    root). Unparseable files become parse-error findings instead of
    aborting the run — a syntax error must fail the lint, not crash it.
    """
    root = os.path.abspath(root or os.getcwd())
    files: List[SourceFile] = []
    errors: List[Finding] = []
    seen: Set[str] = set()
    for p in paths:
        for path in _iter_py_files(os.path.abspath(p), excludes):
            if path in seen:
                continue
            seen.add(path)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            try:
                files.append(SourceFile.parse(path, rel))
            except SyntaxError as e:
                errors.append(Finding(
                    rule="parse-error", path=rel, line=e.lineno or 0,
                    message=f"syntax error: {e.msg}"))
    return Project(root=root, files=files, parse_errors=errors)


def run_rules(project: Project, rules: Iterable) -> List[Finding]:
    """Run every rule over the project; filter suppressed findings."""
    findings: List[Finding] = list(project.parse_errors)
    for rule in rules:
        for finding in rule.check(project):
            src = project.by_relpath(finding.path)
            if src is not None and src.suppressed(finding.rule, finding.line):
                continue
            findings.append(finding)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return findings
