"""Project-wide symbol index + traced-call-graph walker.

The purity and dtype rules need to know which functions execute *inside* a
``jit``/``scan``/``shard_map`` trace. That set is computed statically:

  * every file under a source root maps to a dotted module name
    (``src/repro/cf/server.py`` -> ``repro.cf.server``);
  * per module we index top-level (and nested) function defs plus the
    import table (``from repro.kernels import ops`` -> ``ops`` means module
    ``repro.kernels.ops``), following one level of package re-export
    (``from repro.compress import decode`` resolves through
    ``repro/compress/__init__.py``'s own from-imports);
  * traced ROOTS are (a) an explicit dotted-name list (the fused round
    steps and their kernels), (b) any function carrying a ``jit`` /
    ``pmap`` / ``shard_map`` decorator, and (c) any local function passed
    by name into ``jax.jit(...)`` / ``jax.lax.scan(...)`` /
    ``shard_map(...)`` — which picks up the simulation drivers' compiled
    chunk closures without hand-listing them;
  * the traced set is the BFS closure of project-resolvable calls from the
    roots. Nested defs and lambdas of a traced function are walked as part
    of its body.

Resolution is best-effort by design: a call we cannot resolve (data-driven
dispatch, closure variables, third-party code) is simply not followed.
That keeps the walker precise — it never guesses — at the cost of relying
on the explicit root list for entry points reached dynamically.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Project, SourceFile

# decorator / wrapper identifiers that mark a function as a trace entry
_TRACE_MARKERS = {"jit", "pmap", "shard_map", "eval_shape", "vmap", "scan"}


def module_name_for(relpath: str) -> Optional[str]:
    """Dotted module name for a repo-relative path, or None off-src."""
    norm = relpath.replace(os.sep, "/")
    if "src/" in norm:
        norm = norm.split("src/", 1)[1]
    elif not norm.startswith(("repro/", "repro.")):
        return None
    if not norm.endswith(".py"):
        return None
    norm = norm[: -len(".py")]
    if norm.endswith("/__init__"):
        norm = norm[: -len("/__init__")]
    return norm.replace("/", ".")


@dataclass
class FunctionInfo:
    """One function (possibly nested) in one module."""

    module: str
    qualname: str                # "outer.<locals>.inner" flattened to dots
    node: ast.AST                # FunctionDef | AsyncFunctionDef
    src: SourceFile

    @property
    def ref(self) -> Tuple[str, str]:
        return (self.module, self.qualname)


@dataclass
class ModuleInfo:
    name: str
    src: SourceFile
    functions: Dict[str, FunctionInfo] = field(default_factory=dict)
    # local name -> dotted target: "ops" -> "repro.kernels.ops" (module
    # import) or "decode" -> "repro.compress.decode" (from-import)
    imports: Dict[str, str] = field(default_factory=dict)


class ProjectIndex:
    """Symbol tables + call resolution over a parsed :class:`Project`."""

    def __init__(self, project: Project):
        self.project = project
        self.modules: Dict[str, ModuleInfo] = {}
        for src in project.files:
            mod = module_name_for(src.relpath)
            if mod is None:
                continue
            self.modules[mod] = _index_module(mod, src)

    # ------------------------------------------------------------- #
    # name resolution
    # ------------------------------------------------------------- #
    def dotted_name(self, node: ast.AST, mod: ModuleInfo) -> Optional[str]:
        """Canonical dotted name of a Name/Attribute chain, import-resolved.

        ``np.random.default_rng`` -> ``numpy.random.default_rng`` when the
        module imported ``numpy as np``; unresolvable heads fall back to
        their source spelling so bans on e.g. ``time.`` still match direct
        ``import time`` modules.
        """
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = mod.imports.get(node.id, node.id)
        parts.append(head)
        return ".".join(reversed(parts))

    def resolve_call(
        self, node: ast.Call, mod: ModuleInfo, scope: FunctionInfo
    ) -> Optional[FunctionInfo]:
        """The project function a call statically resolves to, if any."""
        func = node.func
        if isinstance(func, ast.Name):
            # local / same-module function, else a from-import
            name = func.id
            sib = mod.functions.get(f"{scope.qualname}.{name}") \
                or mod.functions.get(name)
            if sib is not None:
                return sib
            return self._resolve_dotted(mod.imports.get(name))
        if isinstance(func, ast.Attribute):
            return self._resolve_dotted(self.dotted_name(func, mod))
        return None

    def _resolve_dotted(self, dotted: Optional[str],
                        depth: int = 0) -> Optional[FunctionInfo]:
        if dotted is None or "." not in dotted or depth > 4:
            return None
        mod_name, attr = dotted.rsplit(".", 1)
        target = self.modules.get(mod_name)
        if target is None:
            return None
        fn = target.functions.get(attr)
        if fn is not None:
            return fn
        # one level of package re-export: __init__.py from-imports
        return self._resolve_dotted(target.imports.get(attr), depth + 1)

    # ------------------------------------------------------------- #
    # traced closure
    # ------------------------------------------------------------- #
    def traced_functions(
        self, roots: Sequence[str] = ()
    ) -> Dict[Tuple[str, str], FunctionInfo]:
        """BFS closure of the traced call graph.

        ``roots`` are dotted names; ``repro.kernels.*`` means every public
        top-level function of every module under that package. Decorator /
        wrapper roots are discovered automatically.
        """
        queue: List[FunctionInfo] = []
        for root in roots:
            queue.extend(self._root_functions(root))
        for mod in self.modules.values():
            for fn in mod.functions.values():
                if _is_marked_root(fn, mod, self):
                    queue.append(fn)

        traced: Dict[Tuple[str, str], FunctionInfo] = {}
        while queue:
            fn = queue.pop()
            if fn.ref in traced:
                continue
            traced[fn.ref] = fn
            mod = self.modules[fn.module]
            for call in _calls_in(fn.node):
                callee = self.resolve_call(call, mod, fn)
                if callee is not None:
                    queue.append(callee)
        return traced

    def _root_functions(self, root: str) -> List[FunctionInfo]:
        if root.endswith(".*"):
            prefix = root[:-2]
            out: List[FunctionInfo] = []
            for name, mod in self.modules.items():
                if name == prefix or name.startswith(prefix + "."):
                    out.extend(fn for qn, fn in mod.functions.items()
                               if "." not in qn and not qn.startswith("_"))
            return out
        fn = self._resolve_dotted(root)
        return [fn] if fn is not None else []


def _index_module(name: str, src: SourceFile) -> ModuleInfo:
    info = ModuleInfo(name=name, src=src)

    for node in ast.walk(src.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                info.imports[alias.asname or alias.name.split(".")[0]] = \
                    alias.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            base = node.module
            if node.level:  # relative import: anchor on this package
                pkg = name.rsplit(".", node.level)[0]
                base = f"{pkg}.{node.module}" if node.module else pkg
            for alias in node.names:
                if alias.name == "*":
                    continue
                info.imports[alias.asname or alias.name] = \
                    f"{base}.{alias.name}"

    def collect(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                info.functions[qual] = FunctionInfo(
                    module=name, qualname=qual, node=child, src=src)
                collect(child, qual)
            elif isinstance(child, ast.ClassDef):
                # methods indexed as Class.method (not callable by bare name)
                collect(child, f"{prefix}.{child.name}" if prefix
                        else child.name)
            elif not isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                collect(child, prefix)

    collect(src.tree, "")
    return info


def _is_marked_root(fn: FunctionInfo, mod: ModuleInfo,
                    index: ProjectIndex) -> bool:
    """jit/pmap/shard_map decorator, or passed by name into jit/scan/..."""
    node = fn.node
    for dec in getattr(node, "decorator_list", []):
        for sub in ast.walk(dec):
            if isinstance(sub, ast.Name) and sub.id in _TRACE_MARKERS:
                return True
            if isinstance(sub, ast.Attribute) and sub.attr in _TRACE_MARKERS:
                return True
    # find Name references to this function used as an argument of a
    # jit/scan/shard_map call anywhere in its own module
    short = fn.qualname.rsplit(".", 1)[-1]
    for call in _calls_in(mod.src.tree):
        dotted = index.dotted_name(call.func, mod) or ""
        tail = dotted.rsplit(".", 1)[-1]
        if tail not in _TRACE_MARKERS:
            continue
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(arg, ast.Name) and arg.id == short:
                return True
    return False


def _calls_in(node: ast.AST) -> List[ast.Call]:
    return [n for n in ast.walk(node) if isinstance(n, ast.Call)]


def local_bindings(fn_node: ast.AST) -> Set[str]:
    """Names bound inside a function body (params, assigns, loops, withs).

    Used to separate trace-time-local container mutation (fine: invisible
    outside the trace) from mutation of closure/global state (impure).
    Nested function defs contribute their own params only to themselves,
    but their assignments are conservatively counted as local here — the
    purity rule walks the whole body at once.
    """
    names: Set[str] = set()
    args = getattr(fn_node, "args", None)
    if args is not None:
        for a in (args.posonlyargs + args.args + args.kwonlyargs
                  + ([args.vararg] if args.vararg else [])
                  + ([args.kwarg] if args.kwarg else [])):
            names.add(a.arg)
    for sub in ast.walk(fn_node):
        if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(sub.name)
            sub_args = sub.args
            for a in (sub_args.posonlyargs + sub_args.args
                      + sub_args.kwonlyargs
                      + ([sub_args.vararg] if sub_args.vararg else [])
                      + ([sub_args.kwarg] if sub_args.kwarg else [])):
                names.add(a.arg)
        elif isinstance(sub, ast.Lambda):
            for a in (sub.args.posonlyargs + sub.args.args
                      + sub.args.kwonlyargs):
                names.add(a.arg)
        elif isinstance(sub, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = sub.targets if isinstance(sub, ast.Assign) \
                else [sub.target]
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        names.add(n.id)
        elif isinstance(sub, (ast.For, ast.AsyncFor)):
            for n in ast.walk(sub.target):
                if isinstance(n, ast.Name):
                    names.add(n.id)
        elif isinstance(sub, (ast.With, ast.AsyncWith)):
            for item in sub.items:
                if item.optional_vars is not None:
                    for n in ast.walk(item.optional_vars):
                        if isinstance(n, ast.Name):
                            names.add(n.id)
        elif isinstance(sub, ast.comprehension):
            for n in ast.walk(sub.target):
                if isinstance(n, ast.Name):
                    names.add(n.id)
        elif isinstance(sub, (ast.Global, ast.Nonlocal)):
            # declared non-local on purpose: NOT local
            names.difference_update(sub.names)
    return names
