"""traced-purity: no host effects inside jit/scan/shard_map-traced code.

The functional core's contract (PR 1 onward): everything reachable from
the fused round steps, the scan chunk bodies and the Pallas kernels is a
pure function of its inputs. This rule walks the traced call graph
(:mod:`repro.analysis.callgraph`) and flags:

  * host clocks / host RNG / host I/O calls (``time.*``, ``np.random.*``,
    stdlib ``random.*``, ``print``/``open``/``input``/``breakpoint``) —
    each would be baked in at trace time or fire per-trace, silently
    desynchronizing the scan/python/shard/async bit-parity contracts;
  * mutation of state the function does not own — ``global`` /
    ``nonlocal`` declarations and mutating method calls
    (``.append``/``.update``/...) or subscript-stores on names that are
    not bound inside the function (trace-time mutation of *local*
    containers is fine and idiomatic: building block lists for
    ``jnp.stack``);
  * ``io_callback`` / ``jax.debug.print`` / ``jax.debug.callback``
    anywhere outside the sanctioned batched-telemetry module — the obs
    subsystem's zero-overhead-when-off contract allows exactly one
    batched, ordered callback per compiled chunk, emitted by
    ``repro.federated.simulation`` (this sub-check is module-wide, not
    call-graph-scoped: an unsanctioned callback is wrong wherever it
    hides).

``jax.random.*`` is the sanctioned traced RNG and is never flagged.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Sequence, Tuple

from repro.analysis.callgraph import (
    FunctionInfo, ProjectIndex, local_bindings,
)
from repro.analysis.core import Finding, Project

# entry points traced by jit/lax.scan/shard_map that no decorator marks:
# the fused round steps (called inside the drivers' compiled closures)
# and every public Pallas kernel / kernel dispatcher
DEFAULT_ROOTS = (
    "repro.cf.server.server_round_step",
    "repro.cf.server.server_round_step_async",
    "repro.kernels.*",
)

# modules allowed to host the batched telemetry io_callback
DEFAULT_SANCTIONED_CALLBACKS = ("repro.federated.simulation",)

_BANNED_PREFIXES: Tuple[Tuple[str, str], ...] = (
    ("time.", "host clock read"),
    ("numpy.random.", "host RNG"),
    ("random.", "host RNG"),
    ("datetime.", "host clock read"),
    ("builtins.print", "host I/O"),
    ("builtins.open", "host I/O"),
    ("builtins.input", "host I/O"),
    ("builtins.breakpoint", "host debugger"),
)

_CALLBACK_TAILS = {"io_callback", "pure_callback"}
_MUTATORS = {"append", "extend", "insert", "remove", "pop", "clear",
             "update", "setdefault", "add", "discard", "popitem",
             "appendleft", "extendleft"}


class TracedPurityRule:
    name = "traced-purity"
    description = ("functions reachable from jit/scan/shard_map entry "
                   "points must be pure: no host clocks/RNG/I-O, no "
                   "mutation of non-local state, no unsanctioned "
                   "host callbacks")

    def __init__(self, roots: Sequence[str] = DEFAULT_ROOTS,
                 sanctioned_callback_modules: Sequence[str] =
                 DEFAULT_SANCTIONED_CALLBACKS):
        self.roots = tuple(roots)
        self.sanctioned = tuple(sanctioned_callback_modules)

    def check(self, project: Project) -> Iterator[Finding]:
        index = ProjectIndex(project)
        traced = index.traced_functions(self.roots)
        for fn in traced.values():
            yield from self._check_function(fn, index)
        # module-wide callback discipline (independent of the call graph)
        for mod_name, mod in sorted(index.modules.items()):
            if any(mod_name == s or mod_name.startswith(s + ".")
                   for s in self.sanctioned):
                continue
            if not mod_name.startswith("repro."):
                continue
            for call in ast.walk(mod.src.tree):
                if not isinstance(call, ast.Call):
                    continue
                dotted = index.dotted_name(call.func, mod) or ""
                tail = dotted.rsplit(".", 1)[-1]
                if tail in _CALLBACK_TAILS or dotted.endswith(
                        ("jax.debug.print", "jax.debug.callback",
                         "debug.print", "debug.callback")):
                    yield Finding(
                        rule=self.name, path=mod.src.relpath,
                        line=call.lineno,
                        message=(f"host callback `{dotted}` outside the "
                                 f"sanctioned batched-telemetry path "
                                 f"({', '.join(self.sanctioned)})"))

    # ------------------------------------------------------------- #
    def _check_function(self, fn: FunctionInfo,
                        index: ProjectIndex) -> Iterator[Finding]:
        mod = index.modules[fn.module]
        local = local_bindings(fn.node)
        short = fn.qualname.rsplit(".", 1)[-1]

        for node in ast.walk(fn.node):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                kind = "global" if isinstance(node, ast.Global) else \
                    "nonlocal"
                yield Finding(
                    rule=self.name, path=fn.src.relpath, line=node.lineno,
                    message=(f"`{kind} {', '.join(node.names)}` in traced "
                             f"function `{short}` mutates state outside "
                             f"the trace"))
            elif isinstance(node, ast.Call):
                yield from self._check_call(node, fn, mod, index, local,
                                            short)
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    # x[i] = v / x.attr = v where x is a free variable
                    base = t
                    while isinstance(base, (ast.Subscript, ast.Attribute)):
                        base = base.value
                    if (isinstance(base, ast.Name) and base is not t
                            and base.id not in local
                            and base.id != "self"
                            and base.id not in mod.imports):
                        yield Finding(
                            rule=self.name, path=fn.src.relpath,
                            line=node.lineno,
                            message=(f"traced function `{short}` stores "
                                     f"into free variable `{base.id}` — "
                                     f"mutation of non-local state"))

    def _check_call(self, node: ast.Call, fn: FunctionInfo, mod, index,
                    local, short) -> Iterator[Finding]:
        dotted = index.dotted_name(node.func, mod)
        if dotted is not None:
            canon = dotted
            if canon in ("print", "open", "input", "breakpoint"):
                canon = f"builtins.{canon}"
            if not canon.startswith("jax."):
                for prefix, why in _BANNED_PREFIXES:
                    if canon == prefix or canon.startswith(prefix) \
                            or canon == prefix.rstrip("."):
                        yield Finding(
                            rule=self.name, path=fn.src.relpath,
                            line=node.lineno,
                            message=(f"{why} `{dotted}` inside traced "
                                     f"function `{short}`"))
                        break
        # container mutation on a free variable: free.append(...)
        func = node.func
        if (isinstance(func, ast.Attribute) and func.attr in _MUTATORS
                and isinstance(func.value, ast.Name)):
            name = func.value.id
            if name not in local and name != "self" \
                    and name not in mod.imports:
                yield Finding(
                    rule=self.name, path=fn.src.relpath, line=node.lineno,
                    message=(f"traced function `{short}` calls "
                             f"`{name}.{func.attr}(...)` on a free "
                             f"variable — mutation of non-local state"))
