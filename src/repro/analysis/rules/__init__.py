"""Rule registry for the contract-enforcing static analysis.

Each rule guards one of the repo's hand-enforced invariants (see
docs/INVARIANTS.md). Default instances are built by :func:`default_rules`;
tests and special runs can instantiate rule classes with their own
scopes/roots.
"""
from repro.analysis.rules.dtype import DtypeWidthRule
from repro.analysis.rules.faults import FaultCarryRule
from repro.analysis.rules.locks import LockGuardRule
from repro.analysis.rules.parity import KernelParityRule
from repro.analysis.rules.purity import TracedPurityRule
from repro.analysis.rules.pytree import PytreeCarryRule

RULE_CLASSES = (
    TracedPurityRule,
    PytreeCarryRule,
    KernelParityRule,
    DtypeWidthRule,
    LockGuardRule,
    FaultCarryRule,
)


def default_rules(disable=()):
    """One default-configured instance of every registered rule."""
    disabled = set(disable)
    return [cls() for cls in RULE_CLASSES if cls.name not in disabled]


def rule_names():
    return [cls.name for cls in RULE_CLASSES]


__all__ = [
    "DtypeWidthRule", "FaultCarryRule", "KernelParityRule",
    "LockGuardRule", "PytreeCarryRule", "TracedPurityRule", "RULE_CLASSES",
    "default_rules", "rule_names",
]
