"""pytree-carry: scan-carry NamedTuples may hold only pytree-leaf fields.

Every state object that rides a ``lax.scan`` carry or crosses a
``shard_map`` boundary (``ServerState``, ``TelemetryState``, the selector
/ codec / optimizer states) must be a pytree whose leaves are arrays (or
nested registered pytrees): a stray ``int``/``str``/config field either
gets silently promoted to a weak-typed traced array (changing dtypes
mid-trajectory) or breaks the carry structure equality that ``lax.scan``
requires. Static configuration belongs in the step closure, not the
carry.

Carry classes are discovered by convention + closure: every NamedTuple
class named ``*State`` or ``*Wire`` under the linted sources, an explicit
extra list for the scan ``ys`` pytrees (``RoundAux``, ``RoundTelemetry``,
``EncodedSnapshot``), and — transitively — any NamedTuple referenced from
a carry field annotation (that is how ``PendingAttribution`` and
``BTSState`` get checked without being listed).

Allowed field annotations: ``jax.Array`` / ``jnp.ndarray`` / ``Array``,
``Any`` (a documented dynamic sub-pytree, e.g. ``ServerState.codec``),
``Optional``/``Union`` of allowed types, ``Dict``/``List``/``Tuple``
containers of allowed types (registered pytree nodes), and other carry
NamedTuples.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Finding, Project, SourceFile

DEFAULT_EXTRA_CARRIES = ("RoundAux", "RoundTelemetry", "EncodedSnapshot")
DEFAULT_SUFFIXES = ("State", "Wire")

_ARRAY_NAMES = {"Array", "ndarray", "ArrayLike"}
_SCALARS = {"int", "float", "bool", "str", "bytes", "complex", "object"}
_CONTAINERS = {"Dict", "dict", "List", "list", "Tuple", "tuple",
               "Sequence", "Mapping", "FrozenSet", "frozenset", "Set",
               "set"}
_WRAPPERS = {"Optional", "Union"}


@dataclass
class _ClassInfo:
    name: str
    node: ast.ClassDef
    src: SourceFile
    fields: List[Tuple[str, Optional[ast.AST], int]]  # (name, annot, line)


class PytreeCarryRule:
    name = "pytree-carry"
    description = ("NamedTuple classes used as scan carries / shard_map "
                   "operands must have only array-or-registered-pytree "
                   "fields; static config goes in the step closure")

    def __init__(self, extra_carries: Sequence[str] = DEFAULT_EXTRA_CARRIES,
                 suffixes: Sequence[str] = DEFAULT_SUFFIXES):
        self.extra = set(extra_carries)
        self.suffixes = tuple(suffixes)

    def check(self, project: Project) -> Iterator[Finding]:
        classes, aliases = _collect(project)
        # roots: suffix-matched + explicit; closure over field annotations
        todo = [c for c in classes.values()
                if c.name.endswith(self.suffixes) or c.name in self.extra]
        seen: Set[str] = set()
        while todo:
            cls = todo.pop()
            if cls.name in seen:
                continue
            seen.add(cls.name)
            for fname, annot, line in cls.fields:
                problems, refs = _validate(annot, classes, aliases)
                for ref in refs:
                    if ref.name not in seen:
                        todo.append(ref)
                for why in problems:
                    yield Finding(
                        rule=self.name, path=cls.src.relpath, line=line,
                        message=(f"carry NamedTuple `{cls.name}` field "
                                 f"`{fname}` {why}"))


def _collect(project: Project):
    """All NamedTuple class defs + module-level type aliases, by name."""
    classes: Dict[str, _ClassInfo] = {}
    aliases: Dict[str, ast.AST] = {}
    for src in project.files:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ClassDef) and _is_namedtuple(node):
                fields: List[Tuple[str, Optional[ast.AST], int]] = []
                for stmt in node.body:
                    if isinstance(stmt, ast.AnnAssign) and \
                            isinstance(stmt.target, ast.Name):
                        fields.append((stmt.target.id, stmt.annotation,
                                       stmt.lineno))
                classes[node.name] = _ClassInfo(
                    name=node.name, node=node, src=src, fields=fields)
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                # module-level alias: SelectorState = Union[...]
                aliases.setdefault(node.targets[0].id, node.value)
            elif isinstance(node, ast.ImportFrom):
                # import renames: BTSState as BanditState
                for alias in node.names:
                    if alias.asname and alias.asname != alias.name:
                        aliases.setdefault(
                            alias.asname,
                            ast.Name(id=alias.name, ctx=ast.Load()))
    return classes, aliases


def _is_namedtuple(node: ast.ClassDef) -> bool:
    for base in node.bases:
        name = base.attr if isinstance(base, ast.Attribute) else \
            getattr(base, "id", None)
        if name == "NamedTuple":
            return True
    return False


def _tail_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _validate(
    annot: Optional[ast.AST],
    classes: Dict[str, _ClassInfo],
    aliases: Dict[str, ast.AST],
    depth: int = 0,
) -> Tuple[List[str], List[_ClassInfo]]:
    """(problem descriptions, referenced NamedTuple classes to recurse)."""
    if annot is None:
        return ["has no type annotation (annotate the pytree leaf type)"], []
    if depth > 6:
        return [], []

    # string annotation ("ServingModel") — parse and recurse
    if isinstance(annot, ast.Constant):
        if isinstance(annot.value, str):
            try:
                parsed = ast.parse(annot.value, mode="eval").body
            except SyntaxError:
                return [f"has unparseable annotation {annot.value!r}"], []
            return _validate(parsed, classes, aliases, depth + 1)
        if annot.value is None:    # NoneType half of Optional[...]
            return [], []
        return [f"has non-type annotation {annot.value!r}"], []

    name = _tail_name(annot)
    if name is not None and not isinstance(annot, ast.Subscript):
        if name == "Any" or name in _ARRAY_NAMES:
            return [], []
        if name in _SCALARS:
            return [(f"is annotated `{name}` — a Python scalar is not an "
                     f"array leaf; make it a () jax.Array or hang it off "
                     f"the static step config")], []
        if name in ("Callable",):
            return [(f"is annotated `{name}` — callables cannot cross a "
                     f"scan/shard_map boundary")], []
        if name in classes:
            return [], [classes[name]]
        if name in aliases:
            return _validate(aliases[name], classes, aliases, depth + 1)
        if name in _CONTAINERS:
            return [], []          # unparameterized container: trust it
        # unknown external type (e.g. chex.Array): give it the benefit of
        # the doubt only when it *looks* like an array alias
        if name.endswith(("Array", "Params")):
            return [], []
        return [(f"is annotated `{name}` — not a known array type, carry "
                 f"NamedTuple or registered pytree (suppress with a "
                 f"`# repro-lint: disable=pytree-carry` if deliberate)")], []

    if isinstance(annot, ast.Subscript):
        head = _tail_name(annot.value)
        inner = annot.slice
        parts = list(inner.elts) if isinstance(inner, ast.Tuple) else [inner]
        if head in _WRAPPERS or head in _CONTAINERS:
            problems: List[str] = []
            refs: List[_ClassInfo] = []
            for part in parts:
                if isinstance(part, ast.Constant) and part.value is Ellipsis:
                    continue
                # dict keys are static structure, not leaves
                if head in ("Dict", "dict", "Mapping") and part is parts[0]:
                    continue
                p, r = _validate(part, classes, aliases, depth + 1)
                problems.extend(p)
                refs.extend(r)
            return problems, refs
        if head in aliases:
            return _validate(aliases[head], classes, aliases, depth + 1)
        return [f"has unsupported generic annotation `{ast.dump(annot)[:40]}`"], []

    if isinstance(annot, ast.BinOp):   # PEP 604: X | Y
        p1, r1 = _validate(annot.left, classes, aliases, depth + 1)
        p2, r2 = _validate(annot.right, classes, aliases, depth + 1)
        return p1 + p2, r1 + r2

    return [], []
