"""kernel-parity: every Pallas kernel must have a ref.py oracle + a test.

The bit-parity contract from PRs 2/4/6: each public kernel in
``src/repro/kernels/*.py`` has a pure-jnp oracle in ``kernels/ref.py``
and at least one test exercises the pair, so a new kernel cannot land
without the machinery that keeps it honest on every backend.

Oracle mapping: ``<kernel>_ref`` by default; kernels whose oracle has a
different name declare it in a module-level ``PARITY_ORACLES`` dict
(``payload_score.py`` maps its three fused scoring kernels onto
``wire_topn_ref``). The test check is textual on purpose — it asks "does
any test file mention both this kernel (or its module) and its oracle?",
which is robust to how the test imports them.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterator, List, Optional, Sequence

from repro.analysis.core import Finding, Project, SourceFile

DEFAULT_KERNELS_DIR = "src/repro/kernels"
DEFAULT_TESTS_DIR = "tests"
_NON_KERNEL_FILES = {"__init__.py", "ops.py", "ref.py"}


class KernelParityRule:
    name = "kernel-parity"
    description = ("every public kernel in kernels/*.py needs a ref.py "
                   "oracle (default <name>_ref, or a PARITY_ORACLES "
                   "entry) and at least one test referencing both")

    def __init__(self, kernels_dir: str = DEFAULT_KERNELS_DIR,
                 tests_dir: str = DEFAULT_TESTS_DIR):
        self.kernels_dir = kernels_dir.rstrip("/")
        self.tests_dir = tests_dir.rstrip("/")

    def check(self, project: Project) -> Iterator[Finding]:
        kernel_files = [
            f for f in project.matching(self.kernels_dir + "/")
            if os.path.basename(f.relpath) not in _NON_KERNEL_FILES
            and "/" not in f.relpath[len(self.kernels_dir) + 1:]]
        if not kernel_files:
            return
        ref_file = project.by_relpath(f"{self.kernels_dir}/ref.py")
        ref_defs = _top_level_defs(ref_file) if ref_file else set()
        test_files = [f for f in project.matching(self.tests_dir + "/")
                      if os.path.basename(f.relpath).startswith("test_")]

        for src in kernel_files:
            oracles = _parity_oracles(src)
            for fn in _public_kernels(src):
                oracle = oracles.get(fn.name, f"{fn.name}_ref")
                if ref_file is None:
                    yield Finding(
                        rule=self.name, path=src.relpath, line=fn.lineno,
                        message=(f"kernel `{fn.name}` has no oracle: "
                                 f"{self.kernels_dir}/ref.py not found"))
                    continue
                if oracle not in ref_defs:
                    yield Finding(
                        rule=self.name, path=src.relpath, line=fn.lineno,
                        message=(f"kernel `{fn.name}` has no ref.py oracle "
                                 f"`{oracle}` (add the oracle, or map the "
                                 f"kernel in PARITY_ORACLES)"))
                    continue
                if test_files and not _covered(fn.name, src, oracle,
                                               test_files):
                    yield Finding(
                        rule=self.name, path=src.relpath, line=fn.lineno,
                        message=(f"kernel `{fn.name}` / oracle `{oracle}` "
                                 f"pair is not exercised by any test under "
                                 f"{self.tests_dir}/ — add a parity test "
                                 f"importing both"))


def _public_kernels(src: SourceFile) -> List[ast.FunctionDef]:
    return [node for node in src.tree.body
            if isinstance(node, ast.FunctionDef)
            and not node.name.startswith("_")]


def _top_level_defs(src: Optional[SourceFile]) -> set:
    if src is None:
        return set()
    return {node.name for node in src.tree.body
            if isinstance(node, ast.FunctionDef)}


def _parity_oracles(src: SourceFile) -> Dict[str, str]:
    """Module-level ``PARITY_ORACLES = {"kernel": "oracle_ref", ...}``."""
    for node in src.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == "PARITY_ORACLES" \
                and isinstance(node.value, ast.Dict):
            out: Dict[str, str] = {}
            for k, v in zip(node.value.keys, node.value.values):
                if isinstance(k, ast.Constant) and isinstance(v,
                                                              ast.Constant):
                    out[str(k.value)] = str(v.value)
            return out
    return {}


def _covered(kernel: str, src: SourceFile, oracle: str,
             test_files: Sequence[SourceFile]) -> bool:
    module = os.path.basename(src.relpath)[:-3]
    for tf in test_files:
        if oracle in tf.text and (kernel in tf.text or module in tf.text):
            return True
    return False
