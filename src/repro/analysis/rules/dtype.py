"""dtype-width: no implicit float64 in traced code or wire formats.

The whole trajectory contract is float32 (JAX default, x64 disabled): a
float64 leaking into a traced function or a codec either crashes under
jit (dtype mismatch against the float32 carry) or — worse — silently
doubles wire bytes and breaks the bit-parity tests only on machines with
x64 enabled. Three checks, two scopes:

STRICT scope — functions in the traced call graph (same walker as
traced-purity) plus every function in the wire-format and kernel modules
(``repro.compress``, ``repro.kernels``):

  * ``float64`` / ``double`` dtype references (``np.float64``,
    ``jnp.float64``, ``dtype="float64"``);
  * ``dtype=float`` — the builtin ``float`` is float64;
  * bare ``np.array`` / ``np.asarray`` / ``np.zeros`` / ``np.ones`` /
    ``np.empty`` / ``np.full`` without an explicit dtype — numpy defaults
    to float64 and the value then enters the traced graph.

HOST scope — every other linted file (drivers, benchmarks, tests):
only the first two checks. Host-side numpy statistics are allowed to be
float64 (that is numpy's native accumulator width and several host
oracles — ``core/regret.py`` — use it deliberately against the traced
float32 fold); such deliberate uses in strict scope carry inline
suppressions.
"""
from __future__ import annotations

import ast
from typing import Iterator, Sequence, Set, Tuple

from repro.analysis.callgraph import ProjectIndex, module_name_for
from repro.analysis.core import Finding, Project
from repro.analysis.rules.purity import DEFAULT_ROOTS

DEFAULT_STRICT_MODULES = ("repro.compress", "repro.kernels")

_F64_TAILS = {"float64", "double", "complex128"}
_BARE_DEFAULT_F64 = {"array", "asarray", "zeros", "ones", "empty", "full",
                     "zeros_like", "ones_like", "empty_like", "full_like"}


class DtypeWidthRule:
    name = "dtype-width"
    description = ("no implicit float64 promotion in traced code or wire "
                   "codecs: float64 dtype refs, dtype=float, and bare "
                   "np.array-family constructors are flagged")

    def __init__(self, roots: Sequence[str] = DEFAULT_ROOTS,
                 strict_modules: Sequence[str] = DEFAULT_STRICT_MODULES):
        self.roots = tuple(roots)
        self.strict_modules = tuple(strict_modules)

    def check(self, project: Project) -> Iterator[Finding]:
        index = ProjectIndex(project)
        traced = index.traced_functions(self.roots)

        # strict-scope line spans: traced function bodies + whole strict
        # modules; everything else linted is host scope
        strict_spans: dict = {}
        for fn in traced.values():
            spans = strict_spans.setdefault(fn.src.relpath, [])
            end = getattr(fn.node, "end_lineno", fn.node.lineno)
            spans.append((fn.node.lineno, end))
        strict_files: Set[str] = set()
        for mod_name, mod in index.modules.items():
            if any(mod_name == s or mod_name.startswith(s + ".")
                   for s in self.strict_modules):
                strict_files.add(mod.src.relpath)

        for src in project.files:
            mod = index.modules.get(module_name_for(src.relpath) or "")
            for node in ast.walk(src.tree):
                line = getattr(node, "lineno", None)
                if line is None:
                    continue
                strict = src.relpath in strict_files or any(
                    a <= line <= b
                    for a, b in strict_spans.get(src.relpath, ()))
                for found_line, msg in self._check_node(node, mod, index,
                                                        strict):
                    yield Finding(rule=self.name, path=src.relpath,
                                  line=found_line, message=msg)

    # ------------------------------------------------------------- #
    def _check_node(self, node: ast.AST, mod, index: ProjectIndex,
                    strict: bool) -> Iterator[Tuple[int, str]]:
        # float64 attribute references: np.float64 / jnp.float64
        if isinstance(node, ast.Attribute) and node.attr in _F64_TAILS:
            yield node.lineno, (
                f"64-bit dtype reference `.{node.attr}` — trajectories "
                f"and wire formats are float32; use an explicit 32-bit "
                f"dtype (suppress if this is a deliberate host-side "
                f"oracle)")
            return
        if not isinstance(node, ast.Call):
            return
        # dtype=float / dtype="float64" keywords on any call
        for kw in node.keywords:
            if kw.arg != "dtype":
                continue
            if isinstance(kw.value, ast.Name) and kw.value.id == "float":
                yield node.lineno, (
                    "`dtype=float` is float64 — name the width "
                    "(jnp.float32) explicitly")
            elif isinstance(kw.value, ast.Constant) and \
                    str(kw.value.value) in ("float64", "double"):
                yield node.lineno, (
                    f"`dtype={kw.value.value!r}` — trajectories and wire "
                    f"formats are float32")
        if not strict:
            return
        # bare numpy constructors defaulting to float64 (strict scope only)
        dotted = None
        if mod is not None:
            dotted = index.dotted_name(node.func, mod)
        elif isinstance(node.func, ast.Attribute) and \
                isinstance(node.func.value, ast.Name) and \
                node.func.value.id in ("np", "numpy"):
            dotted = f"numpy.{node.func.attr}"
        if not dotted or not dotted.startswith("numpy."):
            return
        tail = dotted.rsplit(".", 1)[-1]
        if tail not in _BARE_DEFAULT_F64:
            return
        if any(kw.arg == "dtype" for kw in node.keywords):
            return
        # positional dtype: np.zeros(shape, np.int32) etc.
        max_args = {"array": 2, "asarray": 2, "zeros": 2, "ones": 2,
                    "empty": 2, "full": 3, "zeros_like": 2, "ones_like": 2,
                    "empty_like": 2, "full_like": 3}[tail]
        if len(node.args) >= max_args:
            return
        yield node.lineno, (
            f"bare `{dotted}(...)` without dtype defaults to float64 in "
            f"traced/wire scope — pass an explicit dtype")
