"""lock-guard: shared mutable attributes only under ``with self._lock``.

The serving engine's swap/read/metrics contract (PR 6/7): the model/
version pair and every counter the Prometheus scrape reports change only
together, under one lock, so a scrape sees a consistent cut and versions
are monotone under concurrent readers. This rule generalizes that to any
class that builds a ``threading.Lock``/``RLock`` in ``__init__``:

  * GUARDED attributes are the ``self.x`` names the class *writes outside
    __init__* — mutable shared state by construction (attributes only
    ever assigned in ``__init__`` are init-frozen configuration and stay
    unguarded);
  * every read or write of a guarded attribute in any method other than
    ``__init__`` must sit lexically inside a ``with self.<lock>`` block
    (nested functions inherit the enclosing with-blocks — the lexical
    rule intentionally over-approximates: a closure that escapes the
    lock scope must be suppressed explicitly with a justification).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set, Tuple

from repro.analysis.core import Finding, Project, SourceFile

_LOCK_TYPES = {"Lock", "RLock"}


class LockGuardRule:
    name = "lock-guard"
    description = ("attributes a lock-owning class mutates outside "
                   "__init__ may only be touched inside `with self.<lock>` "
                   "blocks")

    def check(self, project: Project) -> Iterator[Finding]:
        for src in project.files:
            for node in ast.walk(src.tree):
                if isinstance(node, ast.ClassDef):
                    yield from self._check_class(node, src)

    # ------------------------------------------------------------- #
    def _check_class(self, cls: ast.ClassDef,
                     src: SourceFile) -> Iterator[Finding]:
        methods = [n for n in cls.body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        init = next((m for m in methods if m.name == "__init__"), None)
        if init is None:
            return
        locks = _lock_attrs(init)
        if not locks:
            return
        guarded = _guarded_attrs(methods, locks)
        if not guarded:
            return
        for method in methods:
            if method.name == "__init__":
                continue
            yield from self._check_method(method, src, cls.name, locks,
                                          guarded)

    def _check_method(self, method, src: SourceFile, cls_name: str,
                      locks: Set[str],
                      guarded: Set[str]) -> Iterator[Finding]:
        def walk(node: ast.AST, locked: bool) -> Iterator[Finding]:
            for child in ast.iter_child_nodes(node):
                child_locked = locked
                if isinstance(child, (ast.With, ast.AsyncWith)) and \
                        _takes_lock(child, locks):
                    child_locked = True
                if isinstance(child, ast.Attribute) and \
                        isinstance(child.value, ast.Name) and \
                        child.value.id == "self" and \
                        child.attr in guarded and not child_locked:
                    access = "write" if isinstance(
                        child.ctx, (ast.Store, ast.Del)) else "read"
                    yield Finding(
                        rule=self.name, path=src.relpath, line=child.lineno,
                        message=(f"{access} of `self.{child.attr}` in "
                                 f"`{cls_name}.{method.name}` outside "
                                 f"`with self.{sorted(locks)[0]}` — "
                                 f"shared mutable state must be "
                                 f"lock-guarded"))
                yield from walk(child, child_locked)

        yield from walk(method, False)


def _lock_attrs(init) -> Set[str]:
    """self attrs assigned a threading.Lock()/RLock() in __init__."""
    locks: Set[str] = set()
    for node in ast.walk(init):
        if not isinstance(node, ast.Assign) or \
                not isinstance(node.value, ast.Call):
            continue
        func = node.value.func
        name = func.attr if isinstance(func, ast.Attribute) else \
            getattr(func, "id", None)
        if name not in _LOCK_TYPES:
            continue
        for t in node.targets:
            if isinstance(t, ast.Attribute) and \
                    isinstance(t.value, ast.Name) and t.value.id == "self":
                locks.add(t.attr)
    return locks


def _guarded_attrs(methods: List, locks: Set[str]) -> Set[str]:
    """self attrs written (Store/AugStore/Del) outside __init__."""
    guarded: Set[str] = set()
    for method in methods:
        if method.name == "__init__":
            continue
        for node in ast.walk(method):
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.ctx, (ast.Store, ast.Del)) and \
                    isinstance(node.value, ast.Name) and \
                    node.value.id == "self" and node.attr not in locks:
                guarded.add(node.attr)
    return guarded


def _takes_lock(node, locks: Set[str]) -> bool:
    for item in node.items:
        expr = item.context_expr
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and \
                expr.value.id == "self" and expr.attr in locks:
            return True
    return False
