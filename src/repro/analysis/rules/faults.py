"""fault-carry: fault state lives in the scan carry; degradation paths count.

Two halves of the fault-tolerance contract (docs/FAULT_MODEL.md):

  * CARRY PURITY — modules under the fault roots (``src/repro/faults``)
    implement the deterministic fault schedule that is threaded through
    ``jax.lax.scan`` as carry state. Any module-level mutable container
    (list/dict/set literal or constructor call) or ``global`` declaration
    there is hidden per-process fault state: it would desynchronize
    vmapped/sharded replicas and break crash-resume bit-parity, so it is
    flagged. NamedTuple/constant module attributes are fine.
  * COUNTED DEGRADATION — modules under the except roots
    (``src/repro/serve``, ``src/repro/checkpoint``) are the degradation
    layers whose whole point is surviving failure *visibly*. Every
    ``except`` handler there must either re-raise or increment a counter
    (an assignment whose target names match ``_COUNTER_RE`` — failures,
    sheds, retries, totals); a handler that silently swallows an
    exception turns a counted fault into an invisible one.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, Sequence, Tuple

from repro.analysis.core import Finding, Project, SourceFile

# counter-ish identifier fragments: incrementing any of these inside an
# except handler counts as surfacing the failure
_COUNTER_RE = re.compile(r"(count|total|failure|shed|retr|error|drop)",
                         re.IGNORECASE)

# calls that build mutable containers at module scope
_MUTABLE_CTORS = {"list", "dict", "set", "defaultdict", "deque", "Counter",
                  "OrderedDict"}


class FaultCarryRule:
    name = "fault-carry"
    description = ("fault-schedule modules keep state in the scan carry "
                   "(no module-level mutable containers / globals); every "
                   "except in the degradation layers re-raises or "
                   "increments a counter")

    def __init__(
        self,
        fault_roots: Sequence[str] = ("src/repro/faults",),
        except_roots: Sequence[str] = ("src/repro/serve",
                                       "src/repro/checkpoint"),
    ):
        self.fault_roots = tuple(fault_roots)
        self.except_roots = tuple(except_roots)

    def check(self, project: Project) -> Iterator[Finding]:
        for src in project.files:
            if _under(src.relpath, self.fault_roots):
                yield from self._check_fault_module(src)
            if _under(src.relpath, self.except_roots):
                yield from self._check_except_handlers(src)

    # ------------------------------------------------------------- #
    # fault roots: no module-level mutable state, no `global`
    # ------------------------------------------------------------- #
    def _check_fault_module(self, src: SourceFile) -> Iterator[Finding]:
        for stmt in src.tree.body:
            targets: Tuple[ast.expr, ...] = ()
            value = None
            if isinstance(stmt, ast.Assign):
                targets, value = tuple(stmt.targets), stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                targets, value = (stmt.target,), stmt.value
            if value is None or not _is_mutable_container(value):
                continue
            names = ", ".join(_target_names(t) for t in targets)
            # dunder module attributes (__all__ etc.) are interface
            # metadata, not runtime state
            if all(n.startswith("__") and n.endswith("__")
                   for n in names.split(", ")):
                continue
            yield Finding(
                rule=self.name, path=src.relpath, line=stmt.lineno,
                message=(f"module-level mutable container `{names}` in a "
                         f"fault-schedule module — fault state must ride "
                         f"the scan carry (pre-sampled schedule arrays + "
                         f"FaultState), not per-process globals"))
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Global):
                yield Finding(
                    rule=self.name, path=src.relpath, line=node.lineno,
                    message=(f"`global {', '.join(node.names)}` in a "
                             f"fault-schedule module — mutating module "
                             f"state desynchronizes vmapped/sharded "
                             f"replicas; thread it through the scan carry"))

    # ------------------------------------------------------------- #
    # except roots: every handler re-raises or increments a counter
    # ------------------------------------------------------------- #
    def _check_except_handlers(self, src: SourceFile) -> Iterator[Finding]:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if _handler_surfaces(node):
                continue
            what = ast.unparse(node.type) if node.type else "BaseException"
            yield Finding(
                rule=self.name, path=src.relpath, line=node.lineno,
                message=(f"`except {what}` swallows the failure — a "
                         f"degradation-layer handler must re-raise or "
                         f"increment a counter (name matching "
                         f"{_COUNTER_RE.pattern}) so the fault stays "
                         f"observable"))


def _under(relpath: str, roots: Sequence[str]) -> bool:
    p = relpath.replace("\\", "/")
    return any(p.startswith(root.rstrip("/") + "/") for root in roots)


def _is_mutable_container(value: ast.expr) -> bool:
    if isinstance(value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                          ast.DictComp, ast.SetComp)):
        return True
    if isinstance(value, ast.Call):
        func = value.func
        name = func.attr if isinstance(func, ast.Attribute) else \
            getattr(func, "id", None)
        return name in _MUTABLE_CTORS
    return False


def _target_names(target: ast.expr) -> str:
    if isinstance(target, ast.Name):
        return target.id
    if isinstance(target, (ast.Tuple, ast.List)):
        return ", ".join(_target_names(e) for e in target.elts)
    return ast.unparse(target)


def _handler_surfaces(handler: ast.ExceptHandler) -> bool:
    """True iff the handler body re-raises or bumps a counter-named target."""
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        targets: Tuple[ast.expr, ...] = ()
        if isinstance(node, ast.AugAssign):
            targets = (node.target,)
        elif isinstance(node, ast.Assign):
            targets = tuple(node.targets)
        for t in targets:
            if any(_COUNTER_RE.search(n) for n in _ident_chain(t)):
                return True
    return False


def _ident_chain(node: ast.expr):
    """Every identifier-ish name along a target chain: ``self.x``,
    ``d["k"]``, plain names — the counter regex matches any link."""
    while True:
        if isinstance(node, ast.Name):
            yield node.id
            return
        if isinstance(node, ast.Attribute):
            yield node.attr
            node = node.value
        elif isinstance(node, ast.Subscript):
            s = node.slice
            if isinstance(s, ast.Constant) and isinstance(s.value, str):
                yield s.value
            node = node.value
        else:
            return
