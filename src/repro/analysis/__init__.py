"""repro.analysis — contract-enforcing static analysis for this repo.

The functional-core architecture (pure round steps under jit/scan/
shard_map, NamedTuple carries, kernel/oracle bit-parity, float32
trajectories, a lock-disciplined serving engine) is held up by invariants
that nothing mechanical enforced until now. This package is that
enforcement: an AST-based engine with a pluggable rule registry
(:mod:`repro.analysis.rules`), per-line suppressions
(``# repro-lint: disable=<rule>``), a committed baseline for
grandfathered findings, text/JSON reporters, and a complementary
``jax.eval_shape`` shape-lint (:mod:`repro.analysis.shapelint`).

CLI: ``python -m repro.analysis src tests benchmarks`` — exits non-zero
on any finding not in the baseline. See docs/INVARIANTS.md for the
contracts and the rationale behind each rule.
"""
from repro.analysis.baseline import (
    DEFAULT_BASELINE, load_baseline, split_findings, write_baseline,
)
from repro.analysis.core import (
    DEFAULT_EXCLUDES, Finding, Project, SourceFile, load_project, run_rules,
)
from repro.analysis.report import render_json, render_text
from repro.analysis.rules import (
    DtypeWidthRule, KernelParityRule, LockGuardRule, PytreeCarryRule,
    RULE_CLASSES, TracedPurityRule, default_rules, rule_names,
)

__all__ = [
    "DEFAULT_BASELINE", "DEFAULT_EXCLUDES", "Finding", "Project",
    "SourceFile", "RULE_CLASSES", "DtypeWidthRule", "KernelParityRule",
    "LockGuardRule", "PytreeCarryRule", "TracedPurityRule",
    "default_rules", "load_baseline", "load_project", "render_json",
    "render_text", "rule_names", "run_rules", "split_findings",
    "write_baseline",
]
