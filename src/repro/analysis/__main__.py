"""CLI entry point: ``python -m repro.analysis [paths...]``.

Exit status 0 iff no *new* findings (and, with ``--shape-lint``, no shape
errors). Grandfathered findings live in the committed baseline file; the
goal state is an empty baseline.
"""
from __future__ import annotations

import argparse
import sys
from typing import List

from repro.analysis.baseline import (
    DEFAULT_BASELINE, load_baseline, split_findings, write_baseline,
)
from repro.analysis.core import DEFAULT_EXCLUDES, load_project, run_rules
from repro.analysis.report import render_json, render_text
from repro.analysis.rules import default_rules, rule_names


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="contract-enforcing static analysis (see "
                    "docs/INVARIANTS.md)")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files/directories to analyze (default: src)")
    p.add_argument("--root", default=".",
                   help="repo root findings are reported relative to")
    p.add_argument("--baseline", default=DEFAULT_BASELINE,
                   help=f"baseline JSON path (default: {DEFAULT_BASELINE})")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline file; report every finding "
                        "as new")
    p.add_argument("--write-baseline", action="store_true",
                   help="write all current findings to the baseline file "
                        "and exit 0")
    p.add_argument("--disable", action="append", default=[],
                   metavar="RULE", help="disable a rule by name "
                   "(repeatable)")
    p.add_argument("--no-default-excludes", action="store_true",
                   help="lint paths the default excludes would skip "
                        "(e.g. the analysis_fixtures corpus)")
    p.add_argument("--json", action="store_true",
                   help="emit a JSON report instead of text")
    p.add_argument("--shape-lint", action="store_true",
                   help="also run jax.eval_shape checks over the public "
                        "entry points (imports jax + repro)")
    p.add_argument("--list-rules", action="store_true",
                   help="print registered rule names and exit")
    return p


def main(argv: List[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for name in rule_names():
            print(name)
        return 0

    unknown = set(args.disable) - set(rule_names())
    if unknown:
        print(f"error: unknown rule(s) in --disable: {sorted(unknown)}",
              file=sys.stderr)
        return 2

    excludes = ("__pycache__",) if args.no_default_excludes else \
        DEFAULT_EXCLUDES
    project = load_project(args.paths, root=args.root, excludes=excludes)
    findings = run_rules(project, default_rules(disable=args.disable))

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"wrote {len({f.key() for f in findings})} finding key(s) "
              f"to {args.baseline}")
        return 0

    baseline_keys = [] if args.no_baseline else load_baseline(args.baseline)
    new, grandfathered, stale = split_findings(findings, baseline_keys)

    shape_errors: List[str] = []
    if args.shape_lint:
        from repro.analysis.shapelint import run_shape_lint

        shape_errors = run_shape_lint()

    render = render_json if args.json else render_text
    print(render(new, grandfathered, stale, shape_errors))
    return 1 if (new or shape_errors) else 0


if __name__ == "__main__":
    sys.exit(main())
