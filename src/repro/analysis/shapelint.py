"""shape-lint: abstract-interpretation checks over the public entry points.

``jax.eval_shape`` runs the real tracing machinery — every shape rule,
dtype promotion and pytree-structure requirement — without executing a
single flop. This module drives the fused round steps (sync + async, with
and without telemetry), the compressed serving read path and the
telemetry fold over a small grid of (M, K, Theta) shapes and asserts the
contracts the rest of the repo relies on:

  * the scan-carry invariant: ``server_round_step`` returns a state with
    the SAME pytree structure, leaf shapes and leaf dtypes it was given
    (anything else cannot ride ``lax.scan``);
  * the trajectory dtype contract: Q stays float32, round/byte counters
    stay int32/float32 — a float64 or fp16 leak surfaces here in seconds;
  * the wire read path: ``wire_topn`` returns ``((B, N) float32,
    (B, N) int32)`` for every codec;
  * telemetry rows are exactly ``len(TELEMETRY_FIELDS)`` float32 wide and
    the telemetry fold preserves its own carry structure.

Pure shape drift (a refactor changing an output rank, an accidental
promotion) fails the lint long before a trajectory-level test would
notice.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

# (M, K, Theta) grid — small on purpose: eval_shape cost is trace cost
DEFAULT_GRID: Tuple[Tuple[int, int, int], ...] = (
    (64, 8, 8),
    (128, 16, 4),
)
DEFAULT_CODECS = ("fp32", "int8", "topk")
DEFAULT_STRATEGIES = ("bts", "random")
# optimizer moment-storage axis: (m_dtype, v_dtype) pairs, None = the
# frozen fp32 default. Compressed AdamState leaves (int8 codes + scales,
# bf16 tables, factored (M,)+(K,) pairs) must ride the same scan carry.
DEFAULT_MOMENTS: Tuple[object, ...] = (
    None,
    ("bf16", "factored"),
    ("int8", "int8"),
)


def _leaf_sig(x):
    return (tuple(x.shape), str(x.dtype))


def _tree_sig(tree):
    import jax

    return jax.tree.map(_leaf_sig, tree)


def _expect(errors: List[str], cond: bool, ctx: str, msg: str) -> None:
    if not cond:
        errors.append(f"{ctx}: {msg}")


def run_shape_lint(
    grid: Sequence[Tuple[int, int, int]] = DEFAULT_GRID,
    codecs: Sequence[str] = DEFAULT_CODECS,
    strategies: Sequence[str] = DEFAULT_STRATEGIES,
    moments: Sequence[object] = DEFAULT_MOMENTS,
) -> List[str]:
    """Run every shape check; return human-readable error strings."""
    import jax
    import jax.numpy as jnp

    from repro.cf.model import CFConfig
    from repro.cf.server import (
        FCFServerConfig, server_init, server_round_step,
        server_round_step_async,
    )
    from repro.compress import CodecConfig, encode
    from repro.core.selector import SelectorConfig
    from repro.kernels.ref import wire_topn_ref
    from repro.obs.telemetry import (
        TELEMETRY_FIELDS, telemetry_state_init, telemetry_round,
    )
    from repro.optim.state_compress import MomentCodecConfig

    errors: List[str] = []
    f32 = jnp.float32

    for (m, k, theta) in grid:
        m_s = max(2, m // 4)
        cf_cfg = CFConfig(num_users=theta, num_items=m, num_factors=k)
        q0 = jax.ShapeDtypeStruct((m, k), f32)
        key0 = jax.ShapeDtypeStruct((2,), jnp.uint32)
        cohort = jax.ShapeDtypeStruct((theta, m), f32)

        for strategy in strategies:
            sel_cfg = SelectorConfig(strategy=strategy, num_arms=m,
                                     num_select=m_s, dim=k)
            for codec in codecs:
                cc = CodecConfig(name=codec)
                for mom in moments:
                    mc = (None if mom is None
                          else MomentCodecConfig(m_dtype=mom[0],
                                                 v_dtype=mom[1]))
                    srv_cfg = FCFServerConfig(theta=theta, moment=mc)
                    mtag = "fp32" if mom is None else f"{mom[0]}/{mom[1]}"
                    ctx = (f"(M={m}, K={k}, Θ={theta}, {strategy}/{codec}, "
                           f"moment={mtag})")
                    try:
                        errors.extend(_check_sync(
                            jax, ctx, q0, key0, cohort, sel_cfg, srv_cfg,
                            cf_cfg, cc, m, k, m_s,
                            server_init, server_round_step))
                    except Exception as e:  # noqa: BLE001 — report, don't die
                        errors.append(f"{ctx} sync: {type(e).__name__}: {e}")
                    try:
                        errors.extend(_check_async(
                            jax, jnp, ctx, q0, key0, cohort, sel_cfg, srv_cfg,
                            cf_cfg, cc, m, k, m_s,
                            server_init, server_round_step_async))
                    except Exception as e:      # noqa: BLE001
                        errors.append(f"{ctx} async: {type(e).__name__}: {e}")

        # serving read path: every codec, one (B, N) probe per grid point
        for codec in codecs:
            cc = CodecConfig(name=codec)
            ctx = f"(M={m}, K={k}) serve/{codec}"
            try:
                b, top_n = 4, min(8, m)

                def read(q, p, _cc=cc, _k=k, _n=top_n):
                    wire = encode(_cc, q)
                    return wire_topn_ref(_cc, wire, p, _k, _n, block_m=32)

                vals, idx = jax.eval_shape(
                    read, q0, jax.ShapeDtypeStruct((b, k), f32))
                _expect(errors, vals.shape == (b, top_n), ctx,
                        f"topn scores shape {vals.shape} != ({b}, {top_n})")
                _expect(errors, vals.dtype == f32, ctx,
                        f"topn scores dtype {vals.dtype} != float32")
                _expect(errors, idx.shape == (b, top_n), ctx,
                        f"topn ids shape {idx.shape} != ({b}, {top_n})")
                _expect(errors, idx.dtype == jnp.int32, ctx,
                        f"topn ids dtype {idx.dtype} != int32")
            except Exception as e:          # noqa: BLE001
                errors.append(f"{ctx}: {type(e).__name__}: {e}")

    # telemetry fold: carry-preserving, row width pinned to the schema
    try:
        m, m_s = 64, 16
        ts0 = jax.eval_shape(lambda: telemetry_state_init(m))
        from repro.obs.telemetry import RoundTelemetry

        tel = RoundTelemetry(*[
            jax.ShapeDtypeStruct((), jnp.int32 if f == "t" else f32)
            for f in RoundTelemetry._fields])
        ts1, row = jax.eval_shape(
            telemetry_round, ts0,
            tel, jax.ShapeDtypeStruct((m_s,), jnp.int32),
            jax.ShapeDtypeStruct((m_s,), f32))
        _expect(errors, _tree_sig(ts1) == _tree_sig(ts0), "telemetry",
                "telemetry_round does not preserve TelemetryState "
                "shapes/dtypes")
        _expect(errors, row.shape == (len(TELEMETRY_FIELDS),), "telemetry",
                f"row shape {row.shape} != ({len(TELEMETRY_FIELDS)},)")
        _expect(errors, row.dtype == f32, "telemetry",
                f"row dtype {row.dtype} != float32")
    except Exception as e:                  # noqa: BLE001
        errors.append(f"telemetry: {type(e).__name__}: {e}")

    return errors


def _check_sync(jax, ctx, q0, key0, cohort, sel_cfg, srv_cfg, cf_cfg, cc,
                m, k, m_s, server_init, server_round_step) -> List[str]:
    errors: List[str] = []
    state = jax.eval_shape(
        lambda q, key: server_init(q, sel_cfg, key, srv_cfg, cc), q0, key0)

    for telemetry in (False, True):
        def step(st, x, _tel=telemetry):
            return server_round_step(
                st, x, sel_cfg=sel_cfg, config=srv_cfg, cf_cfg=cf_cfg,
                codec_cfg=cc, telemetry=_tel)

        out_state, aux = jax.eval_shape(step, state, cohort)
        tag = f"{ctx} sync(telemetry={telemetry})"
        _expect(errors, _tree_sig(out_state) == _tree_sig(state), tag,
                "round step does not preserve ServerState pytree "
                "shapes/dtypes (breaks the lax.scan carry contract)")
        _expect(errors, _leaf_sig(out_state.q) == ((m, k), "float32"), tag,
                f"Q leaf is {_leaf_sig(out_state.q)}, expected "
                f"(({m}, {k}), float32)")
        _expect(errors, _leaf_sig(aux.indices)[0] == (m_s,), tag,
                f"aux.indices shape {aux.indices.shape} != ({m_s},)")
        _expect(errors, _leaf_sig(aux.rewards) == ((m_s,), "float32"), tag,
                f"aux.rewards is {_leaf_sig(aux.rewards)}")
        n_tel = len(jax.tree.leaves(aux.telemetry))
        _expect(errors, (n_tel > 0) == telemetry, tag,
                f"telemetry={telemetry} but aux.telemetry has {n_tel} "
                f"leaves — the zero-overhead-when-off contract")
    return errors


def _check_async(jax, jnp, ctx, q0, key0, cohort, sel_cfg, srv_cfg, cf_cfg,
                 cc, m, k, m_s, server_init,
                 server_round_step_async) -> List[str]:
    errors: List[str] = []
    slots = 3
    state = jax.eval_shape(
        lambda q, key: server_init(q, sel_cfg, key, srv_cfg, cc,
                                   async_slots=slots), q0, key0)

    def step(st, x, s):
        return server_round_step_async(
            st, x, s, sel_cfg=sel_cfg, config=srv_cfg, cf_cfg=cf_cfg,
            codec_cfg=cc)

    out_state, aux = jax.eval_shape(
        step, state, cohort, jax.ShapeDtypeStruct((), jnp.int32))
    tag = f"{ctx} async"
    _expect(errors, _tree_sig(out_state) == _tree_sig(state), tag,
            "async round step does not preserve ServerState pytree "
            "shapes/dtypes (breaks the lax.scan carry contract)")
    _expect(errors, _leaf_sig(aux.indices)[0] == (m_s,), tag,
            f"aux.indices shape {aux.indices.shape} != ({m_s},)")
    ring_leaves = jax.tree.leaves(out_state.snapshots)
    _expect(errors, all(l.shape[0] == slots for l in ring_leaves), tag,
            f"snapshot ring leaves lost their (slots={slots},) axis")
    return errors
