"""Committed baseline of grandfathered findings.

The baseline file is a JSON document holding finding *keys*
(``rule::path::message`` — line numbers excluded, so pure line drift
never churns it). The CLI fails only on findings absent from the
baseline; baseline entries that no longer fire are reported as stale so
the file shrinks monotonically toward the goal state: empty.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Sequence, Tuple

from repro.analysis.core import Finding

DEFAULT_BASELINE = "analysis_baseline.json"


def load_baseline(path: str) -> List[str]:
    if not os.path.exists(path):
        return []
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, dict) or not isinstance(
            data.get("findings"), list):
        raise ValueError(
            f"baseline {path} must be {{'findings': [keys...]}}")
    return [str(k) for k in data["findings"]]


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    keys = sorted({f.key() for f in findings})
    doc: Dict = {
        "comment": ("grandfathered repro.analysis findings — new code "
                    "must not add entries; prefer fixing or an inline "
                    "`# repro-lint: disable=<rule>` with justification"),
        "findings": keys,
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


def split_findings(
    findings: Sequence[Finding], baseline_keys: Sequence[str]
) -> Tuple[List[Finding], List[Finding], List[str]]:
    """(new, grandfathered, stale-baseline-keys)."""
    baseline = set(baseline_keys)
    new = [f for f in findings if f.key() not in baseline]
    old = [f for f in findings if f.key() in baseline]
    live = {f.key() for f in findings}
    stale = sorted(k for k in baseline if k not in live)
    return new, old, stale
