"""Text / JSON reporters for analysis findings."""
from __future__ import annotations

import json
from typing import Dict, List, Sequence

from repro.analysis.core import Finding


def render_text(
    new: Sequence[Finding],
    grandfathered: Sequence[Finding] = (),
    stale_baseline: Sequence[str] = (),
    shape_errors: Sequence[str] = (),
) -> str:
    lines: List[str] = []
    for f in new:
        lines.append(f.render())
    for err in shape_errors:
        lines.append(f"shape-lint: {err}")
    if grandfathered:
        lines.append(f"note: {len(grandfathered)} grandfathered finding(s) "
                     f"suppressed by baseline")
    if stale_baseline:
        lines.append(f"note: {len(stale_baseline)} stale baseline entr"
                     f"{'y' if len(stale_baseline) == 1 else 'ies'} no "
                     f"longer fire(s) — prune the baseline:")
        for key in stale_baseline:
            lines.append(f"  stale: {key}")
    total_bad = len(new) + len(shape_errors)
    if total_bad:
        lines.append(f"FAILED: {len(new)} new finding(s), "
                     f"{len(shape_errors)} shape-lint error(s)")
    else:
        lines.append("OK: no new findings")
    return "\n".join(lines)


def render_json(
    new: Sequence[Finding],
    grandfathered: Sequence[Finding] = (),
    stale_baseline: Sequence[str] = (),
    shape_errors: Sequence[str] = (),
) -> str:
    def enc(f: Finding) -> Dict:
        return {"rule": f.rule, "path": f.path, "line": f.line,
                "message": f.message, "key": f.key()}

    doc = {
        "new": [enc(f) for f in new],
        "grandfathered": [enc(f) for f in grandfathered],
        "stale_baseline": list(stale_baseline),
        "shape_errors": list(shape_errors),
        "ok": not new and not shape_errors,
    }
    return json.dumps(doc, indent=2)
