"""Activation-sharding hints.

Model code stays mesh-agnostic; launchers (dryrun/train) install the mesh
axes the global batch is sharded over, and perf-critical layers anchor
their big activations with ``constrain_batch`` — a no-op when no hints are
installed (single-device tests/benches) so the model zoo needs no mesh.

SPMD sharding propagation alone loses the batch sharding through
scatter/gather-based MoE dispatch (measured: 43GB all-gathers per layer in
the mixtral dry-run, §Perf); one constraint on the dispatch path pins it.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: Optional[Tuple[str, ...]] = None
_MESH = None
_KV_TIME_SHARD = False


@contextmanager
def batch_axes(axes: Optional[Tuple[str, ...]], mesh=None,
               kv_time_shard: bool = False):
    """Install the mesh + batch axes of the global batch for the trace.

    ``kv_time_shard``: decode KV caches are sharded over the model axis on
    the TIME dim; the attention block switches to the shard_map
    distributed-LSE decode path (§Perf, decode_32k memory iteration).
    """
    global _BATCH_AXES, _MESH, _KV_TIME_SHARD
    prev = (_BATCH_AXES, _MESH, _KV_TIME_SHARD)
    _BATCH_AXES = tuple(axes) if axes else None
    _MESH = mesh
    _KV_TIME_SHARD = kv_time_shard
    try:
        yield
    finally:
        _BATCH_AXES, _MESH, _KV_TIME_SHARD = prev


def constrain_batch(x: jax.Array, *trailing) -> jax.Array:
    """Anchor dim 0 of ``x`` to the batch mesh axes (no-op without hints).

    ``trailing`` are specs for the remaining dims (padded with None).
    """
    if _BATCH_AXES is None:
        return x
    spec = [_BATCH_AXES] + list(trailing)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, P(*spec))


def active() -> bool:
    return _BATCH_AXES is not None


def get_batch_axes() -> Tuple[str, ...]:
    assert _BATCH_AXES is not None, "no sharding hints installed"
    return _BATCH_AXES


def get_mesh():
    return _MESH


def kv_time_sharded() -> bool:
    return _KV_TIME_SHARD and _BATCH_AXES is not None
