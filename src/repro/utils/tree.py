"""Pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_num_params(tree) -> int:
    """Total number of scalar parameters in a pytree of arrays."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) if hasattr(l, "shape") else 1 for l in leaves))


def tree_size_bytes(tree) -> int:
    """Total byte size of a pytree of arrays (or ShapeDtypeStructs)."""
    total = 0
    for l in jax.tree_util.tree_leaves(tree):
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
    return total


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def global_norm(tree) -> jax.Array:
    """L2 norm over all leaves of a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
