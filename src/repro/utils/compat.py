"""Version compatibility shims for the jax API surface.

The repo targets the modern top-level ``jax.shard_map`` API; older jax
releases (< 0.5) only ship ``jax.experimental.shard_map.shard_map`` with the
``check_rep`` keyword where the new API has ``check_vma``. All internal code
imports :func:`shard_map` from here so both generations work unchanged.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with graceful fallback to the experimental API."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
