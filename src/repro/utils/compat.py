"""Version compatibility shims for the jax API surface.

The repo targets the modern top-level ``jax.shard_map`` API; older jax
releases (< 0.5) only ship ``jax.experimental.shard_map.shard_map`` with the
``check_rep`` keyword where the new API has ``check_vma``. All internal code
imports :func:`shard_map` from here so both generations work unchanged.

:func:`optimization_barrier` wraps ``jax.lax.optimization_barrier`` and, on
jax releases whose primitive has no vmap batching rule yet (< 0.5), registers
the trivial one (barrier the batched operands, pass the batch dims through) —
the sharded round engine uses barriers to pin its ordered gradient reduction
and the seed sweeps vmap over it.
"""
from __future__ import annotations

import jax


def _ensure_barrier_batching_rule() -> None:
    try:
        from jax._src.lax import lax as _lax_internal
        from jax.interpreters import batching

        prim = _lax_internal.optimization_barrier_p
        if prim in batching.primitive_batchers:
            return

        def _batcher(args, dims):
            return prim.bind(*args), list(dims)

        batching.primitive_batchers[prim] = _batcher
    except Exception:          # pragma: no cover — newer jax ships the rule
        pass


def optimization_barrier(x):
    """``jax.lax.optimization_barrier`` usable under ``jax.vmap``."""
    _ensure_barrier_batching_rule()
    return jax.lax.optimization_barrier(x)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with graceful fallback to the experimental API."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
