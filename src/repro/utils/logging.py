"""Lightweight structured logging + metric accumulation (no external deps)."""
from __future__ import annotations

import logging
import sys
import time
from typing import Any, Dict, List, Optional

from repro.obs.sinks import InMemorySink, Sink, write_csv

_FORMAT = "%(asctime)s %(name)s %(levelname).1s | %(message)s"


def get_logger(name: str, level: int = logging.INFO) -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
        logger.addHandler(handler)
        logger.setLevel(level)
        logger.propagate = False
    return logger


class MetricLogger:
    """Accumulates scalar metrics per step and can dump a CSV.

    Used by the FL simulation driver and the training loop. Keeps a rolling
    window so the paper's "average of the previous ten global metric values"
    convention (Sec 6.2) is directly supported via ``rolling_mean``.

    Rebased on the observability sinks (:mod:`repro.obs.sinks`): rows
    accumulate in a :class:`repro.obs.sinks.Sink` (``InMemorySink`` by
    default, or any sink passed as ``sink=``) and ``to_csv`` goes through
    the shared stable-column writer, so columns no longer depend on which
    row was logged first and missing cells are explicitly ``""``. The
    public API (``rows``/``log``/``rolling_mean``/``series``/``last``/
    ``to_csv``) is unchanged; new code streaming telemetry should prefer
    the obs sinks directly (this class remains the step-metrics
    accumulator for drivers).
    """

    def __init__(self, out_path: Optional[str] = None,
                 sink: Optional[Sink] = None):
        self._sink = sink if sink is not None else InMemorySink()
        if not hasattr(self._sink, "events"):
            raise ValueError(
                "MetricLogger needs a sink with an .events buffer "
                "(InMemorySink/CsvSink); for stream-only sinks use "
                "repro.obs directly")
        self.out_path = out_path
        self._t0 = time.time()

    @property
    def rows(self) -> List[Dict[str, Any]]:
        return self._sink.events

    def log(self, step: int, **metrics: float) -> None:
        row = {"step": step, "wall_s": round(time.time() - self._t0, 3)}
        row.update({k: float(v) for k, v in metrics.items()})
        self._sink.emit(row)

    def rolling_mean(self, key: str, window: int = 10) -> float:
        vals = [r[key] for r in self.rows if key in r][-window:]
        return float(sum(vals) / max(len(vals), 1))

    def series(self, key: str) -> List[float]:
        return [r[key] for r in self.rows if key in r]

    def last(self, key: str, default: float = float("nan")) -> float:
        for r in reversed(self.rows):
            if key in r:
                return r[key]
        return default

    def to_csv(self, path: Optional[str] = None) -> str:
        path = path or self.out_path
        assert path is not None, "no output path configured"
        return write_csv(path, self.rows)


class Timer:
    """Context-manager wall-clock timer: ``with Timer() as t: ...; t.seconds``."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False
