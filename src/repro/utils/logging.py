"""Lightweight structured logging + metric accumulation (no external deps)."""
from __future__ import annotations

import csv
import logging
import os
import sys
import time
from collections import defaultdict
from typing import Any, Dict, List, Optional

_FORMAT = "%(asctime)s %(name)s %(levelname).1s | %(message)s"


def get_logger(name: str, level: int = logging.INFO) -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
        logger.addHandler(handler)
        logger.setLevel(level)
        logger.propagate = False
    return logger


class MetricLogger:
    """Accumulates scalar metrics per step and can dump a CSV.

    Used by the FL simulation driver and the training loop. Keeps a rolling
    window so the paper's "average of the previous ten global metric values"
    convention (Sec 6.2) is directly supported via ``rolling_mean``.
    """

    def __init__(self, out_path: Optional[str] = None):
        self.rows: List[Dict[str, Any]] = []
        self.out_path = out_path
        self._t0 = time.time()

    def log(self, step: int, **metrics: float) -> None:
        row = {"step": step, "wall_s": round(time.time() - self._t0, 3)}
        row.update({k: float(v) for k, v in metrics.items()})
        self.rows.append(row)

    def rolling_mean(self, key: str, window: int = 10) -> float:
        vals = [r[key] for r in self.rows if key in r][-window:]
        return float(sum(vals) / max(len(vals), 1))

    def series(self, key: str) -> List[float]:
        return [r[key] for r in self.rows if key in r]

    def last(self, key: str, default: float = float("nan")) -> float:
        for r in reversed(self.rows):
            if key in r:
                return r[key]
        return default

    def to_csv(self, path: Optional[str] = None) -> str:
        path = path or self.out_path
        assert path is not None, "no output path configured"
        keys: List[str] = []
        for r in self.rows:
            for k in r:
                if k not in keys:
                    keys.append(k)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(self.rows)
        return path


class Timer:
    """Context-manager wall-clock timer: ``with Timer() as t: ...; t.seconds``."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False
