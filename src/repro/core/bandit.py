"""Bayesian Thompson Sampling bandit with Gaussian conjugate priors.

Implements the sampling strategy of Sec. 3.1 (Eqs. 7-12):

  reward model (Eq. 7):   R^j ~ N(mu^j, 1/tau),  tau fixed (=1 in the paper)
  prior       (Eq. 8):    mu^j ~ N(mu_theta, 1/tau_theta)
  posterior   (Eq. 9):    mu^j | R^j ~ N(mu_hat^j, 1/tau_hat^j)
  mu_hat  (Eq. 10):       (tau_theta*mu_theta + n^j * Z_t(a^j)) / (tau_theta + n^j)
  tau_hat (Eq. 11):       tau_theta + n^j * tau
  Z_t     (Eq. 12):       mean of rewards received by arm j so far

The state is fully vectorized over all M arms; ``bts_select`` draws one sample
per arm from the posterior and returns the top-M_s arms (multiple-plays
Thompson sampling, as in the paper's top-M item selection setting).

All functions are pure and jit-safe.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class BTSState(NamedTuple):
    """Sufficient statistics of the per-arm Gaussian posterior.

    Eq. 10 needs only ``n^j`` (selection counts) and ``Z_t`` (running mean
    reward), so we carry the running *sum* and counts and derive the posterior
    parameters on demand — numerically exact and O(M) memory.
    """

    reward_sum: jax.Array  # (M,) float32 — sum of rewards per arm
    counts: jax.Array      # (M,) float32 — n^j, number of times arm j selected
    mu_theta: jax.Array    # ()  prior mean
    tau_theta: jax.Array   # ()  prior precision
    tau: jax.Array         # ()  fixed reward-likelihood precision (paper: 1.0)


def bts_init(
    num_arms: int,
    mu_theta: float = 0.0,
    tau_theta: float = 10_000.0,
    tau: float = 1.0,
) -> BTSState:
    """Paper hyper-parameters (Sec. 6.1): (mu_theta, tau_theta) = (0, 10000)."""
    return BTSState(
        reward_sum=jnp.zeros((num_arms,), jnp.float32),
        counts=jnp.zeros((num_arms,), jnp.float32),
        mu_theta=jnp.asarray(mu_theta, jnp.float32),
        tau_theta=jnp.asarray(tau_theta, jnp.float32),
        tau=jnp.asarray(tau, jnp.float32),
    )


def bts_posterior(state: BTSState) -> Tuple[jax.Array, jax.Array]:
    """Posterior (mu_hat, tau_hat) per arm — Eqs. 10 and 11."""
    n = state.counts
    # Z_t(a^j) = running mean reward; 0 for never-selected arms (prior rules).
    z = jnp.where(n > 0, state.reward_sum / jnp.maximum(n, 1.0), 0.0)
    mu_hat = (state.tau_theta * state.mu_theta + n * z) / (state.tau_theta + n)
    tau_hat = state.tau_theta + n * state.tau
    return mu_hat, tau_hat


def bts_sample(state: BTSState, key: jax.Array) -> jax.Array:
    """Draw one posterior sample mu^j ~ N(mu_hat^j, 1/tau_hat^j) per arm."""
    mu_hat, tau_hat = bts_posterior(state)
    sigma = jax.lax.rsqrt(tau_hat)
    return mu_hat + sigma * jax.random.normal(key, mu_hat.shape, mu_hat.dtype)


def bts_select(
    state: BTSState, key: jax.Array, num_select: int
) -> Tuple[jax.Array, jax.Array]:
    """Select the top-``num_select`` arms by posterior sample value.

    Returns (indices (num_select,), sampled values (num_select,)).
    Matches Algorithm 1 line 8: "Select M_s items from BTS representing the
    largest sampled values ordered by their expected rewards".
    """
    samples = bts_sample(state, key)
    values, indices = jax.lax.top_k(samples, num_select)
    return indices, values


def bts_update(state: BTSState, indices: jax.Array, rewards: jax.Array,
               weights=None) -> BTSState:
    """Record rewards for the selected arms (Algorithm 1 line 17).

    ``indices`` (M_s,) int32, ``rewards`` (M_s,) float32. Non-finite rewards
    (possible at t=1 when the previous-gradient buffer is all zeros) are
    replaced with 0 so a single bad round cannot poison an arm's posterior.

    ``weights`` (M_s,) f32 are per-pull observation weights: weight 0 means
    the pull was never observed (the fault layer's corrupted rows), so
    neither the reward sum nor the pull count advances — the arm's
    posterior is exactly as if it had not been selected. ``None`` keeps the
    historical unit-weight program byte-for-byte.
    """
    rewards = jnp.where(jnp.isfinite(rewards), rewards, 0.0).astype(jnp.float32)
    if weights is None:
        reward_sum = state.reward_sum.at[indices].add(rewards)
        counts = state.counts.at[indices].add(1.0)
    else:
        w = weights.astype(jnp.float32)
        reward_sum = state.reward_sum.at[indices].add(rewards * w)
        counts = state.counts.at[indices].add(w)
    return state._replace(reward_sum=reward_sum, counts=counts)
