"""The paper's primary contribution: bandit-based payload optimization.

Model-agnostic — the same selector drives CF item-factor payloads and LLM
vocab-row / MoE-expert payloads.
"""
from repro.core.bandit import BTSState, bts_init, bts_select, bts_update, bts_posterior
from repro.core.rewards import RewardState, reward_init, compute_rewards, update_v
from repro.core.payload import PayloadSelector, make_selector, payload_bytes
from repro.core.regret import RegretTracker

__all__ = [
    "BTSState", "bts_init", "bts_select", "bts_update", "bts_posterior",
    "RewardState", "reward_init", "compute_rewards", "update_v",
    "PayloadSelector", "make_selector", "payload_bytes", "RegretTracker",
]
