"""The paper's primary contribution: bandit-based payload optimization.

Model-agnostic — the same selector drives CF item-factor payloads and LLM
vocab-row / MoE-expert payloads.
"""
from repro.core.bandit import BTSState, bts_init, bts_select, bts_update, bts_posterior
from repro.core.rewards import RewardState, reward_init, compute_rewards, update_v
from repro.core.payload import PayloadSelector, make_selector, payload_bytes
from repro.core.selector import (
    STRATEGIES,
    BTSSelectorState,
    FullState,
    MagnitudeState,
    RandomState,
    SelectorConfig,
    SelectorState,
    selector_counts,
    selector_init,
    selector_observe,
    selector_select,
)
from repro.core.regret import RegretTracker

__all__ = [
    "BTSState", "bts_init", "bts_select", "bts_update", "bts_posterior",
    "RewardState", "reward_init", "compute_rewards", "update_v",
    "PayloadSelector", "make_selector", "payload_bytes", "RegretTracker",
    "STRATEGIES", "SelectorConfig", "SelectorState", "BTSSelectorState",
    "RandomState", "FullState", "MagnitudeState",
    "selector_init", "selector_select", "selector_observe", "selector_counts",
]
