"""Unified payload-selection strategies (legacy stateful shim).

A ``PayloadSelector`` decides, each FL round, which of the M arms (CF items,
LLM vocab rows, MoE experts) have their parameters transmitted. Strategies:

  * ``bts``       — the paper's contribution: Bayesian Thompson Sampling
                    guided by the composite reward (Sec. 3).
  * ``random``    — FCF-Random baseline: uniform subset each round.
  * ``full``      — FCF (Original): no reduction; upper bound.
  * ``magnitude`` — beyond-paper baseline: greedy top-M_s by accumulated
                    gradient magnitude (no exploration; lets us quantify how
                    much the bandit's exploration matters).

Since the functional-core refactor, ALL selection math lives in the pure,
scan/vmap-safe :mod:`repro.core.selector`; this class is a thin mutable
wrapper kept for backwards compatibility with Python-side round loops
(``FCFServer``, the federated-LLM driver). New code — in particular the
``lax.scan`` round engine in :mod:`repro.federated.simulation` — should use
``SelectorConfig`` + ``selector_init/select/observe`` directly.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.compress import CodecConfig, dense_bytes, direction_configs
from repro.compress import wire_bytes as codec_wire_bytes
from repro.core.selector import (
    STRATEGIES,
    SelectorConfig,
    SelectorState,
    selector_counts,
    selector_init,
    selector_observe,
    selector_select,
)

__all__ = [
    "STRATEGIES", "payload_bytes", "PayloadSelector", "make_selector",
]


def payload_bytes(num_selected: int, dim: int, dtype_bits: int = 64) -> int:
    """Paper Table 1 formula: (#parameters x bits) / 8 bytes.

    The paper's Table 1 assumes float64 model payloads (``dtype_bits=64``);
    the simulation transmits float32, so accounting call sites must pass the
    *actual* transmission width (see ``PayloadSelector.dtype_bits``).

    Routed through :func:`repro.compress.dense_bytes` — the whole repo's
    byte accounting (dense and quantized) lives in one module.
    """
    return dense_bytes(num_selected, dim, dtype_bits)


@dataclass
class PayloadSelector:
    """Selects ``num_select`` of ``num_arms`` arms each round.

    Thin stateful compatibility shim over the pure functional selector core
    (:mod:`repro.core.selector`): it owns a PRNG key and a state pytree and
    mutates them in place, but every transition is a pure-core call, so a
    shim-driven loop and a scan-driven loop traverse identical math.
    """

    num_arms: int
    num_select: int
    dim: int
    strategy: str = "bts"
    gamma: float = 0.999
    beta2: float = 0.99
    mu_theta: float = 0.0
    tau_theta: float = 10_000.0
    reward_mode: str = "geometric"
    # standardize rewards per round (zero mean / unit variance over the
    # selected arms) before the posterior update. Beyond-paper: keeps the
    # reward scale commensurate with the BTS prior (sigma = 1/sqrt(tau)),
    # so posteriors of explored/unexplored arms keep overlapping and the
    # selection rotates instead of locking onto the first winners —
    # matters on DENSE data where coverage drives accuracy (§Paper-T4).
    reward_norm: bool = False
    # transmission dtype width in bits: the simulation moves float32 payloads,
    # so byte accounting defaults to 32 (the paper's Table 1 uses 64).
    dtype_bits: int = 32
    # payload wire format (repro.compress codec name). "fp32" reproduces the
    # plain dtype_bits accounting; quantized codecs price the actual wire
    # image (values + per-row scales / indices) via compress.wire_bytes.
    codec: str = "fp32"
    seed: int = 0

    def __post_init__(self):
        if self.strategy == "full":
            self.num_select = self.num_arms
        self._cfg = SelectorConfig(
            strategy=self.strategy, num_arms=self.num_arms,
            num_select=self.num_select, dim=self.dim, gamma=self.gamma,
            beta2=self.beta2, mu_theta=self.mu_theta,
            tau_theta=self.tau_theta, reward_mode=self.reward_mode,
            reward_norm=self.reward_norm,
        )
        self._state: SelectorState = selector_init(self._cfg)
        self._key = jax.random.PRNGKey(self.seed)

    # ------------------------------------------------------------------ #
    @property
    def config(self) -> SelectorConfig:
        return self._cfg

    @property
    def state(self) -> SelectorState:
        return self._state

    @property
    def t(self) -> int:
        return int(self._state.t)

    @property
    def bts_state(self):
        """Bandit posterior stats (bts strategy only), for introspection."""
        return getattr(self._state, "bts", None)

    @property
    def reward_state(self):
        return getattr(self._state, "reward", None)

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # ------------------------------------------------------------------ #
    def select(self) -> jax.Array:
        """Return (num_select,) arm indices for this round (Alg. 1 line 8)."""
        indices, self._state = selector_select(
            self._cfg, self._state, self._next_key())
        return indices

    def observe(self, indices: jax.Array, grads: jax.Array) -> jax.Array:
        """Feed back aggregated gradients for the selected arms.

        ``grads`` has shape (num_select, dim). Returns the per-arm rewards
        (zeros for non-learning strategies, for uniform logging).
        Implements Algorithm 1 lines 14-18 for the ``bts`` strategy.
        """
        self._state, rewards = selector_observe(
            self._cfg, self._state, indices, grads)
        return rewards

    # ------------------------------------------------------------------ #
    def _row_bytes(self, num_rows: int) -> int:
        """Downlink wire bytes for ``num_rows`` payload rows of this codec."""
        if self.codec == "fp32":
            # honor dtype_bits (e.g. the paper's Table-1 float64 accounting)
            return payload_bytes(num_rows, self.dim, self.dtype_bits)
        down_cfg, _ = direction_configs(CodecConfig(name=self.codec))
        return codec_wire_bytes(down_cfg, num_rows, self.dim)

    @property
    def round_payload_bytes(self) -> int:
        return self._row_bytes(self.num_select)

    @property
    def full_payload_bytes(self) -> int:
        return self._row_bytes(self.num_arms)

    @property
    def reduction_pct(self) -> float:
        return 100.0 * (1.0 - self.num_select / self.num_arms)

    def selection_counts(self) -> np.ndarray:
        """Per-arm transmission counts — meaningful for every strategy."""
        return np.asarray(selector_counts(self._cfg, self._state))


def make_selector(
    strategy: str,
    num_arms: int,
    dim: int,
    keep_fraction: float = 1.0,
    **kwargs,
) -> PayloadSelector:
    """Factory: ``keep_fraction`` = fraction of arms transmitted per round.

    The paper's "90% payload reduction" is ``keep_fraction=0.10``.
    """
    if strategy == "full":
        num_select = num_arms
    else:
        num_select = max(1, int(round(keep_fraction * num_arms)))
    return PayloadSelector(
        num_arms=num_arms, num_select=num_select, dim=dim, strategy=strategy, **kwargs
    )
