"""Unified payload-selection strategies.

A ``PayloadSelector`` decides, each FL round, which of the M arms (CF items,
LLM vocab rows, MoE experts) have their parameters transmitted. Strategies:

  * ``bts``       — the paper's contribution: Bayesian Thompson Sampling
                    guided by the composite reward (Sec. 3).
  * ``random``    — FCF-Random baseline: uniform subset each round.
  * ``full``      — FCF (Original): no reduction; upper bound.
  * ``magnitude`` — beyond-paper baseline: greedy top-M_s by accumulated
                    gradient magnitude (no exploration; lets us quantify how
                    much the bandit's exploration matters).

The class is a thin stateful wrapper for the (Python-level) FL round loop;
all inner math is pure-JAX and jitted.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bandit import BTSState, bts_init, bts_select, bts_update
from repro.core.rewards import RewardState, compute_rewards, reward_init

STRATEGIES = ("bts", "random", "full", "magnitude")


def payload_bytes(num_selected: int, dim: int, dtype_bits: int = 64) -> int:
    """Paper Table 1 formula: (#parameters x bits) / 8 bytes."""
    return (num_selected * dim * dtype_bits) // 8


@dataclass
class PayloadSelector:
    """Selects ``num_select`` of ``num_arms`` arms each round."""

    num_arms: int
    num_select: int
    dim: int
    strategy: str = "bts"
    gamma: float = 0.999
    beta2: float = 0.99
    mu_theta: float = 0.0
    tau_theta: float = 10_000.0
    reward_mode: str = "geometric"
    # standardize rewards per round (zero mean / unit variance over the
    # selected arms) before the posterior update. Beyond-paper: keeps the
    # reward scale commensurate with the BTS prior (sigma = 1/sqrt(tau)),
    # so posteriors of explored/unexplored arms keep overlapping and the
    # selection rotates instead of locking onto the first winners —
    # matters on DENSE data where coverage drives accuracy (§Paper-T4).
    reward_norm: bool = False
    seed: int = 0

    bts_state: Optional[BTSState] = field(default=None, repr=False)
    reward_state: Optional[RewardState] = field(default=None, repr=False)
    t: int = 0

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}, got {self.strategy!r}")
        if self.strategy == "full":
            self.num_select = self.num_arms
        if not (0 < self.num_select <= self.num_arms):
            raise ValueError(
                f"num_select must be in (0, {self.num_arms}], got {self.num_select}")
        self._key = jax.random.PRNGKey(self.seed)
        if self.strategy == "bts":
            self.bts_state = bts_init(self.num_arms, self.mu_theta, self.tau_theta)
            self.reward_state = reward_init(self.num_arms, self.dim)
        elif self.strategy == "magnitude":
            # accumulated |grad| mass per arm; start uniform so the first
            # rounds are effectively random (cold start).
            self._mass = jnp.zeros((self.num_arms,), jnp.float32)

    # ------------------------------------------------------------------ #
    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def select(self) -> jax.Array:
        """Return (num_select,) arm indices for this round (Alg. 1 line 8)."""
        self.t += 1
        if self.strategy == "full":
            return jnp.arange(self.num_arms, dtype=jnp.int32)
        if self.strategy == "random":
            return jax.random.choice(
                self._next_key(), self.num_arms, (self.num_select,), replace=False
            ).astype(jnp.int32)
        if self.strategy == "magnitude":
            noise = 1e-6 * jax.random.normal(self._next_key(), self._mass.shape)
            _, idx = jax.lax.top_k(self._mass + noise, self.num_select)
            return idx.astype(jnp.int32)
        indices, _ = bts_select(self.bts_state, self._next_key(), self.num_select)
        return indices.astype(jnp.int32)

    def observe(self, indices: jax.Array, grads: jax.Array) -> jax.Array:
        """Feed back aggregated gradients for the selected arms.

        ``grads`` has shape (num_select, dim). Returns the per-arm rewards
        (zeros for non-bandit strategies, for uniform logging).
        Implements Algorithm 1 lines 14-18 for the ``bts`` strategy.
        """
        if self.strategy == "bts":
            rewards, self.reward_state = compute_rewards(
                self.reward_state, indices, grads,
                t=jnp.asarray(self.t, jnp.float32),
                gamma=self.gamma, beta2=self.beta2, mode=self.reward_mode,
            )
            if self.reward_norm:
                mu = jnp.mean(rewards)
                sd = jnp.maximum(jnp.std(rewards), 1e-9)
                rewards = (rewards - mu) / sd
            self.bts_state = bts_update(self.bts_state, indices, rewards)
            return rewards
        if self.strategy == "magnitude":
            mass = jnp.sum(jnp.abs(grads), axis=-1)
            self._mass = self._mass.at[indices].add(mass)
            return mass
        return jnp.zeros((indices.shape[0],), jnp.float32)

    # ------------------------------------------------------------------ #
    @property
    def round_payload_bytes(self) -> int:
        return payload_bytes(self.num_select, self.dim)

    @property
    def full_payload_bytes(self) -> int:
        return payload_bytes(self.num_arms, self.dim)

    @property
    def reduction_pct(self) -> float:
        return 100.0 * (1.0 - self.num_select / self.num_arms)

    def selection_counts(self) -> np.ndarray:
        if self.strategy == "bts":
            return np.asarray(self.bts_state.counts)
        return np.zeros((self.num_arms,), np.float32)


def make_selector(
    strategy: str,
    num_arms: int,
    dim: int,
    keep_fraction: float = 1.0,
    **kwargs,
) -> PayloadSelector:
    """Factory: ``keep_fraction`` = fraction of arms transmitted per round.

    The paper's "90% payload reduction" is ``keep_fraction=0.10``.
    """
    if strategy == "full":
        num_select = num_arms
    else:
        num_select = max(1, int(round(keep_fraction * num_arms)))
    return PayloadSelector(
        num_arms=num_arms, num_select=num_select, dim=dim, strategy=strategy, **kwargs
    )
