"""Composite reward function for federated payload selection (Sec. 3.2).

Eq. 13:
  r_t^j = (1 - gamma*t) * cos_sim(v_t^j, grad_t^j)
        + (gamma / t)   * sum_k | grad_{t-1}^j[k] - grad_t^j[k] |

Eq. 14 (Adam-style second-moment EMA):
  v_t^j = beta2 * v_{t-1}^j + (1 - beta2) * grad_t^j**2      [stored]
  vhat_t^j = v_t^j / (1 - beta2**t)                          [used in Eq. 13]

The paper typesets Eq. 14 with a flat "/(1 - beta2)" on the recursion itself.
Applied literally at every iteration that multiplies v by beta2/(1-beta2) = 99
per selection and overflows float32 after ~40 selections (verified by test).
It is clearly intended as Adam's bias correction, which we apply as vhat
(and which is in any case irrelevant to Eq. 13: cosine similarity is
scale-invariant — see test_cosine_invariant_to_paper_v_normalization).

Two readings of the first coefficient are implemented:

  * ``geometric`` (default): (1 - gamma**t). With the paper's gamma=0.999 this
    starts near 0 and grows toward 1 — exactly the behaviour the paper
    describes ("increases the reward ... with the increasing number of FL
    iterations") and keeps rewards bounded.
  * ``paper_literal``: (1 - gamma*t), the literal typeset formula, which is
    negative for every t > 1/gamma ~= 1 and diverges linearly — contradicting
    the stated behaviour. Kept for auditability.

See DESIGN.md §8 for the full rationale.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


class RewardState(NamedTuple):
    """Per-arm buffers the reward function needs across FL iterations.

    v:         (M, K) exponential decay of past squared gradients (Eq. 14)
    prev_grad: (M, K) last observed gradient per arm  (nabla^j Q, Alg.1 l.18)
    """

    v: jax.Array
    prev_grad: jax.Array


def reward_init(num_arms: int, dim: int, dtype=jnp.float32) -> RewardState:
    """Algorithm 1 lines 5-6: both buffers initialized to zero."""
    return RewardState(
        v=jnp.zeros((num_arms, dim), dtype),
        prev_grad=jnp.zeros((num_arms, dim), dtype),
    )


def update_v(v_sel: jax.Array, grad_sel: jax.Array, beta2: float = 0.99) -> jax.Array:
    """Eq. 14 EMA recursion for the selected rows. Shapes (M_s, K).

    Stored un-normalized (standard Adam); bias correction is applied at use
    site. The paper's literal per-step "/(1-beta2)" diverges (see module doc).
    """
    return beta2 * v_sel + (1.0 - beta2) * jnp.square(grad_sel)


def _cosine_sim(a: jax.Array, b: jax.Array, axis: int = -1) -> jax.Array:
    num = jnp.sum(a * b, axis=axis)
    den = jnp.linalg.norm(a, axis=axis) * jnp.linalg.norm(b, axis=axis)
    return num / jnp.maximum(den, _EPS)


def compute_rewards(
    state: RewardState,
    indices: jax.Array,   # (M_s,) arms selected this round
    grads: jax.Array,     # (M_s, K) aggregated gradients received for them
    t: jax.Array,         # () current FL iteration, 1-based
    gamma: float = 0.999,
    beta2: float = 0.99,
    mode: str = "geometric",
    row_ops=None,         # optional kernels.ops.RowOps for sharded buffers
    row_mask=None,        # (M_s,) bool — False rows were never observed
) -> Tuple[jax.Array, RewardState]:
    """Rewards for the selected arms + updated buffers (Alg. 1 lines 14-18).

    Order of operations follows Algorithm 1: v is updated with the *current*
    gradient (line 14) before the reward is computed (line 16), and prev_grad
    is replaced after (line 18).

    ``t`` is the ATTRIBUTION round of the feedback, not necessarily the
    server's wall-clock round: under the async cohort engine a gradient
    observed at round t was computed against the snapshot (and arm pull) of
    round t-s, and the caller passes that snapshot round here. Both
    time-dependent coefficients — the ``1 - gamma^t`` cosine weight and the
    ``gamma/t`` delta weight — are then evaluated at the pull round, so a
    stale observation is scored exactly as it would have been had it arrived
    synchronously (the delayed-feedback correction; the v/prev_grad buffers
    still advance in arrival order, matching Alg. 1's per-arm recursion).

    The (M, K) buffers are touched only through row gather/scatter of the
    selected arms, so passing a ``row_ops`` pair (``repro.kernels.ops.RowOps``)
    lets the same math run against row-sharded buffers inside ``shard_map``
    (the sharded round engine row-shards v/prev_grad exactly like the global
    model). ``None`` keeps the resident-table fast path.

    ``row_mask`` marks rows whose feedback never arrived (checksum-rejected
    wire rows under the fault layer): their rewards are zeroed and their
    v/prev_grad buffer rows are scattered back *unchanged* — the arm's
    reward recursion is exactly as if it had not been pulled. ``None``
    (the default) compiles the historical program byte-for-byte.
    """
    t = jnp.asarray(t, jnp.float32)
    if row_ops is None:
        v_sel = state.v[indices]
        prev_sel = state.prev_grad[indices]
    else:
        v_sel = row_ops.gather(state.v, indices)
        prev_sel = row_ops.gather(state.prev_grad, indices)

    v_new = update_v(v_sel, grads, beta2)
    if row_ops is not None:
        # pin the EMA's fusion boundary (see kernels.ops.RowOps): the same
        # expression must compile identically whether a resident or a
        # shard-local scatter consumes it
        from repro.utils.compat import optimization_barrier
        v_new = optimization_barrier(v_new)

    if mode == "geometric":
        w_cos = 1.0 - jnp.power(gamma, t)
    elif mode == "paper_literal":
        w_cos = 1.0 - gamma * t
    else:
        raise ValueError(f"unknown reward mode: {mode!r}")

    # Eq. 13 cosine term. Bias-corrected vhat = v/(1-beta2^t) differs from
    # v_new by a positive scalar, to which cosine similarity is invariant, so
    # we use v_new directly (cheaper, numerically safer).
    cos_term = w_cos * _cosine_sim(v_new, grads, axis=-1)
    delta_term = (gamma / t) * jnp.sum(jnp.abs(prev_sel - grads), axis=-1)
    rewards = cos_term + delta_term

    if row_mask is not None:
        keep = row_mask[:, None]
        rewards = jnp.where(row_mask, rewards, 0.0)
        v_new = jnp.where(keep, v_new, v_sel)
        grads = jnp.where(keep, grads, prev_sel)

    if row_ops is None:
        new_state = RewardState(
            v=state.v.at[indices].set(v_new),
            prev_grad=state.prev_grad.at[indices].set(grads),
        )
    else:
        new_state = RewardState(
            v=row_ops.scatter_set(state.v, indices, v_new),
            prev_grad=row_ops.scatter_set(state.prev_grad, indices, grads),
        )
    return rewards, new_state
