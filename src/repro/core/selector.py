"""Pure functional payload-selection core — the scan/vmap-safe engine.

Each strategy is a pure state pytree behind a uniform API:

    state            = selector_init(cfg)
    indices, state   = selector_select(cfg, state, key)
    state, rewards   = selector_observe(cfg, state, indices, feedback)
    counts           = selector_counts(cfg, state)

``cfg`` is a hashable :class:`SelectorConfig` NamedTuple resolved at *trace*
time (strategy dispatch happens in Python, so a jitted/scanned round step
compiles exactly one strategy's code path); every state field is a traced
array, so the whole thing is safe under ``jax.jit``, ``jax.lax.scan`` and
``jax.vmap`` (multi-seed / multi-config sweeps vectorize over the state).

Strategies (Sec. 3 of the paper + beyond-paper baselines):

  * ``bts``       — Bayesian Thompson Sampling over the composite reward.
  * ``random``    — FCF-Random: uniform subset without replacement.
  * ``full``      — FCF (Original): all arms, no reduction.
  * ``magnitude`` — greedy top-M_s by accumulated |grad| mass.

The legacy stateful :class:`repro.core.payload.PayloadSelector` is now a thin
mutable shim over these functions.

ASYNC SELECTION. The staleness-bounded async cohort engine
(``FLSimConfig(backend="async")``) commits cohorts that solved against a
snapshot published up to ``max_staleness`` rounds earlier, so the bandit's
feedback for a pull arrives *delayed*: the reward observed at round t
belongs to the arms pulled at round t-s. :class:`AsyncSelectorState` wraps
any strategy state with a :class:`PendingAttribution` ring buffer recording
the in-flight pulls ``(indices, round)``; at commit time the engine looks
the stale pull up and feeds :func:`selector_observe` with ``t_obs`` set to
the *snapshot* round, so the time-dependent reward coefficients (Eq. 13's
``1 - gamma^t`` and ``gamma/t``) are evaluated at the round the arms were
actually pulled — the delay correction that keeps the bandit's reward scale
consistent under staleness (cf. the delayed-feedback MAB line in PAPERS.md).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.bandit import BTSState as BanditState
from repro.core.bandit import bts_init, bts_select, bts_update
from repro.core.rewards import RewardState, compute_rewards, reward_init

STRATEGIES = ("bts", "random", "full", "magnitude")

# tiny tiebreak noise so the magnitude strategy is uniform-random before any
# mass has accumulated (cold start) instead of degenerate-argsort-stable
_MAGNITUDE_NOISE = 1e-6


class SelectorConfig(NamedTuple):
    """Static (hashable) selector hyper-parameters, fixed for a whole run."""

    strategy: str
    num_arms: int
    num_select: int
    dim: int
    gamma: float = 0.999
    beta2: float = 0.99
    mu_theta: float = 0.0
    tau_theta: float = 10_000.0
    reward_mode: str = "geometric"
    reward_norm: bool = False


class BTSSelectorState(NamedTuple):
    """BTS strategy: bandit posterior + reward buffers + round counter."""

    t: jax.Array          # () int32 — number of selections so far
    bts: BanditState      # per-arm Gaussian posterior sufficient stats
    reward: RewardState   # (M, K) v / prev_grad buffers (Eqs. 13-14)


class RandomState(NamedTuple):
    """FCF-Random: stateless selection; counts kept for analysis parity."""

    t: jax.Array          # () int32
    counts: jax.Array     # (M,) float32 — times each arm was transmitted


class FullState(NamedTuple):
    """FCF (Original): every arm every round; only the round counter."""

    t: jax.Array          # () int32


class MagnitudeState(NamedTuple):
    """Greedy mass strategy: accumulated |grad| mass + selection counts."""

    t: jax.Array          # () int32
    mass: jax.Array       # (M,) float32 — accumulated sum_k |grad_jk|
    counts: jax.Array     # (M,) float32 — times each arm was transmitted


SelectorState = Union[BTSSelectorState, RandomState, FullState, MagnitudeState]


class PendingAttribution(NamedTuple):
    """Ring buffer of arm pulls awaiting delayed feedback (async engine).

    Slot ``(t - 1) % slots`` holds the pull of round t; with ``slots =
    max_staleness + 1`` a pull is overwritten exactly when it can no longer
    be committed (bounded staleness), so the buffer is a fixed-shape scan
    carry costing one (slots, num_select) index block — not a history.
    """

    indices: jax.Array    # (slots, num_select) int32 — arms pulled per slot
    t: jax.Array          # (slots,) int32 — round number of each pull


class AsyncSelectorState(NamedTuple):
    """Any strategy state + the pending-attribution buffer (async engine)."""

    inner: SelectorState
    pending: PendingAttribution


def pending_init(cfg: SelectorConfig, slots: int) -> PendingAttribution:
    """All-zero pending buffer with ``slots`` in-flight pull slots.

    Zero rounds are never looked up: the async engine's staleness schedule
    clamps s <= t-1, so every popped slot has been pushed first.
    """
    return PendingAttribution(
        indices=jnp.zeros((slots, cfg.num_select), jnp.int32),
        t=jnp.zeros((slots,), jnp.int32),
    )


def pending_record(
    pending: PendingAttribution, slot: jax.Array, indices: jax.Array,
    t: jax.Array,
) -> PendingAttribution:
    """Record round ``t``'s pull into ``slot`` (traced index)."""
    return PendingAttribution(
        indices=jax.lax.dynamic_update_index_in_dim(
            pending.indices, indices, slot, 0),
        t=jax.lax.dynamic_update_index_in_dim(
            pending.t, t.astype(jnp.int32), slot, 0),
    )


def pending_lookup(
    pending: PendingAttribution, slot: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """The in-flight pull stored in ``slot``: ``(indices, pull round)``."""
    return (
        jax.lax.dynamic_index_in_dim(pending.indices, slot, 0,
                                     keepdims=False),
        jax.lax.dynamic_index_in_dim(pending.t, slot, 0, keepdims=False),
    )


def async_selector_init(cfg: SelectorConfig, slots: int) -> AsyncSelectorState:
    """Fresh strategy state wrapped with a ``slots``-deep pending buffer."""
    return AsyncSelectorState(
        inner=selector_init(cfg), pending=pending_init(cfg, slots))


def validate_config(cfg: SelectorConfig) -> None:
    if cfg.strategy not in STRATEGIES:
        raise ValueError(
            f"strategy must be one of {STRATEGIES}, got {cfg.strategy!r}")
    if cfg.strategy == "full" and cfg.num_select != cfg.num_arms:
        raise ValueError("full strategy requires num_select == num_arms")
    if not (0 < cfg.num_select <= cfg.num_arms):
        raise ValueError(
            f"num_select must be in (0, {cfg.num_arms}], got {cfg.num_select}")


def selector_init(cfg: SelectorConfig) -> SelectorState:
    """Fresh (all-zero) state for ``cfg.strategy``. Pure; needs no PRNG key."""
    validate_config(cfg)
    t0 = jnp.zeros((), jnp.int32)
    if cfg.strategy == "bts":
        return BTSSelectorState(
            t=t0,
            bts=bts_init(cfg.num_arms, cfg.mu_theta, cfg.tau_theta),
            reward=reward_init(cfg.num_arms, cfg.dim),
        )
    if cfg.strategy == "random":
        return RandomState(t=t0, counts=jnp.zeros((cfg.num_arms,), jnp.float32))
    if cfg.strategy == "magnitude":
        return MagnitudeState(
            t=t0,
            mass=jnp.zeros((cfg.num_arms,), jnp.float32),
            counts=jnp.zeros((cfg.num_arms,), jnp.float32),
        )
    return FullState(t=t0)


def selector_select(
    cfg: SelectorConfig, state: SelectorState, key: jax.Array
) -> Tuple[jax.Array, SelectorState]:
    """One round of arm selection (Alg. 1 line 8).

    Returns ``(indices (num_select,) int32, new_state)``. The caller owns the
    PRNG stream and passes a fresh subkey each round.

    The selection is a SET (Alg. 1 treats Q* as an unordered payload subset),
    and it is returned in ascending index order: downstream consumers are all
    per-row (gather, scatter, rewards), and sorted indices make the hot
    (B, M) / (M, K) gathers sequential-ish — measurably faster per round on
    large tables than value-ordered top-k output.
    """
    state = state._replace(t=state.t + 1)
    if cfg.strategy == "full":
        return jnp.arange(cfg.num_arms, dtype=jnp.int32), state
    if cfg.strategy == "random":
        # uniform subset without replacement as top-k of iid uniforms:
        # O(M log M_s) instead of jax.random.choice's full M-permutation —
        # the difference between ~0.3ms and ~3.5ms per round at M=10k
        scores = jax.random.uniform(key, (cfg.num_arms,))
        _, idx = jax.lax.top_k(scores, cfg.num_select)
        idx = jnp.sort(idx).astype(jnp.int32)
        return idx, state._replace(counts=state.counts.at[idx].add(1.0))
    if cfg.strategy == "magnitude":
        noise = _MAGNITUDE_NOISE * jax.random.normal(key, state.mass.shape)
        _, idx = jax.lax.top_k(state.mass + noise, cfg.num_select)
        idx = jnp.sort(idx).astype(jnp.int32)
        return idx, state._replace(counts=state.counts.at[idx].add(1.0))
    idx, _ = bts_select(state.bts, key, cfg.num_select)
    return jnp.sort(idx).astype(jnp.int32), state


def selector_observe(
    cfg: SelectorConfig,
    state: SelectorState,
    indices: jax.Array,    # (num_select,) arms selected this round
    feedback: jax.Array,   # (num_select, dim) aggregated gradient feedback
    row_ops=None,          # optional kernels.ops.RowOps for sharded buffers
    t_obs: Optional[jax.Array] = None,   # attribution round (async delay fix)
    row_mask: Optional[jax.Array] = None,  # (num_select,) bool observed gate
) -> Tuple[SelectorState, jax.Array]:
    """Feed back the round's aggregated gradients (Alg. 1 lines 14-18).

    Returns ``(new_state, per-arm rewards)``; rewards are zeros for the
    strategies that do not learn from feedback (uniform logging shape).

    ``row_ops`` (``repro.kernels.ops.RowOps``) routes the BTS reward
    buffers' row traffic — the only O(M*K) state a selector carries — so the
    sharded round engine can keep those buffers row-sharded next to the
    global model. The (M,) posterior/count vectors always stay resident
    (selection is a full-table top_k).

    ``t_obs`` is the round the reward should be attributed to. ``None``
    (synchronous) uses the selector's own round counter; the async engine
    passes the *snapshot* round of the stale pull so the reward's
    time-dependent coefficients are delay-corrected (module docstring).

    ``row_mask`` marks the pulls whose feedback actually arrived (the
    fault layer's checksum-rejected rows are False): rewards are computed,
    standardized and accumulated over the observed pulls only — an
    unobserved arm's posterior, count and reward buffers stay exactly as
    if the arm had not been pulled. ``None`` keeps the historical program
    byte-for-byte.
    """
    if cfg.strategy == "bts":
        t_attr = state.t if t_obs is None else t_obs
        rewards, reward_state = compute_rewards(
            state.reward, indices, feedback,
            t=t_attr.astype(jnp.float32),
            gamma=cfg.gamma, beta2=cfg.beta2, mode=cfg.reward_mode,
            row_ops=row_ops, row_mask=row_mask,
        )
        if cfg.reward_norm:
            if row_mask is None:
                mu = jnp.mean(rewards)
                sd = jnp.maximum(jnp.std(rewards), 1e-9)
                rewards = (rewards - mu) / sd
            else:
                # standardize over the observed pulls only, then re-zero
                # the unobserved rows so they contribute nothing downstream
                w = row_mask.astype(jnp.float32)
                n = jnp.maximum(jnp.sum(w), 1.0)
                mu = jnp.sum(rewards * w) / n
                var = jnp.sum(jnp.square(rewards - mu) * w) / n
                sd = jnp.maximum(jnp.sqrt(var), 1e-9)
                rewards = jnp.where(row_mask, (rewards - mu) / sd, 0.0)
        weights = None if row_mask is None else row_mask.astype(jnp.float32)
        return (
            state._replace(
                bts=bts_update(state.bts, indices, rewards, weights=weights),
                reward=reward_state,
            ),
            rewards,
        )
    if cfg.strategy == "magnitude":
        mass = jnp.sum(jnp.abs(feedback), axis=-1)
        if row_mask is not None:
            mass = mass * row_mask.astype(jnp.float32)
        return state._replace(mass=state.mass.at[indices].add(mass)), mass
    return state, jnp.zeros((indices.shape[0],), jnp.float32)


def selector_counts(cfg: SelectorConfig, state: SelectorState) -> jax.Array:
    """Per-arm transmission counts, meaningful for every strategy.

    bts: posterior observation counts n^j (updated at observe time);
    random/magnitude: counts accumulated at select time; full: t per arm.
    """
    if isinstance(state, AsyncSelectorState):
        state = state.inner
    if cfg.strategy == "bts":
        return state.bts.counts
    if cfg.strategy in ("random", "magnitude"):
        return state.counts
    return jnp.full(
        (cfg.num_arms,), state.t.astype(jnp.float32), jnp.float32)


def pull_stats(cfg: SelectorConfig,
               state: SelectorState) -> Tuple[jax.Array, jax.Array]:
    """Traced arm-pull coverage: ``(arms_explored, pull_max)`` scalars.

    ``arms_explored`` counts arms transmitted at least once, ``pull_max``
    is the hottest arm's transmission count — the per-strategy pull-count
    aggregates the round-telemetry stream emits. Built on
    :func:`selector_counts`, whose (M,) vectors stay replicated under the
    sharded engine, so these reductions are shard-safe as-is.
    """
    counts = selector_counts(cfg, state)
    return (jnp.sum(counts > 0).astype(jnp.float32),
            jnp.max(counts).astype(jnp.float32))
