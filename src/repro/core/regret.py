"""Empirical regret / selection-statistics tracking (Sec. 3.3).

The paper argues (without proof) that FCF-BTS regret should be sub-linear in
FL iterations. We cannot prove a bound either, but we *measure* an empirical
proxy: per-round pseudo-regret against the best fixed subset in hindsight,

    regret_t = mean(reward of best-M_s arms by hindsight mean) - mean(reward_t)

accumulated over rounds. A sub-linear cumulative curve (flattening slope) is
reported by the convergence benchmark.
"""
from __future__ import annotations

from typing import List

import numpy as np


class RegretTracker:
    def __init__(self, num_arms: int):
        self.num_arms = num_arms
        # host-side oracle: f64 accumulators on purpose, so the tracker can
        # cross-check the traced f32 telemetry fold against higher precision
        self.reward_sum = np.zeros((num_arms,), np.float64)  # repro-lint: disable=dtype-width
        self.counts = np.zeros((num_arms,), np.float64)  # repro-lint: disable=dtype-width
        self.per_round_mean: List[float] = []
        self.cumulative: List[float] = []
        self._cum = 0.0

    def record(self, indices, rewards) -> None:
        indices = np.asarray(indices)
        rewards = np.asarray(rewards, np.float64)  # repro-lint: disable=dtype-width
        self.reward_sum[indices] += rewards
        self.counts[indices] += 1.0
        self.per_round_mean.append(float(rewards.mean()))

        m_s = len(indices)
        means = np.divide(
            self.reward_sum, self.counts,
            out=np.zeros_like(self.reward_sum), where=self.counts > 0,
        )
        best = np.sort(means)[-m_s:].mean()
        self._cum += max(0.0, best - self.per_round_mean[-1])
        self.cumulative.append(self._cum)

    def slope_last(self, window: int = 50) -> float:
        """Average per-round regret over the trailing window (lower = converged)."""
        if len(self.cumulative) < 2:
            return float("nan")
        w = min(window, len(self.cumulative) - 1)
        return (self.cumulative[-1] - self.cumulative[-1 - w]) / w
