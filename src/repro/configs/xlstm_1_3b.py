"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks at 7:1 (arXiv:2405.04517).
48 layers = 6 periods of (7x mLSTM + 1x sLSTM). d_ff=0: xLSTM blocks carry
their own internal up/down projections. Fully recurrent => long_500k runs."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    source="[arXiv:2405.04517]",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "mlstm",
                   "mlstm", "mlstm", "mlstm", "slstm"),
    mlstm_proj_factor=2.0,
)
