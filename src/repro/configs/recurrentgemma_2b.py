"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 attn:recurrent
[arXiv:2402.19427]. Pattern: (rglru, rglru, attn) repeating; 26 layers =
8 full periods + 2 remainder recurrent layers. Local attention window 2048."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    source="[arXiv:2402.19427]",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,            # GQA kv=1 (MQA)
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "swa"),
    sliding_window=2048,       # RG's local attention window
    d_rnn=2560,                # lru_width
    conv_width=4,
)
