"""qwen3-4b [dense] — qk_norm, GQA kv=8 [hf:Qwen/Qwen3-8B lineage].
Pure full attention => long_500k skipped (DESIGN.md §4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    arch_type="dense",
    source="[hf:Qwen/Qwen3-8B]",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151_936,
    block_pattern=("attn",),
    qk_norm=True,
    rope_theta=1_000_000.0,
)
