"""minitron-4b [dense] — pruned nemotron, GQA kv=8, full attention
[arXiv:2407.14679]. Pure full attention => long_500k skipped (DESIGN.md §4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    arch_type="dense",
    source="[arXiv:2407.14679]",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256_000,
    block_pattern=("attn",),
)
