"""internvl2-2b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].
LM BACKBONE ONLY: the InternViT vision encoder + MLP projector is a stub;
input_specs() supplies precomputed patch embeddings (B, patches, d_model)
that are prepended to the text embeddings. long_500k skipped (full attn)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    arch_type="vlm",
    source="[arXiv:2404.16821]",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    block_pattern=("attn",),
    modality="vision",
    frontend_seq=256,          # stub: ViT patch embeddings per image
)
