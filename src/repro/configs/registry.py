"""Architecture registry: --arch <id> resolution for launchers and tests."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig
from repro.configs.recurrentgemma_2b import CONFIG as _recurrentgemma_2b
from repro.configs.starcoder2_7b import CONFIG as _starcoder2_7b
from repro.configs.mixtral_8x7b import CONFIG as _mixtral_8x7b
from repro.configs.minitron_4b import CONFIG as _minitron_4b
from repro.configs.stablelm_12b import CONFIG as _stablelm_12b
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.xlstm_1_3b import CONFIG as _xlstm_1_3b
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4_scout
from repro.configs.qwen3_4b import CONFIG as _qwen3_4b
from repro.configs.internvl2_2b import CONFIG as _internvl2_2b

ARCH_CONFIGS: Dict[str, ModelConfig] = {
    c.name: c for c in [
        _recurrentgemma_2b, _starcoder2_7b, _mixtral_8x7b, _minitron_4b,
        _stablelm_12b, _seamless, _xlstm_1_3b, _llama4_scout, _qwen3_4b,
        _internvl2_2b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCH_CONFIGS:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCH_CONFIGS)}")
    return ARCH_CONFIGS[name]


def list_archs() -> List[str]:
    return sorted(ARCH_CONFIGS)
