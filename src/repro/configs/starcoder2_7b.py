"""starcoder2-7b [dense] — GQA kv=4, RoPE, 4k sliding-window attention
[arXiv:2402.19173]. The SWA variant makes long_500k eligible (DESIGN.md §4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    source="[arXiv:2402.19173]",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    block_pattern=("swa",),
    sliding_window=4096,
    rope_theta=100_000.0,
)
