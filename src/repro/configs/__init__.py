from repro.configs.base import ModelConfig
from repro.configs.shapes import INPUT_SHAPES, InputShape
from repro.configs.registry import ARCH_CONFIGS, get_config, list_archs

__all__ = ["ModelConfig", "INPUT_SHAPES", "InputShape", "ARCH_CONFIGS",
           "get_config", "list_archs"]
