"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal
[arXiv:2308.11596]. TRANSFORMER BACKBONE ONLY: the mel-spectrogram +
conformer feature extractor is a stub; input_specs() supplies precomputed
frame embeddings (B, frames, d_model). 24 bidirectional encoder layers +
24 causal decoder layers with cross-attention. long_500k skipped
(enc-dec cross-attention is full; DESIGN.md §4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    source="[arXiv:2308.11596]",
    num_layers=24,             # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,           # GQA kv=16 (full MHA)
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    block_pattern=("attn",),
    encoder_layers=24,
    modality="audio",
    frontend_seq=1024,         # stub: #audio frames after feature extraction
)
