"""Architecture configuration.

``block_pattern`` is the repeating unit of layer kinds; the model scans over
``num_layers // len(pattern)`` periods (remainder layers, if any, are applied
unscanned with the pattern prefix). Kinds:

  attn      full-attention + dense MLP
  swa       sliding-window attention + dense MLP
  moe       full-attention + MoE FFN
  moe_swa   sliding-window attention + MoE FFN
  rglru     RecurrentGemma recurrent block + dense MLP
  mlstm     xLSTM matrix-memory block (self-contained, no extra MLP)
  slstm     xLSTM scalar-memory block (self-contained, no extra MLP)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | audio | vlm
    source: str                         # citation ([arXiv:...] / [hf:...])
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[str, ...] = ("attn",)
    # attention details
    sliding_window: int = 4096
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # recurrent details
    d_rnn: int = 0                      # rglru width (defaults to d_model)
    conv_width: int = 4
    mlstm_proj_factor: float = 2.0
    # encoder-decoder (audio): encoder is bidirectional full attention
    encoder_layers: int = 0
    # multimodal stub frontend: #embedding positions supplied by input_specs
    modality: str = "text"              # text | audio | vision
    frontend_seq: int = 0
    # numerics
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # activation checkpointing of the scanned block body during training:
    #   "blocks" — jax.checkpoint every scanned period (memory-term default)
    #   "none"   — store all residuals (the naive baseline; see §Perf)
    remat: str = "blocks"

    # ------------------------------------------------------------------ #
    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def padded_vocab(self) -> int:
        """Embedding/unembedding table rows: vocab_size rounded up to a
        multiple of 512 so the vocab dim shards 16-way (and is MXU-aligned).
        Logits for padded ids are masked to -inf in the loss / decode."""
        if self.vocab_size % 512 == 0 or self.vocab_size % 16 == 0:
            return self.vocab_size
        return ((self.vocab_size + 511) // 512) * 512

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def supports_long_context(self) -> bool:
        """True if every block is sub-quadratic in sequence length (windowed
        attention or recurrent) — the long_500k eligibility rule."""
        if self.is_enc_dec:
            return False
        return all(k in ("swa", "moe_swa", "rglru", "mlstm", "slstm")
                   for k in self.block_pattern)

    @property
    def has_decode_step(self) -> bool:
        return True   # all assigned archs are decoders or enc-dec

    def param_count(self) -> int:
        """Approximate total parameter count (used for roofline MODEL_FLOPS)."""
        return _count_params(self)

    def active_param_count(self) -> int:
        """MoE-active parameters (6*N_active*D convention)."""
        return _count_params(self, active_only=True)

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                vocab: int = 1024, num_experts: int = 4) -> "ModelConfig":
        """CPU-smoke-test variant of the same family (assignment rule:
        <=2 layers, d_model<=512, <=4 experts)."""
        pattern = self.block_pattern
        layers = max(num_layers, len(pattern))
        layers = (layers // len(pattern)) * len(pattern)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        return dataclasses.replace(
            self,
            num_layers=layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=2 * d_model,
            vocab_size=vocab,
            sliding_window=16,
            num_experts=min(self.num_experts, num_experts),
            experts_per_token=min(self.experts_per_token,
                                  min(self.num_experts, num_experts)),
            d_rnn=d_model if self.d_rnn else 0,
            encoder_layers=min(self.encoder_layers, 2),
            frontend_seq=min(self.frontend_seq, 8),
            dtype="float32",
        )


def _slstm_ffn(d: int) -> int:
    """Matches models/xlstm._ffn_dim: 4/3*d rounded up to a multiple of 256."""
    return int(-(-(4 * d / 3) // 256) * 256)


def _count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    d, ff = cfg.d_model, cfg.d_ff
    qdim = cfg.num_heads * cfg.head_dim
    kvdim = cfg.num_kv_heads * cfg.head_dim
    attn = d * qdim * 2 + d * kvdim * 2
    dense_mlp = 3 * d * ff
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)

    def block_params(kind: str) -> int:
        if kind in ("attn", "swa"):
            return attn + dense_mlp + 2 * d
        if kind in ("moe", "moe_swa"):
            e = (cfg.experts_per_token if active_only else cfg.num_experts)
            return attn + e * 3 * d * ff + d * cfg.num_experts + 2 * d
        if kind == "rglru":
            r = cfg.d_rnn or d
            return 2 * d * r + 2 * r * r + cfg.conv_width * r + r * d \
                + dense_mlp + 2 * d
        if kind == "mlstm":
            di = int(cfg.mlstm_proj_factor * d)
            return 2 * d * di + 3 * di * di + di * 2 * cfg.num_heads + di * d + d
        if kind == "slstm":
            dh = d // cfg.num_heads
            return 4 * d * d + cfg.num_heads * dh * 4 * dh \
                + 2 * d * _slstm_ffn(d) + _slstm_ffn(d) * d + d
        raise ValueError(kind)

    pattern = cfg.block_pattern
    for i in range(cfg.num_layers):
        total += block_params(pattern[i % len(pattern)])
    if cfg.is_enc_dec:
        # encoder self-attn layers + decoder cross-attention additions
        total += cfg.encoder_layers * (attn + dense_mlp + 2 * d)
        total += cfg.num_layers * (attn + d)          # cross-attn per dec layer
    return int(total)
