"""llama4-scout-17b-a16e [moe] — 16 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E]. Chunked/local attention (8k window)
makes long_500k eligible. Text backbone (early-fusion image tokens arrive
as ordinary embeddings through input_specs for the vlm-style shapes)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    source="[hf:meta-llama/Llama-4-Scout-17B-16E]",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,                 # per-expert FFN width
    vocab_size=202_048,
    block_pattern=("moe_swa",),
    sliding_window=8192,       # chunked-attention analogue
    num_experts=16,
    experts_per_token=1,
    rope_theta=500_000.0,
)
