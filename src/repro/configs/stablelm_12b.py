"""stablelm-12b [dense] — GQA kv=8, full attention
[hf:stabilityai/stablelm-2-1_6b lineage]. long_500k skipped (full attn)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    source="[hf:stabilityai/stablelm-2-1_6b]",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100_352,
    block_pattern=("attn",),
)
