"""Request batching + snapshot publish/swap around a :class:`ServingModel`.

Two serving-infrastructure concerns live here, deliberately outside the
pure model:

  * REQUEST BATCHING — recommendation requests arrive at arbitrary batch
    sizes, but every distinct shape costs one XLA compile. The engine pads
    each request up to a fixed bucket ladder (``buckets``), so steady-state
    traffic hits a handful of compiled programs no matter the request mix;
    oversized requests chunk over the largest bucket. Padded user rows are
    all-zero factor vectors whose results are sliced off before returning.
  * SNAPSHOT PUBLISH/SWAP — training publishes encoded payload rows
    (the async ring's :class:`repro.cf.server.EncodedSnapshot` entries);
    ``publish_snapshot`` patches them into the wire-resident model and
    atomically swaps the result in. The swap is a single reference
    assignment under a lock with a monotonically bumped version;
    in-flight requests keep the model value they grabbed at entry (JAX
    arrays are immutable), so readers see either the old or the new model
    in full — never a mix (tested in tests/test_serving.py).

The model/version pair only changes together under ``_lock``; the jit
cache is keyed on (bucket, M, codec) shapes, so a swap to a same-shape
model never recompiles.
"""
from __future__ import annotations

import threading
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.serve.model import ServingModel

DEFAULT_BUCKETS = (8, 64, 256)


class ServeStats(NamedTuple):
    """Engine counters (monotonic since construction)."""

    requests: int           # recommend() calls
    users: int              # real (unpadded) user rows served
    installs: int           # snapshot/model swaps
    version: int            # current model version


class ServingEngine:
    """Batched, hot-swappable serving front-end over a wire-format model."""

    def __init__(
        self,
        model: ServingModel,
        *,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        top_n: int = 10,
        block_m: int = 1024,
    ):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive, got {buckets!r}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.top_n = int(top_n)
        self.block_m = int(block_m)
        self._lock = threading.Lock()
        self._model = model
        self._requests = 0
        self._users = 0
        self._installs = 0

    # ------------------------------------------------------------- #
    # model access + publish/swap
    # ------------------------------------------------------------- #
    @property
    def model(self) -> ServingModel:
        with self._lock:
            return self._model

    def swap(self, model: ServingModel) -> ServingModel:
        """Atomically install ``model`` as the live serving model."""
        with self._lock:
            if model.version <= self._model.version:
                model = model._replace(version=self._model.version + 1)
            self._model = model
            self._installs += 1
            return model

    def publish_rows(self, indices: jax.Array, rows_wire: Any) -> ServingModel:
        """Patch encoded payload rows into the live model and swap."""
        return self.swap(self.model.install_rows(indices, rows_wire))

    def publish_snapshot(self, snapshot) -> ServingModel:
        """Install an async-ring :class:`EncodedSnapshot` (no fp32 decode)."""
        return self.swap(self.model.install_snapshot(snapshot))

    def publisher(self):
        """A ``(round, ServerState) -> None`` hook for ``FLSimConfig
        .snapshot_hook``: publishes each eval-boundary state into this
        engine. Async-engine states publish their freshest encoded ring
        snapshot — the wire rows themselves, never a decoded fp32 Q* —
        while synchronous states (no ring) re-encode the full table.
        """
        def hook(_round: int, state) -> None:
            if state.snapshots != ():
                from repro.cf.server import latest_snapshot
                self.publish_snapshot(latest_snapshot(state))
            else:
                cur = self.model
                self.swap(ServingModel.from_dense(
                    cur.cfg, state.q, version=cur.version + 1))

        return hook

    def stats(self) -> ServeStats:
        with self._lock:
            return ServeStats(requests=self._requests, users=self._users,
                              installs=self._installs,
                              version=self._model.version)

    # ------------------------------------------------------------- #
    # batched reads
    # ------------------------------------------------------------- #
    def _bucket_for(self, b: int) -> int:
        for size in self.buckets:
            if b <= size:
                return size
        return self.buckets[-1]

    def recommend(
        self,
        p: jax.Array,                             # (B, K) user factors
        top_n: Optional[int] = None,
        train_mask: Optional[jax.Array] = None,   # (B, M); 1 = exclude
    ) -> Tuple[jax.Array, jax.Array]:
        """Top-N items for a batch of users: ``(scores, ids)``, best first.

        The request is padded up to the bucket ladder (or chunked over the
        largest bucket) and scored against ONE model value grabbed at
        entry, so a concurrent publish never splits a request across model
        versions.
        """
        n = self.top_n if top_n is None else int(top_n)
        model = self.model           # one consistent view for the request
        b = p.shape[0]
        out_v, out_i = [], []
        step = self.buckets[-1]
        for start in range(0, b, step):
            pc = p[start:start + step]
            mc = None if train_mask is None \
                else train_mask[start:start + step]
            v, i = self._run_bucket(model, pc, mc, n)
            out_v.append(v)
            out_i.append(i)
        with self._lock:
            self._requests += 1
            self._users += b
        if len(out_v) == 1:
            return out_v[0], out_i[0]
        return jnp.concatenate(out_v), jnp.concatenate(out_i)

    def _run_bucket(self, model: ServingModel, p: jax.Array,
                    mask: Optional[jax.Array], top_n: int):
        b = p.shape[0]
        size = self._bucket_for(b)
        if b < size:
            p = jnp.pad(p, ((0, size - b), (0, 0)))
            if mask is not None:
                mask = jnp.pad(mask, ((0, size - b), (0, 0)))
        vals, idx = model.topn(p, top_n, train_mask=mask,
                               block_m=self.block_m)
        return vals[:b], idx[:b]
