"""Request batching + snapshot publish/swap around a :class:`ServingModel`.

Two serving-infrastructure concerns live here, deliberately outside the
pure model:

  * REQUEST BATCHING — recommendation requests arrive at arbitrary batch
    sizes, but every distinct shape costs one XLA compile. The engine pads
    each request up to a fixed bucket ladder (``buckets``), so steady-state
    traffic hits a handful of compiled programs no matter the request mix;
    oversized requests chunk over the largest bucket. Padded user rows are
    all-zero factor vectors whose results are sliced off before returning.
  * SNAPSHOT PUBLISH/SWAP — training publishes encoded payload rows
    (the async ring's :class:`repro.cf.server.EncodedSnapshot` entries);
    ``publish_snapshot`` patches them into the wire-resident model and
    atomically swaps the result in. The swap is a single reference
    assignment under a lock with a monotonically bumped version;
    in-flight requests keep the model value they grabbed at entry (JAX
    arrays are immutable), so readers see either the old or the new model
    in full — never a mix (tested in tests/test_serving.py).

The model/version pair only changes together under ``_lock``; the jit
cache is keyed on (bucket, M, codec) shapes, so a swap to a same-shape
model never recompiles.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.obs.config import ObsConfig
from repro.obs.hist import LatencyHistogram
from repro.obs.prom import Metric, render
from repro.obs.trace import span
from repro.serve.model import ServingModel
from repro.utils.logging import get_logger

log = get_logger("repro.serve")

DEFAULT_BUCKETS = (8, 64, 256)


class LoadShedError(RuntimeError):
    """A request was refused admission (queue full or deadline exceeded).

    ``reason`` is ``"queue"`` or ``"deadline"`` — the same label the
    ``frs_serve_shed_total`` Prometheus counter is partitioned by."""

    def __init__(self, message: str, reason: str):
        self.reason = reason
        super().__init__(message)


class ServeStats(NamedTuple):
    """Engine counters (monotonic since construction)."""

    requests: int           # recommend() calls
    users: int              # real (unpadded) user rows served
    installs: int           # snapshot/model swaps
    version: int            # current model version
    # trailing defaults keep historical positional constructions valid
    shed: int = 0           # requests refused admission (queue + deadline)
    publish_failures: int = 0   # failed snapshot-install attempts


class ServingEngine:
    """Batched, hot-swappable serving front-end over a wire-format model."""

    def __init__(
        self,
        model: ServingModel,
        *,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        top_n: int = 10,
        block_m: int = 1024,
        obs: Optional[ObsConfig] = None,
        max_inflight: Optional[int] = None,
        admission_deadline_s: Any = None,
        publish_max_retries: int = 2,
        publish_backoff_s: float = 0.05,
    ):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive, got {buckets!r}")
        if max_inflight is not None and max_inflight <= 0:
            raise ValueError(
                f"max_inflight must be positive, got {max_inflight!r}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.top_n = int(top_n)
        self.block_m = int(block_m)
        # load-shedding knobs: a bounded admission queue (max_inflight
        # concurrent recommend() calls; None = unbounded) and per-request
        # admission deadlines (seconds a request may have waited before
        # entry; a float applies to every bucket, a {bucket: seconds} dict
        # sets per-bucket budgets — larger buckets usually afford less
        # queueing since they cost more to score)
        self.max_inflight = None if max_inflight is None else int(max_inflight)
        self.admission_deadline_s = admission_deadline_s
        self.publish_max_retries = int(publish_max_retries)
        self.publish_backoff_s = float(publish_backoff_s)
        self._lock = threading.Lock()
        self._model = model
        self._requests = 0
        self._users = 0
        self._installs = 0
        self._shed_queue = 0
        self._shed_deadline = 0
        self._publish_failures = 0
        self._publish_retries = 0
        # observability: metrics() renders regardless, but per-request
        # latency timing (a device sync per bucket chunk) only runs with an
        # enabled obs config — the read path is untouched otherwise
        self._obs_on = obs is not None and obs.enabled
        self._lat: Dict[int, LatencyHistogram] = {
            b: LatencyHistogram() for b in self.buckets}
        self._inflight = 0
        self._snapshot_age = -1     # rounds; -1 = never published

    # ------------------------------------------------------------- #
    # model access + publish/swap
    # ------------------------------------------------------------- #
    @property
    def model(self) -> ServingModel:
        with self._lock:
            return self._model

    def swap(self, model: ServingModel) -> ServingModel:
        """Atomically install ``model`` as the live serving model."""
        with self._lock:
            if model.version <= self._model.version:
                model = model._replace(version=self._model.version + 1)
            self._model = model
            self._installs += 1
            return model

    def publish_rows(self, indices: jax.Array, rows_wire: Any) -> ServingModel:
        """Patch encoded payload rows into the live model and swap."""
        return self.swap(self.model.install_rows(indices, rows_wire))

    def publish_snapshot(self, snapshot) -> ServingModel:
        """Install an async-ring :class:`EncodedSnapshot` (no fp32 decode)."""
        return self.swap(self.model.install_snapshot(snapshot))

    def publisher(self):
        """A ``(round, ServerState) -> None`` hook for ``FLSimConfig
        .snapshot_hook``: publishes each eval-boundary state into this
        engine. Async-engine states publish their freshest encoded ring
        snapshot — the wire rows themselves, never a decoded fp32 Q* —
        while synchronous states (no ring) re-encode the full table.

        Degradation contract: a failed install is retried up to
        ``publish_max_retries`` times with exponential backoff; if every
        attempt fails the hook logs, bumps ``frs_serve_publish_failures_
        total``, and RETURNS — the previously installed model version
        stays live and the exception never propagates into the training
        loop (which has its own containment, but should not need it for
        serving-side faults).
        """
        def hook(round_: int, state) -> None:
            attempts = self.publish_max_retries + 1
            for attempt in range(attempts):
                if attempt:
                    with self._lock:
                        self._publish_retries += 1
                    time.sleep(self.publish_backoff_s * 2 ** (attempt - 1))
                try:
                    with span("publish_snapshot", round=round_,
                              attempt=attempt):
                        if state.snapshots != ():
                            from repro.cf.server import latest_snapshot
                            snap = latest_snapshot(state)
                            self.publish_snapshot(snap)
                            age = round_ - int(snap.t) if self._obs_on else 0
                        else:
                            cur = self.model
                            self.swap(ServingModel.from_dense(
                                cur.cfg, state.q, version=cur.version + 1))
                            age = 0     # sync states publish their live table
                    with self._lock:
                        self._snapshot_age = age
                    return
                except Exception:
                    with self._lock:
                        self._publish_failures += 1
                    log.exception(
                        "snapshot install attempt %d/%d failed at round %d",
                        attempt + 1, attempts, round_)
            log.error(
                "giving up on round %d snapshot publish after %d attempts; "
                "previous model version stays live", round_, attempts)

        return hook

    def stats(self) -> ServeStats:
        with self._lock:
            return ServeStats(requests=self._requests, users=self._users,
                              installs=self._installs,
                              version=self._model.version,
                              shed=self._shed_queue + self._shed_deadline,
                              publish_failures=self._publish_failures)

    # ------------------------------------------------------------- #
    # observability
    # ------------------------------------------------------------- #
    def latency_histogram(self) -> LatencyHistogram:
        """All bucket histograms merged (exact) — one engine-wide view.

        Populated only when the engine was built with an enabled obs
        config; empty (``total == 0``) otherwise.
        """
        with self._lock:
            hists = [h.copy() for h in self._lat.values()]
        merged = hists[0]
        for h in hists[1:]:
            merged = merged.merge(h)
        return merged

    def metrics(self) -> str:
        """Prometheus text exposition of the engine's counters, gauges and
        per-bucket latency histograms (format 0.0.4).

        Always renders — latency histograms just stay empty without an
        enabled obs config. Thread-safe against concurrent ``recommend``/
        ``swap`` calls: everything is copied under the lock, so a scrape
        sees one consistent cut (counters monotone across scrapes).
        """
        with self._lock:
            model = self._model
            requests, users = self._requests, self._users
            installs, inflight = self._installs, self._inflight
            age = self._snapshot_age
            shed_q, shed_d = self._shed_queue, self._shed_deadline
            pub_fail, pub_retry = self._publish_failures, self._publish_retries
            hists = [({"bucket": str(b)}, h.copy())
                     for b, h in sorted(self._lat.items())]
        families = [
            Metric("frs_serve_requests_total", "counter",
                   "recommend() calls served", [({}, requests)]),
            Metric("frs_serve_users_total", "counter",
                   "real (unpadded) user rows served", [({}, users)]),
            Metric("frs_serve_installs_total", "counter",
                   "model snapshot installs (swap count)",
                   [({}, installs)]),
            Metric("frs_serve_queue_depth", "gauge",
                   "recommend() calls currently in flight",
                   [({}, inflight)]),
            Metric("frs_serve_model_version", "gauge",
                   "live serving model version", [({}, model.version)]),
            Metric("frs_serve_snapshot_age_rounds", "gauge",
                   "age in rounds of the last published snapshot "
                   "(-1 = never published)", [({}, age)]),
            Metric("frs_serve_resident_bytes", "gauge",
                   "wire-resident serving model bytes",
                   [({}, model.resident_bytes())]),
            Metric("frs_serve_shed_total", "counter",
                   "requests refused admission, by reason",
                   [({"reason": "queue"}, shed_q),
                    ({"reason": "deadline"}, shed_d)]),
            Metric("frs_serve_publish_failures_total", "counter",
                   "failed snapshot-install attempts", [({}, pub_fail)]),
            Metric("frs_serve_publish_retries_total", "counter",
                   "snapshot-install retry attempts", [({}, pub_retry)]),
            Metric("frs_serve_latency_seconds", "histogram",
                   "recommend latency per padded request bucket",
                   hists=hists),
        ]
        return render(families)

    # ------------------------------------------------------------- #
    # batched reads
    # ------------------------------------------------------------- #
    def _bucket_for(self, b: int) -> int:
        for size in self.buckets:
            if b <= size:
                return size
        return self.buckets[-1]

    def _deadline_for(self, bucket: int) -> Optional[float]:
        d = self.admission_deadline_s
        if d is None:
            return None
        if isinstance(d, dict):
            v = d.get(bucket)
            return None if v is None else float(v)
        return float(d)

    def recommend(
        self,
        p: jax.Array,                             # (B, K) user factors
        top_n: Optional[int] = None,
        train_mask: Optional[jax.Array] = None,   # (B, M); 1 = exclude
        admitted_at: Optional[float] = None,      # time.monotonic() at enqueue
    ) -> Tuple[jax.Array, jax.Array]:
        """Top-N items for a batch of users: ``(scores, ids)``, best first.

        The request is padded up to the bucket ladder (or chunked over the
        largest bucket) and scored against ONE model value grabbed at
        entry, so a concurrent publish never splits a request across model
        versions.

        Load shedding: when ``admitted_at`` (a ``time.monotonic()`` stamp
        taken where the request entered the system) is older than the
        bucket's admission deadline, or ``max_inflight`` requests are
        already executing, the request is refused with
        :class:`LoadShedError` before any scoring work — shedding stale or
        excess load costs O(1), keeping admitted-request latency bounded.
        """
        n = self.top_n if top_n is None else int(top_n)
        b = p.shape[0]
        if admitted_at is not None:
            deadline = self._deadline_for(self._bucket_for(b))
            if deadline is not None \
                    and time.monotonic() - admitted_at > deadline:
                with self._lock:
                    self._shed_deadline += 1
                raise LoadShedError(
                    f"request of {b} users exceeded its {deadline}s "
                    f"admission deadline", reason="deadline")
        with self._lock:
            # check-and-increment under one lock acquisition: the bounded
            # queue can never over-admit between a check and a later bump
            if self.max_inflight is not None \
                    and self._inflight >= self.max_inflight:
                self._shed_queue += 1
                raise LoadShedError(
                    f"{self._inflight} requests in flight "
                    f"(max_inflight={self.max_inflight})", reason="queue")
            self._inflight += 1
        model = self.model           # one consistent view for the request
        timed = self._obs_on
        try:
            with span("serve_batch", users=b):
                out_v, out_i = [], []
                step = self.buckets[-1]
                for start in range(0, b, step):
                    pc = p[start:start + step]
                    mc = None if train_mask is None \
                        else train_mask[start:start + step]
                    if timed:
                        t0 = time.perf_counter()
                        v, i = self._run_bucket(model, pc, mc, n)
                        jax.block_until_ready((v, i))
                        dt = time.perf_counter() - t0
                        with self._lock:
                            self._lat[self._bucket_for(pc.shape[0])] \
                                .record(dt)
                    else:
                        v, i = self._run_bucket(model, pc, mc, n)
                    out_v.append(v)
                    out_i.append(i)
        finally:
            with self._lock:
                self._inflight -= 1
        with self._lock:
            self._requests += 1
            self._users += b
        if len(out_v) == 1:
            return out_v[0], out_i[0]
        return jnp.concatenate(out_v), jnp.concatenate(out_i)

    def _run_bucket(self, model: ServingModel, p: jax.Array,
                    mask: Optional[jax.Array], top_n: int):
        b = p.shape[0]
        size = self._bucket_for(b)
        if b < size:
            p = jnp.pad(p, ((0, size - b), (0, 0)))
            if mask is not None:
                mask = jnp.pad(mask, ((0, size - b), (0, 0)))
        vals, idx = model.topn(p, top_n, train_mask=mask,
                               block_m=self.block_m)
        return vals[:b], idx[:b]
