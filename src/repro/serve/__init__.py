"""Compressed serving engine: the read path of the federated recommender.

Training optimizes which payload rows move (the paper's contribution);
this package serves recommendations FROM that compressed payload. The
model stays in its downlink wire format end-to-end — the async engine's
encoded ring snapshots install directly as serving rows
(:func:`ServingModel.install_snapshot`, no fp32 round-trip), and requests
score against the wire image through the fused dequant->score->top-N
kernel (:func:`repro.kernels.wire_topn`), never materializing the dense
fp32 table or a (B, M) score matrix.

  ServingModel   immutable wire-format model + row-patch install
  ServingEngine  pad-to-bucket request batching + atomic snapshot swap
"""
from repro.serve.model import ServingModel
from repro.serve.engine import LoadShedError, ServeStats, ServingEngine

__all__ = ["LoadShedError", "ServeStats", "ServingEngine", "ServingModel"]
