"""The serving model: a full item table resident in its WIRE format.

A deployed federated recommender holds the same artifact it trains over
the wire — the compressed payload (SecEmb's deployment model; PAPERS.md).
:class:`ServingModel` keeps the (M, K) item table as a wire pytree
(int8 codes + per-row scales, fp16 halves, packed int4 nibbles, or raw
fp32) and exposes exactly two operations:

  * ``topn`` — fused dequant->score->top-N via :func:`repro.kernels
    .wire_topn`; the fp32 table and the (B, M) score matrix never exist.
  * ``install_rows`` / ``install_snapshot`` — patch the wire image with
    freshly published payload rows, still encoded. Every codec here
    encodes PER ROW (row-leading leaves, per-row scales), so scattering
    wire rows is bit-identical to re-encoding the patched dense table —
    the property that makes decode-free publishing sound (tested in
    tests/test_serving.py).

Models are immutable pytree-of-arrays values: installs return a new
model with a bumped ``version``, and in-flight readers keep scoring the
arrays they already hold (JAX arrays cannot be mutated), so a concurrent
swap can never tear a request.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compress import (
    CodecConfig, direction_configs, encode, wire_resident_bytes,
)
from repro.kernels import wire_topn


class ServingModel(NamedTuple):
    cfg: CodecConfig        # the DOWNLINK wire format the table is held in
    wire: Any               # full-table wire pytree (row-leading leaves)
    num_items: int          # M
    dim: int                # K
    version: int = 0        # bumped on every install (swap audit trail)

    @classmethod
    def from_dense(cls, cfg: CodecConfig, item_factors: jax.Array,
                   version: int = 0) -> "ServingModel":
        """Encode a dense (M, K) table into its resident wire image.

        The one place a dense table legitimately enters the serving path:
        bootstrapping from a synchronous-engine state (which holds fp32 Q).
        Async ring snapshots skip this — see :meth:`install_snapshot`.
        """
        down_cfg, _ = direction_configs(cfg)
        m, k = item_factors.shape
        return cls(cfg=down_cfg, wire=encode(down_cfg, item_factors),
                   num_items=m, dim=k, version=version)

    def topn(
        self,
        p: jax.Array,                         # (B, K) user factors
        top_n: int,
        train_mask: Optional[jax.Array] = None,   # (B, M); 1 = exclude
        *,
        block_m: int = 1024,
    ) -> Tuple[jax.Array, jax.Array]:
        """(scores (B, N) f32, item ids (B, N) i32), best first."""
        return wire_topn(self.cfg, self.wire, p, self.dim, top_n,
                         train_mask=train_mask, block_m=block_m)

    def install_rows(self, indices: jax.Array, rows_wire: Any,
                     ) -> "ServingModel":
        """Patch ``indices`` with already-encoded payload rows (no decode).

        ``rows_wire`` must be in this model's wire format with row-leading
        leaves (the async ring's entries are, by construction — the ring
        mirrors the downlink format). Indices must be unique, as selector
        pulls are.
        """
        idx = indices.astype(jnp.int32)
        wire = jax.tree.map(lambda full, rows: full.at[idx].set(rows),
                            self.wire, rows_wire)
        return self._replace(wire=wire, version=self.version + 1)

    def install_snapshot(self, snapshot) -> "ServingModel":
        """Install a :class:`repro.cf.server.EncodedSnapshot` ring entry."""
        return self.install_rows(snapshot.indices, snapshot.wire)

    def resident_bytes(self) -> int:
        """Bytes the model actually occupies in serving memory."""
        return wire_resident_bytes(self.wire)
