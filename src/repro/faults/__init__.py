"""Deterministic fault injection: pre-sampled schedules, scan-carry
damage counters, and the simulated-crash / resume machinery.

See :mod:`repro.faults.core` for the model and docs/FAULT_MODEL.md for
the taxonomy, determinism guarantees and degradation semantics.
"""
from repro.faults.core import (
    FAULT_SEED_STREAM, FaultConfig, FaultSchedule, FaultState, RoundFaults,
    SimulatedCrash, build_fault_schedule, fault_state_init,
    fault_state_update, flip_row_bits, round_faults_xs,
)

__all__ = [
    "FAULT_SEED_STREAM", "FaultConfig", "FaultSchedule", "FaultState",
    "RoundFaults", "SimulatedCrash", "build_fault_schedule",
    "fault_state_init", "fault_state_update", "flip_row_bits",
    "round_faults_xs",
]
