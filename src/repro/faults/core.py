"""Deterministic fault injection for the federated round engines.

The fault model (docs/FAULT_MODEL.md) is *pre-sampled data, not runtime
randomness*: :func:`build_fault_schedule` draws every fault the trajectory
will ever see from one host RNG stream at build time — per-round client
dropout and straggler timeouts over the cohort slots, per-round wire-row
corruption over the selected payload rows, and an optional simulated host
crash at a fixed round. The schedule is fed to the compiled engines as
ordinary ``lax.scan`` xs (:class:`RoundFaults` slices) and the cumulative
damage counters ride the scan carry as :class:`FaultState` (the
``ServerState.faults`` field) — so faulted trajectories are reproducible
bit-for-bit across the scan/python/shard/async backends and under vmap,
exactly like the cohort and staleness schedules they mirror
(``federated/simulation._build`` / ``_staleness_schedule``).

Determinism contract: the dropout/straggler draws consume the RNG stream
first and the corruption draws second, so enabling corruption never
perturbs the dropout schedule (and vice versa: ``corrupt_rate=0`` skips
the corruption draw entirely).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

# RNG stream id for the fault schedule: seed+61 keeps it disjoint from the
# cohort (seed+31) and staleness (seed+47) streams
FAULT_SEED_STREAM = 61


class SimulatedCrash(RuntimeError):
    """Raised by the simulation driver when ``FaultConfig.crash_round``
    fires: the process "dies" mid-trajectory, losing every round since the
    last checkpoint. Resume via ``FLSimConfig.resume_from``."""

    def __init__(self, round_: int, checkpoint_dir: Optional[str] = None):
        self.round_ = round_
        self.checkpoint_dir = checkpoint_dir
        where = f" (checkpoints in {checkpoint_dir!r})" if checkpoint_dir \
            else ""
        super().__init__(f"simulated host crash at round {round_}{where}")


class FaultConfig(NamedTuple):
    """Static fault-injection knobs (hashable config, never a carry).

    With ``enabled=False`` (the default) every fault hook is skipped at
    Python/trace time — the compiled programs are bit-identical to a build
    without this package (``tests/test_faults.py``).
    """

    enabled: bool = False
    # per-cohort-slot probability the client drops out (never reports)
    dropout_rate: float = 0.0
    # per-cohort-slot probability the client misses the round deadline;
    # semantics equal dropout for the round (the update never lands) but
    # the damage is counted separately
    straggler_rate: float = 0.0
    # per-payload-row probability of a wire bit flip on the uplink
    corrupt_rate: float = 0.0
    # simulated host crash while executing this 1-based round (None = never)
    crash_round: Optional[int] = None
    # fault-stream sub-seed: schedules vary with (sim seed, this)
    seed: int = 0

    def validate(self) -> None:
        for name in ("dropout_rate", "straggler_rate", "corrupt_rate"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"FaultConfig.{name} must be in [0, 1), "
                                 f"got {v}")
        if self.dropout_rate + self.straggler_rate >= 1.0:
            raise ValueError(
                "dropout_rate + straggler_rate must be < 1 (a cohort with "
                "no possible survivors cannot renormalize)")
        if self.crash_round is not None and self.crash_round < 1:
            raise ValueError("crash_round is 1-based and must be >= 1, "
                             f"got {self.crash_round}")


class FaultSchedule(NamedTuple):
    """Host-side pre-sampled schedule for a whole trajectory (numpy)."""

    survivors: np.ndarray        # (rounds, cohort) f32 — 1 kept, 0 lost
    dropped: np.ndarray          # (rounds,) f32 — dropped clients per round
    stragglers: np.ndarray       # (rounds,) f32 — stragglers per round
    corrupt: Optional[np.ndarray]  # (rounds, num_select) bool, or None


class RoundFaults(NamedTuple):
    """One round's fault slice, consumed by the fused round step as scan
    xs. ``corrupt`` is the empty pytree ``()`` when corruption checking is
    statically off (so the faults-without-corruption programs carry no
    checksum ops at all)."""

    survivors: jax.Array         # (cohort,) f32, padded to the block total
    dropped: jax.Array           # () f32
    stragglers: jax.Array        # () f32
    corrupt: Any = ()            # (num_select,) bool, or ()


class FaultState(NamedTuple):
    """Cumulative damage counters riding the scan carry
    (``ServerState.faults``)."""

    dropped: jax.Array           # () f32 — clients that never reported
    stragglers: jax.Array        # () f32 — clients past the round deadline
    corrupt_rows: jax.Array      # () f32 — wire rows rejected at decode
    retransmit_bytes: jax.Array  # () f32 — byte cost of re-sending them


def fault_state_init() -> FaultState:
    return FaultState(
        dropped=jnp.zeros((), jnp.float32),
        stragglers=jnp.zeros((), jnp.float32),
        corrupt_rows=jnp.zeros((), jnp.float32),
        retransmit_bytes=jnp.zeros((), jnp.float32),
    )


def fault_state_update(state: FaultState, dropped: jax.Array,
                       stragglers: jax.Array, corrupt_rows: jax.Array,
                       retransmit_bytes: jax.Array) -> FaultState:
    return FaultState(
        dropped=state.dropped + dropped,
        stragglers=state.stragglers + stragglers,
        corrupt_rows=state.corrupt_rows + corrupt_rows,
        retransmit_bytes=state.retransmit_bytes + retransmit_bytes,
    )


def build_fault_schedule(cfg: FaultConfig, rounds: int, cohort_size: int,
                         num_select: int, seed: int) -> FaultSchedule:
    """Pre-sample every fault of the trajectory (host-side, build time).

    One uniform draw per (round, cohort slot) is partitioned into
    dropout / straggler / survivor bands, so the two loss modes are
    mutually exclusive and their marginal rates are exact. The corruption
    draw happens strictly after, and only when ``corrupt_rate > 0``.
    """
    rng = np.random.default_rng([seed + FAULT_SEED_STREAM, cfg.seed])
    u = rng.random((rounds, cohort_size))
    dropped_mask = u < cfg.dropout_rate
    straggler_mask = (~dropped_mask) & \
        (u < cfg.dropout_rate + cfg.straggler_rate)
    survivors = (~(dropped_mask | straggler_mask)).astype(np.float32)
    corrupt = None
    if cfg.corrupt_rate > 0.0:
        corrupt = rng.random((rounds, num_select)) < cfg.corrupt_rate
    return FaultSchedule(
        survivors=survivors,
        dropped=dropped_mask.sum(axis=1).astype(np.float32),
        stragglers=straggler_mask.sum(axis=1).astype(np.float32),
        corrupt=corrupt,
    )


def round_faults_xs(sched: FaultSchedule, start: int, end: int,
                    pad_to: Optional[int] = None) -> RoundFaults:
    """Slice rounds ``[start, end)`` of the schedule into scan xs.

    ``pad_to`` zero-pads the survivor axis (padded cohort slots are dead
    by definition, and a zero pad keeps ``sum(survivors)`` exact)."""
    surv = sched.survivors[start:end]
    if pad_to is not None and pad_to > surv.shape[1]:
        surv = np.pad(surv, ((0, 0), (0, pad_to - surv.shape[1])))
    corrupt = () if sched.corrupt is None \
        else jnp.asarray(sched.corrupt[start:end])
    return RoundFaults(
        survivors=jnp.asarray(surv, jnp.float32),
        dropped=jnp.asarray(sched.dropped[start:end]),
        stragglers=jnp.asarray(sched.stragglers[start:end]),
        corrupt=corrupt,
    )


def _flip_first_word(leaf: jax.Array, corrupt: jax.Array) -> jax.Array:
    """XOR the lowest bit of each corrupted row's first element."""
    rows = leaf.shape[0]
    flat = leaf.reshape(rows, -1)
    first = flat[:, 0]
    if leaf.dtype == jnp.float32:
        w = jax.lax.bitcast_convert_type(first, jnp.int32)
        w = jnp.where(corrupt, w ^ jnp.int32(1), w)
        first = jax.lax.bitcast_convert_type(w, jnp.float32)
    elif leaf.dtype == jnp.float16:
        w = jax.lax.bitcast_convert_type(first, jnp.int16)
        w = jnp.where(corrupt, w ^ jnp.int16(1), w)
        first = jax.lax.bitcast_convert_type(w, jnp.float16)
    else:
        one = jnp.asarray(1, leaf.dtype)
        first = jnp.where(corrupt, first ^ one, first)
    return flat.at[:, 0].set(first).reshape(leaf.shape)


def flip_row_bits(wire: Any, corrupt: jax.Array) -> Any:
    """Inject a single bit flip into each corrupted row of a wire pytree.

    The flip lands in the first leaf (values for every codec), so any
    ``corrupt[i]=True`` row decodes to a different value than was encoded
    — which :func:`repro.compress.verify_rows` must catch."""
    leaves, treedef = jax.tree_util.tree_flatten(wire)
    leaves = [_flip_first_word(leaves[0], corrupt)] + leaves[1:]
    return jax.tree_util.tree_unflatten(treedef, leaves)
