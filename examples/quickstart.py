"""Quickstart: the paper's technique in ~50 lines, plus the codec axis.

Runs federated collaborative filtering on a synthetic Movielens-like
dataset four ways — full payload (FCF), bandit-selected 10% payload
(FCF-BTS, the paper's method), random 10% payload (FCF-Random), and
FCF-BTS with the 10% payload *also* quantized to int8 on the wire
(the compression subsystem's joint rows x bits reduction) — then prints
recommendation quality next to the bytes actually moved.

  PYTHONPATH=src python examples/quickstart.py

Fault-tolerance flags (docs/FAULT_MODEL.md) drive the crash-resume
contract end to end: `--checkpoint-dir` checkpoints at eval boundaries,
`--crash-round T` simulates a host crash at round T (the process exits
via SimulatedCrash), and a second invocation with `--resume-from DIR`
picks up from the newest hash-verified checkpoint and finishes with the
exact trajectory the uninterrupted run would have had.
"""
import argparse
from typing import Optional, Sequence

from repro.data.synthetic import load_dataset
from repro.faults import FaultConfig, SimulatedCrash
from repro.federated.simulation import FLSimConfig, run_fcf_simulation


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="checkpoint the BTS run at every eval boundary")
    ap.add_argument("--crash-round", type=int, default=None,
                    help="simulate a host crash at this round (BTS run)")
    ap.add_argument("--resume-from", default=None,
                    help="resume the BTS run from a checkpoint dir/path")
    args = ap.parse_args(argv)
    fault_kw = {}
    if args.crash_round is not None:
        fault_kw["faults"] = FaultConfig(enabled=True,
                                         crash_round=args.crash_round)

    spec, train, test = load_dataset("movielens-mini", seed=0)
    print(f"dataset: {spec.name}  users={spec.num_users} items={spec.num_items}")

    variants = {
        "full": dict(strategy="full"),
        # the bts run is the one the fault-tolerance flags drive
        "bts": dict(strategy="bts", checkpoint_dir=args.checkpoint_dir,
                    resume_from=args.resume_from, **fault_kw),
        "random": dict(strategy="random"),
        "bts+int8": dict(strategy="bts", codec="int8"),
    }
    results = {}
    for name, kw in variants.items():
        cfg = FLSimConfig(keep_fraction=0.10, rounds=args.rounds, theta=50,
                          eval_every=max(args.rounds // 6, 1),
                          eval_users=200, seed=0, **kw)
        try:
            results[name] = run_fcf_simulation(train, test, cfg)
        except SimulatedCrash as exc:
            print(f"\nsimulated crash at round {exc.round_} — rerun with "
                  f"--resume-from {args.checkpoint_dir} to continue")
            raise SystemExit(3)

    print(f"\n{'method':<12} {'F1@10':>8} {'MAP@10':>8} {'MB moved':>10}")
    for name, res in results.items():
        mb = (res.bytes_down + res.bytes_up) / 1e6
        print(f"{name:<12} {res.final['f1']:>8.4f} "
              f"{res.final['map']:>8.4f} {mb:>10.1f}")

    full, bts = results["full"], results["bts"]

    def moved(r):
        return r.bytes_down + r.bytes_up

    saved = 100 * (1 - moved(bts) / moved(full))
    drop = 100 * (1 - bts.final["f1"] / full.final["f1"])
    print(f"\nFCF-BTS moved {saved:.0f}% fewer bytes for a "
          f"{drop:.1f}% F1 drop (paper: 90% fewer, ~4-8% drop on sparse data)")

    q = results["bts+int8"]
    saved_q = 100 * (1 - moved(q) / moved(full))
    drop_q = 100 * (1 - q.final["f1"] / full.final["f1"])
    print(f"BTS + int8 wire moved {saved_q:.1f}% fewer bytes "
          f"({moved(bts) / moved(q):.1f}x less than BTS alone) for a "
          f"{drop_q:.1f}% F1 drop — the second payload axis is almost free")


if __name__ == "__main__":
    main()
