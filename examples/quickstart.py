"""Quickstart: the paper's technique in ~40 lines.

Runs federated collaborative filtering on a synthetic Movielens-like
dataset three ways — full payload (FCF), bandit-selected 10% payload
(FCF-BTS, the paper's method), and random 10% payload (FCF-Random) —
then prints recommendation quality next to the bytes actually moved.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.data.synthetic import load_dataset
from repro.federated.simulation import FLSimConfig, run_fcf_simulation


def main() -> None:
    spec, train, test = load_dataset("movielens-mini", seed=0)
    print(f"dataset: {spec.name}  users={spec.num_users} items={spec.num_items}")

    results = {}
    for strategy in ("full", "bts", "random"):
        cfg = FLSimConfig(strategy=strategy, keep_fraction=0.10, rounds=150,
                          theta=50, eval_every=25, eval_users=200, seed=0)
        results[strategy] = run_fcf_simulation(train, test, cfg)

    print(f"\n{'method':<12} {'F1@10':>8} {'MAP@10':>8} {'MB moved':>10}")
    for name, res in results.items():
        mb = (res.bytes_down + res.bytes_up) / 1e6
        print(f"{name:<12} {res.final['f1']:>8.4f} "
              f"{res.final['map']:>8.4f} {mb:>10.1f}")

    full, bts = results["full"], results["bts"]
    saved = 100 * (1 - (bts.bytes_down + bts.bytes_up)
                   / (full.bytes_down + full.bytes_up))
    drop = 100 * (1 - bts.final["f1"] / full.final["f1"])
    print(f"\nFCF-BTS moved {saved:.0f}% fewer bytes for a "
          f"{drop:.1f}% F1 drop (paper: 90% fewer, ~4-8% drop on sparse data)")


if __name__ == "__main__":
    main()
