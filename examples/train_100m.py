"""End-to-end driver: train a ~100M-parameter member of any assigned
architecture family for a few hundred steps on synthetic token data.

  PYTHONPATH=src python examples/train_100m.py --arch qwen3-4b --steps 300

Equivalent to `python -m repro.launch.train --reduced`; kept as an example
so the public API surface (configs -> model -> train loop -> checkpoint)
is visible in one place.
"""
import argparse

from repro.launch import train as train_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    ns = argparse.Namespace(
        arch=args.arch, reduced=True, steps=args.steps,
        batch_size=args.batch_size, seq_len=args.seq_len, lr=3e-4,
        log_every=20, ckpt_dir=args.ckpt_dir, ckpt_every=100, seed=0)
    summary = train_mod.train_centralized(ns)
    assert summary["loss_dropped"], "training must reduce the loss"
    print(summary)


if __name__ == "__main__":
    main()
