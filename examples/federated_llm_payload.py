"""The paper's generalization to deep models: federated LLM fine-tuning
with bandit-selected *vocab-row* payloads.

Arms = vocabulary rows of the embedding/unembedding tables (the
item-dependent payload of an LLM); each round the BTS bandit picks 10% of
rows to transmit, clients run standard local SGD, and the Eq. 13 reward is
computed on the per-row embedding deltas. Compare against `--strategy full`
or `random` to see the accuracy/traffic trade-off, and add `--codec int8`
to also quantize the row payload on the wire (fused dequant+scatter
patch-in on the client).

  PYTHONPATH=src python examples/federated_llm_payload.py --strategy bts
"""
import argparse

from repro.configs.registry import get_config
from repro.federated.llm import FedLLMConfig, run_federated_llm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--strategy", default="bts",
                    choices=("bts", "random", "full", "magnitude"))
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--codec", default="fp32",
                    choices=("fp32", "fp16", "int8", "int4", "topk"),
                    help="wire format for the vocab-row payload")
    args = ap.parse_args()

    # 2-layer, 1024-vocab member of the arch family (CPU-sized)
    cfg = get_config(args.arch).reduced()
    fed = FedLLMConfig(strategy=args.strategy, keep_fraction=0.10,
                       rounds=args.rounds, num_clients=6,
                       clients_per_round=3, local_steps=2,
                       batch_size=4, seq_len=32, seed=0, codec=args.codec)
    out = run_federated_llm(cfg, fed)

    print(f"\narch family: {args.arch} (reduced)  strategy: {args.strategy}"
          f"  codec: {args.codec}")
    print(f"eval loss:        {out['first_eval_loss']:.4f} -> "
          f"{out['final_eval_loss']:.4f} over {args.rounds} rounds")
    print(f"vocab-row bytes:  {out['bytes_item_dep'] / 1e6:.1f} MB "
          f"(full-payload equivalent {out['bytes_item_dep_full_equivalent'] / 1e6:.1f} MB)")
    print(f"item-dependent payload reduction: "
          f"{out['item_payload_reduction_pct']:.1f}%")
    print(f"body bytes (constant in vocab):   {out['bytes_body'] / 1e6:.1f} MB")


if __name__ == "__main__":
    main()
