"""Batched serving example: prefill a prompt batch, decode greedily against
the KV cache (the serve_step the decode dry-run shapes lower), for any
assigned architecture including the recurrent/hybrid ones.

  PYTHONPATH=src python examples/serve_batch.py --arch recurrentgemma-2b
"""
import argparse

from repro.launch import serve as serve_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    ns = argparse.Namespace(arch=args.arch, reduced=True, batch=args.batch,
                            prompt_len=32, gen=args.gen, seed=0)
    out = serve_mod.serve(ns)
    print(f"generated token matrix shape: {out['generated'].shape}")


if __name__ == "__main__":
    main()
