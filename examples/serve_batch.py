"""Batched LLM serving example: prefill a prompt batch, decode greedily
against the KV cache (the serve_step the decode dry-run shapes lower), for
any assigned architecture including the recurrent/hybrid ones.

  PYTHONPATH=src python examples/serve_batch.py --arch recurrentgemma-2b

The federated-recommender counterpart — serving top-N recommendations
straight off the COMPRESSED item-factor model via the fused
dequant->score->top-N kernel — lives in examples/serve_recs.py.
"""
import argparse
import sys
from typing import List, Optional

from repro.launch import serve as serve_mod


def main(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny smoke config (seconds, CI-sized)")
    args = ap.parse_args(argv)
    if args.dry_run:
        args.batch, args.gen = 2, 4

    ns = argparse.Namespace(arch=args.arch, reduced=True, batch=args.batch,
                            prompt_len=8 if args.dry_run else 32,
                            gen=args.gen, seed=0)
    out = serve_mod.serve(ns)
    print(f"generated token matrix shape: {out['generated'].shape}")
    return out


if __name__ == "__main__":
    main(sys.argv[1:])
