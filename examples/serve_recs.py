"""Recommendation serving example: train-and-serve off the compressed model.

Runs the paper's full deployment loop — the async round engine trains
FCF-BTS while publishing its ENCODED Q* snapshots into a live serving
engine (no fp32 round-trip), then a batched request stream scores users
through the fused dequant->score->top-N kernel against the int8 wire
image. Prints users/sec, p50/p99 latency, and resident model bytes.

  PYTHONPATH=src python examples/serve_recs.py
  PYTHONPATH=src python examples/serve_recs.py --codec int4 --batch 64

Observability (repro.obs): ``--obs-out DIR`` streams per-round training
telemetry + host spans to JSONL and writes a final Prometheus scrape;
``--metrics-port 0`` serves live ``/metrics`` (latency histograms, model
version, snapshot age). p50/p99 here use the same obs.hist quantile math
as the engine endpoint and benchmarks/serving.py.

  PYTHONPATH=src python examples/serve_recs.py --obs-out /tmp/obs \
      --metrics-port 9100 --serve-forever
  PYTHONPATH=src python -m repro.obs.check /tmp/obs

The LLM decode counterpart (KV-cache serving of the model zoo) lives in
examples/serve_batch.py.
"""
import sys
from typing import List, Optional

from repro.launch import serve_recs as serve_recs_mod


def main(argv: Optional[List[str]] = None) -> dict:
    return serve_recs_mod.main(argv)


if __name__ == "__main__":
    main(sys.argv[1:])
