"""Recommendation serving example: train-and-serve off the compressed model.

Runs the paper's full deployment loop — the async round engine trains
FCF-BTS while publishing its ENCODED Q* snapshots into a live serving
engine (no fp32 round-trip), then a batched request stream scores users
through the fused dequant->score->top-N kernel against the int8 wire
image. Prints users/sec, p50/p99 latency, and resident model bytes.

  PYTHONPATH=src python examples/serve_recs.py
  PYTHONPATH=src python examples/serve_recs.py --codec int4 --batch 64

The LLM decode counterpart (KV-cache serving of the model zoo) lives in
examples/serve_batch.py.
"""
import sys
from typing import List, Optional

from repro.launch import serve_recs as serve_recs_mod


def main(argv: Optional[List[str]] = None) -> dict:
    return serve_recs_mod.main(argv)


if __name__ == "__main__":
    main(sys.argv[1:])
