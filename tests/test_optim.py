"""Tests for the from-scratch Adam (dense + sparse-row payload variant)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adam import (
    AdamConfig, adam_init, adam_update, adam_update_rows, sgd_update,
)


def _reference_adam(params, grads_seq, cfg):
    """Straightline numpy Adam for cross-checking."""
    # f64 on purpose: the oracle should be strictly more precise than the DUT
    p = np.array(params, np.float64)  # repro-lint: disable=dtype-width
    m = np.zeros_like(p)
    v = np.zeros_like(p)
    for t, g in enumerate(grads_seq, start=1):
        g = np.asarray(g, np.float64)  # repro-lint: disable=dtype-width
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * g**2
        mhat = m / (1 - cfg.beta1**t)
        vhat = v / (1 - cfg.beta2**t)
        p = p - cfg.lr * mhat / (np.sqrt(vhat) + cfg.eps)
    return p


def test_dense_adam_matches_reference():
    cfg = AdamConfig(lr=0.01, beta1=0.1, beta2=0.99, eps=1e-8)  # paper values
    rng = np.random.default_rng(0)
    params = jnp.asarray(rng.standard_normal(12).astype(np.float32))
    grads_seq = [rng.standard_normal(12).astype(np.float32) for _ in range(5)]
    state = adam_init(params)
    p = params
    for g in grads_seq:
        p, state = adam_update(jnp.asarray(g), state, p, cfg)
    want = _reference_adam(params, grads_seq, cfg)
    np.testing.assert_allclose(np.asarray(p), want, rtol=1e-4, atol=1e-6)


def test_row_adam_equals_dense_when_all_rows_selected():
    cfg = AdamConfig()
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.standard_normal((6, 4)).astype(np.float32))
    idx = jnp.arange(6)
    dense_state = adam_init(table)
    row_state = adam_init(table, per_row=True)
    p_dense, p_rows = table, table
    for _ in range(4):
        g = jnp.asarray(rng.standard_normal((6, 4)).astype(np.float32))
        p_dense, dense_state = adam_update(g, dense_state, p_dense, cfg)
        p_rows, row_state = adam_update_rows(g, idx, row_state, p_rows, cfg)
    np.testing.assert_allclose(np.asarray(p_rows), np.asarray(p_dense),
                               rtol=1e-5, atol=1e-6)


def test_row_adam_only_touches_selected_rows():
    cfg = AdamConfig()
    table = jnp.ones((8, 3))
    state = adam_init(table, per_row=True)
    idx = jnp.asarray([1, 5])
    g = jnp.ones((2, 3))
    new_table, new_state = adam_update_rows(g, idx, state, table, cfg)
    touched = np.asarray(new_table) != 1.0
    assert touched[1].all() and touched[5].all()
    assert not touched[[0, 2, 3, 4, 6, 7]].any()
    np.testing.assert_array_equal(np.asarray(new_state.t), [0, 1, 0, 0, 0, 1, 0, 0])


def test_row_adam_bias_correction_is_per_row():
    """A row selected for the first time at t=100 must get the same step as a
    row selected for the first time at t=1 (per-row timesteps)."""
    cfg = AdamConfig(lr=0.1)
    table = jnp.zeros((2, 2))
    state = adam_init(table, per_row=True)
    g = jnp.full((1, 2), 2.0)
    # row 0 updated 3 times; row 1 never
    t0 = table
    for _ in range(3):
        t0, state = adam_update_rows(g, jnp.asarray([0]), state, t0, cfg)
    # now row 1's first update: step size must equal row 0's first update
    t1, state = adam_update_rows(g, jnp.asarray([1]), state, t0, cfg)
    first_step_row1 = abs(float(t1[1, 0]) - 0.0)
    # row 0's very first update moved it by lr * 1 (bias-corrected full step)
    assert first_step_row1 == pytest.approx(cfg.lr, rel=1e-4)


def test_adam_converges_on_quadratic():
    cfg = AdamConfig(lr=0.05, beta1=0.9, beta2=0.999)
    target = jnp.asarray([1.0, -2.0, 0.5])
    p = jnp.zeros(3)
    state = adam_init(p)
    for _ in range(500):
        g = 2 * (p - target)
        p, state = adam_update(g, state, p, cfg)
    np.testing.assert_allclose(np.asarray(p), np.asarray(target), atol=1e-2)


def test_sgd_update():
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    out = sgd_update(g, p, lr=0.1)
    np.testing.assert_allclose(np.asarray(out["w"]), [0.95, 2.05], rtol=1e-6)
