"""Fault-injection subsystem: off-path bit-parity, degradation semantics,
verified crash-resume, and containment of serving-side hook failures.

The hard contracts (docs/FAULT_MODEL.md):

  * FAULTS-OFF PARITY — ``faults=None`` and ``FaultConfig(enabled=False)``
    produce BIT-identical trajectories for every backend x codec: the
    fault machinery is gated at Python/trace time and adds zero ops when
    off (the obs-layer discipline, reapplied).
  * DETERMINISTIC SCHEDULES — the fault stream is pre-sampled from
    ``(config, seed)`` on its own seed stream; two builds agree exactly,
    and the in-state counters match the schedule's own sums.
  * DEGRADATION IS EXACT — dropped clients are no-op rows (survivor
    renormalization), corrupted rows are checksum-rejected into the
    error-feedback residual, and both leave the surviving math untouched.
  * CRASH-RESUME PARITY — crash at round t + resume from the newest
    verified checkpoint == the uninterrupted run, bitwise, including the
    fault counters; corrupt checkpoints are skipped by hash verification.
  * CONTAINMENT — a raising snapshot hook never aborts training.
"""
import os
import pathlib
import subprocess
import sys
from dataclasses import replace

import numpy as np
import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
SRC = str(REPO_ROOT / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.compress import (  # noqa: E402
    CHECKSUM_BYTES_PER_ROW, CodecConfig, direction_configs, encode,
    row_checksums, verify_rows, wire_bytes,
)
from repro.faults import (  # noqa: E402
    FaultConfig, SimulatedCrash, build_fault_schedule, flip_row_bits,
    round_faults_xs,
)
from repro.federated.simulation import (  # noqa: E402
    FLSimConfig, run_fcf_simulation,
)
from repro.launch.mesh import fake_cpu_devices_env  # noqa: E402

BACKENDS = ("scan", "python", "async")
CODECS = ("fp32", "int8", "topk")


def _mini_data(seed=0, users=60, items=80):
    rng = np.random.default_rng(seed)
    train = (rng.random((users, items)) < 0.15).astype(np.float32)
    test = (rng.random((users, items)) < 0.05).astype(np.float32)
    return train, test


def _cfg(backend, **kw):
    base = dict(strategy="bts", keep_fraction=0.25, rounds=6, theta=10,
                eval_every=3, eval_users=40, seed=0, codec="int8",
                record_selections=True)
    if backend == "async":
        base["max_staleness"] = 2
    base["backend"] = backend
    base.update(kw)
    return FLSimConfig(**base)


def _assert_bitwise(tag, a, b):
    np.testing.assert_array_equal(a.selections, b.selections,
                                  err_msg=f"{tag}: selections")
    np.testing.assert_array_equal(a.rewards, b.rewards,
                                  err_msg=f"{tag}: rewards")
    np.testing.assert_array_equal(np.asarray(a.server_state.q),
                                  np.asarray(b.server_state.q),
                                  err_msg=f"{tag}: Q")
    np.testing.assert_array_equal(np.asarray(a.server_state.opt.m),
                                  np.asarray(b.server_state.opt.m),
                                  err_msg=f"{tag}: adam m")
    assert float(a.server_state.bytes_up) == \
        float(b.server_state.bytes_up), f"{tag}: bytes_up"
    assert a.history.series("f1") == b.history.series("f1"), \
        f"{tag}: f1 trajectory"


def _assert_states_bitwise(tag, sa, sb):
    """Final ServerState parity incl. fault counters (crash-resume)."""
    np.testing.assert_array_equal(np.asarray(sa.q), np.asarray(sb.q),
                                  err_msg=f"{tag}: Q")
    np.testing.assert_array_equal(np.asarray(sa.opt.m),
                                  np.asarray(sb.opt.m),
                                  err_msg=f"{tag}: adam m")
    np.testing.assert_array_equal(np.asarray(sa.opt.v),
                                  np.asarray(sb.opt.v),
                                  err_msg=f"{tag}: adam v")
    assert float(sa.bytes_up) == float(sb.bytes_up), f"{tag}: bytes_up"
    for field in ("dropped", "stragglers", "corrupt_rows",
                  "retransmit_bytes"):
        assert float(getattr(sa.faults, field)) == \
            float(getattr(sb.faults, field)), f"{tag}: faults.{field}"


# --------------------------------------------------------------------- #
# config validation + composition limits
# --------------------------------------------------------------------- #
def test_fault_config_validation():
    FaultConfig(enabled=True, dropout_rate=0.3, straggler_rate=0.2,
                corrupt_rate=0.1, crash_round=5).validate()
    with pytest.raises(ValueError, match="dropout_rate"):
        FaultConfig(enabled=True, dropout_rate=1.0).validate()
    with pytest.raises(ValueError, match="straggler"):
        FaultConfig(enabled=True, dropout_rate=0.6,
                    straggler_rate=0.5).validate()
    with pytest.raises(ValueError, match="crash_round"):
        FaultConfig(enabled=True, crash_round=0).validate()


def test_seed_sweep_rejects_enabled_faults():
    from repro.federated.simulation import run_seed_sweep

    train, test = _mini_data()
    cfg = _cfg("scan", faults=FaultConfig(enabled=True, dropout_rate=0.1))
    with pytest.raises(ValueError, match="faults"):
        run_seed_sweep(train, test, cfg, seeds=(0, 1))


def test_faults_and_obs_are_mutually_exclusive():
    from repro.obs import ObsConfig

    train, test = _mini_data()
    cfg = _cfg("scan", faults=FaultConfig(enabled=True, dropout_rate=0.1),
               obs=ObsConfig(enabled=True))
    with pytest.raises(ValueError, match="faults"):
        run_fcf_simulation(train, test, cfg)


# --------------------------------------------------------------------- #
# deterministic pre-sampled schedule
# --------------------------------------------------------------------- #
def test_schedule_deterministic_and_banded():
    cfg = FaultConfig(enabled=True, dropout_rate=0.25, straggler_rate=0.15,
                      corrupt_rate=0.1, seed=3)
    a = build_fault_schedule(cfg, rounds=50, cohort_size=12, num_select=20,
                             seed=7)
    b = build_fault_schedule(cfg, rounds=50, cohort_size=12, num_select=20,
                             seed=7)
    np.testing.assert_array_equal(a.survivors, b.survivors)
    np.testing.assert_array_equal(a.corrupt, b.corrupt)
    # one uniform draw partitioned into bands: a slot is dropped OR a
    # straggler, never both, and survivors is exactly the complement
    assert np.all(a.dropped + a.stragglers
                  == 12 - a.survivors.sum(axis=1))
    removed = 1.0 - a.survivors.mean()
    assert abs(removed - 0.4) < 0.05
    # a different fault seed reshuffles the stream
    c = build_fault_schedule(cfg._replace(seed=4), rounds=50,
                             cohort_size=12, num_select=20, seed=7)
    assert not np.array_equal(a.survivors, c.survivors)


def test_schedule_corrupt_gating_and_xs_padding():
    cfg = FaultConfig(enabled=True, dropout_rate=0.2, seed=0)
    sched = build_fault_schedule(cfg, rounds=10, cohort_size=5,
                                 num_select=8, seed=0)
    assert sched.corrupt is None          # corrupt_rate=0: no draw at all
    rf = round_faults_xs(sched, 2, 7, pad_to=8)
    assert rf.survivors.shape == (5, 8)
    # padding slots are dead weight, never counted as survivors
    np.testing.assert_array_equal(np.asarray(rf.survivors[:, 5:]), 0.0)
    assert isinstance(rf.corrupt, tuple) and rf.corrupt == ()


# --------------------------------------------------------------------- #
# faults-off bit-parity: every backend x codec
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("codec", CODECS)
def test_disabled_faults_is_bit_identical(backend, codec):
    """faults=None and FaultConfig(enabled=False) add zero ops."""
    train, test = _mini_data()
    cfg = _cfg(backend, codec=codec)
    base = run_fcf_simulation(train, test, cfg)
    off = run_fcf_simulation(
        train, test, replace(cfg, faults=FaultConfig(enabled=False,
                                                     dropout_rate=0.5)))
    _assert_bitwise(f"{backend}/{codec}/disabled", base, off)


# --------------------------------------------------------------------- #
# dropout: survivors renormalized, dropped slots exact no-ops
# --------------------------------------------------------------------- #
def test_dropout_counters_match_schedule_and_backends_agree():
    train, test = _mini_data()
    faults = FaultConfig(enabled=True, dropout_rate=0.3,
                         straggler_rate=0.1, seed=0)
    cfg = _cfg("scan", rounds=8, faults=faults)
    res = run_fcf_simulation(train, test, cfg)
    sched = build_fault_schedule(faults, cfg.rounds, min(cfg.theta,
                                                         train.shape[0]),
                                 num_select=20, seed=cfg.seed)
    assert float(res.server_state.faults.dropped) == sched.dropped.sum()
    assert float(res.server_state.faults.stragglers) == \
        sched.stragglers.sum()
    # python engine agrees bitwise with the compiled scan
    py = run_fcf_simulation(train, test, replace(cfg, backend="python"))
    _assert_bitwise("scan-vs-python/faulted", res, py)
    # and the degraded trajectory genuinely differs from the clean one
    clean = run_fcf_simulation(train, test, replace(cfg, faults=None))
    assert not np.array_equal(np.asarray(res.server_state.q),
                              np.asarray(clean.server_state.q))


def test_async_engine_runs_faulted():
    train, test = _mini_data()
    cfg = _cfg("async", rounds=8,
               faults=FaultConfig(enabled=True, dropout_rate=0.3, seed=0))
    res = run_fcf_simulation(train, test, cfg)
    assert float(res.server_state.faults.dropped) > 0
    assert np.isfinite(np.asarray(res.server_state.q)).all()
    # deterministic: same config, same trajectory
    again = run_fcf_simulation(train, test, cfg)
    _assert_bitwise("async/faulted-repro", res, again)


# --------------------------------------------------------------------- #
# corruption: checksums detect, rejects count, residual retransmits
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("codec", CODECS)
def test_checksum_detects_single_word_flips(codec):
    _, up_cfg = direction_configs(CodecConfig(name=codec))
    rng = np.random.default_rng(0)
    rows = jnp.asarray(rng.standard_normal((6, 16)), jnp.float32)
    wire = encode(up_cfg, rows)
    sums = row_checksums(wire)
    # clean wire verifies
    np.testing.assert_array_equal(np.asarray(verify_rows(wire, sums)),
                                  True)
    # flipping one word in rows {1, 4} is detected exactly there
    corrupt = jnp.asarray([False, True, False, False, True, False])
    received = flip_row_bits(wire, corrupt)
    np.testing.assert_array_equal(np.asarray(verify_rows(received, sums)),
                                  ~np.asarray(corrupt))


@pytest.mark.parametrize("codec", ("fp32", "int8"))
def test_corruption_rejects_and_prices_retransmits(codec):
    train, test = _mini_data()
    faults = FaultConfig(enabled=True, corrupt_rate=0.15, seed=0)
    cfg = _cfg("scan", rounds=8, codec=codec, faults=faults)
    res = run_fcf_simulation(train, test, cfg)
    num_select = 20           # keep_fraction 0.25 of 80 items
    sched = build_fault_schedule(faults, cfg.rounds, min(cfg.theta,
                                                         train.shape[0]),
                                 num_select=num_select, seed=cfg.seed)
    expected_rejects = float(sched.corrupt.sum())
    assert expected_rejects > 0, "schedule drew no corruption at this seed"
    assert float(res.server_state.faults.corrupt_rows) == expected_rejects
    # retransmit bytes price each rejected row at wire + checksum width
    _, up_cfg = direction_configs(CodecConfig(name=codec))
    per_row = wire_bytes(up_cfg, 1, cfg.num_factors) + CHECKSUM_BYTES_PER_ROW
    assert float(res.server_state.faults.retransmit_bytes) == \
        expected_rejects * per_row
    # the uplink carries the checksum overhead vs the clean run
    clean = run_fcf_simulation(train, test, replace(cfg, faults=None))
    assert res.bytes_up > clean.bytes_up
    # rejected updates really were withheld: trajectories diverge
    assert not np.array_equal(np.asarray(res.server_state.q),
                              np.asarray(clean.server_state.q))


def test_corruption_vmap_safe():
    """Checksum + flip kernels vmap cleanly (batched fault xs)."""
    _, up_cfg = direction_configs(CodecConfig(name="int8"))
    rng = np.random.default_rng(1)
    rows = jnp.asarray(rng.standard_normal((3, 5, 8)), jnp.float32)
    corrupt = jnp.asarray(rng.random((3, 5)) < 0.4)
    wires = jax.vmap(lambda r: encode(up_cfg, r))(rows)
    sums = jax.vmap(row_checksums)(wires)
    flipped = jax.vmap(flip_row_bits)(wires, corrupt)
    ok = jax.vmap(verify_rows)(flipped, sums)
    np.testing.assert_array_equal(np.asarray(ok), ~np.asarray(corrupt))


# --------------------------------------------------------------------- #
# verified crash-resume
# --------------------------------------------------------------------- #
def _resume_cfg(backend, ckpt_dir=None, crash=None, resume=None):
    faults = FaultConfig(enabled=True, dropout_rate=0.1, seed=0,
                         crash_round=crash)
    return _cfg(backend, rounds=9, eval_every=3, faults=faults,
                checkpoint_dir=ckpt_dir, resume_from=resume)


@pytest.mark.parametrize("backend", ("scan", "async"))
def test_crash_resume_bit_parity(backend, tmp_path):
    """crash at round t + resume == uninterrupted, bitwise."""
    train, test = _mini_data()
    uninterrupted = run_fcf_simulation(train, test, _resume_cfg(backend))
    d = str(tmp_path / "ckpt")
    with pytest.raises(SimulatedCrash) as exc:
        run_fcf_simulation(train, test,
                           _resume_cfg(backend, ckpt_dir=d, crash=5))
    assert exc.value.round_ == 5
    resumed = run_fcf_simulation(
        train, test, _resume_cfg(backend, ckpt_dir=d, resume=d))
    _assert_states_bitwise(f"{backend}/resume",
                           uninterrupted.server_state,
                           resumed.server_state)
    # the resumed history covers only post-crash evals, at matching values
    assert uninterrupted.history.series("f1")[1:] == \
        resumed.history.series("f1")


def test_resume_skips_corrupt_checkpoint(tmp_path):
    """A checkpoint torn by the crash is hash-rejected during discovery;
    resume walks back to the newest verified one and still reaches the
    uninterrupted trajectory bitwise."""
    train, test = _mini_data()
    uninterrupted = run_fcf_simulation(train, test, _resume_cfg("scan"))
    d = str(tmp_path / "ckpt")
    with pytest.raises(SimulatedCrash):
        run_fcf_simulation(train, test,
                           _resume_cfg("scan", ckpt_dir=d, crash=8))
    # corrupt the newest checkpoint (round 6); round 3 stays intact
    newest = os.path.join(d, "ckpt_00000006.npz")
    assert os.path.exists(newest)
    with open(newest, "r+b") as f:
        f.seek(64)
        byte = f.read(1)
        f.seek(64)
        f.write(bytes([byte[0] ^ 0xFF]))
    resumed = run_fcf_simulation(
        train, test, _resume_cfg("scan", ckpt_dir=d, resume=d))
    _assert_states_bitwise("resume-past-corruption",
                           uninterrupted.server_state,
                           resumed.server_state)


def test_resume_from_empty_dir_fails_loudly(tmp_path):
    train, test = _mini_data()
    d = str(tmp_path / "nothing")
    os.makedirs(d)
    with pytest.raises(FileNotFoundError, match="no verified checkpoint"):
        run_fcf_simulation(train, test, _resume_cfg("scan", resume=d))


def test_python_backend_crash_resume(tmp_path):
    train, test = _mini_data()
    uninterrupted = run_fcf_simulation(train, test, _resume_cfg("python"))
    d = str(tmp_path / "ckpt")
    with pytest.raises(SimulatedCrash):
        run_fcf_simulation(train, test,
                           _resume_cfg("python", ckpt_dir=d, crash=5))
    resumed = run_fcf_simulation(
        train, test, _resume_cfg("python", ckpt_dir=d, resume=d))
    _assert_states_bitwise("python/resume", uninterrupted.server_state,
                           resumed.server_state)


# --------------------------------------------------------------------- #
# snapshot-hook containment
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ("scan", "python"))
def test_raising_snapshot_hook_never_aborts_training(backend):
    train, test = _mini_data()
    cfg = _cfg(backend)
    base = run_fcf_simulation(train, test, cfg)

    calls = []

    def exploding_hook(round_, state):
        calls.append(round_)
        raise RuntimeError("simulated publish failure")

    res = run_fcf_simulation(train, test,
                             replace(cfg, snapshot_hook=exploding_hook))
    assert calls == [3, 6]                # every eval boundary still fired
    assert res.hook_failures == 2
    assert base.hook_failures == 0
    _assert_bitwise(f"{backend}/hook-containment", base, res)


# --------------------------------------------------------------------- #
# D=8 sharded engine (fake-device subprocess, one jax init)
# --------------------------------------------------------------------- #
_SHARD_SCRIPT = r"""
from dataclasses import replace
import numpy as np
from repro.faults import FaultConfig
from repro.federated.simulation import FLSimConfig, run_fcf_simulation

rng = np.random.default_rng(0)
train = (rng.random((60, 80)) < 0.15).astype(np.float32)
test = (rng.random((60, 80)) < 0.05).astype(np.float32)

shard = FLSimConfig(strategy="bts", keep_fraction=0.25, rounds=6, theta=10,
                    eval_every=3, eval_users=40, seed=0, codec="int8",
                    record_selections=True, backend="shard", mesh_shards=8)

# faults-off parity: enabled=False is bit-identical to no faults at all
base = run_fcf_simulation(train, test, shard)
off = run_fcf_simulation(
    train, test, replace(shard, faults=FaultConfig(enabled=False)))
np.testing.assert_array_equal(base.selections, off.selections)
np.testing.assert_array_equal(np.asarray(base.server_state.q),
                              np.asarray(off.server_state.q))
assert base.history.series("f1") == off.history.series("f1")

# faulted parity: D=8 mesh == 8-way blocked scan, bitwise, counters incl.
faults = FaultConfig(enabled=True, dropout_rate=0.3, corrupt_rate=0.1,
                     seed=0)
fs = run_fcf_simulation(train, test, replace(shard, faults=faults))
ref = run_fcf_simulation(
    train, test, replace(shard, backend="scan", mesh_shards=None,
                         cohort_shards=8, faults=faults))
np.testing.assert_array_equal(np.asarray(fs.server_state.q),
                              np.asarray(ref.server_state.q))
for field in ("dropped", "stragglers", "corrupt_rows", "retransmit_bytes"):
    a = float(getattr(fs.server_state.faults, field))
    b = float(getattr(ref.server_state.faults, field))
    assert a == b, (field, a, b)
assert float(fs.server_state.faults.dropped) > 0
assert float(fs.server_state.faults.corrupt_rows) > 0
assert fs.history.series("f1") == ref.history.series("f1")

print("SHARD_FAULTS_OK")
"""


@pytest.mark.subprocess
def test_shard_backend_fault_parity():
    """D=8 sharded engine: faults-off parity AND the faulted trajectory
    bit-matches the 8-way blocked scan reference, fault counters included
    (corruption math is replicated, so intact masks agree across shards)."""
    env = fake_cpu_devices_env(8)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT],
        capture_output=True, text=True, env=env, cwd=str(REPO_ROOT),
        timeout=1200,
    )
    assert proc.returncode == 0, (
        f"shard faults subprocess failed\nstdout:\n{proc.stdout}\n"
        f"stderr:\n{proc.stderr}")
    assert "SHARD_FAULTS_OK" in proc.stdout
