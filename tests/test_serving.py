"""Serving read path: fused dequant->score->top-N kernel parity, the
decode-free block-scoring contract, snapshot publish/swap, chunked-eval
bit-parity, and the request-batching layer.

Parity tiers mirror the repo's kernel contract: fp32/fp16/int8 (and the
chunked ref for every codec) are BIT-EXACT against the naive dense path;
int4 is bit-exact in interpret mode and documented-ulp on hardware; topk
has no kernel and always routes through the chunked ref.
"""
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.compress import (
    CodecConfig, decode, decode_row_block, encode, slice_rows,
    wire_resident_bytes,
)
from repro.kernels import payload_score as ps_mod
from repro.kernels import ref

RNG = np.random.default_rng(7)

ALL_CODECS = ("fp32", "fp16", "int8", "int4", "topk")
KERNEL_CODECS = ("fp32", "fp16", "int8", "int4")   # have a Pallas path


def _wire(codec, m, k, seed=0):
    cfg = CodecConfig(name=codec)
    q = jnp.asarray(np.random.default_rng(seed).standard_normal((m, k)),
                    jnp.float32)
    return cfg, q, encode(cfg, q)


def _dense_topn(cfg, wire, p, k, n, mask=None):
    """The naive oracle: full decode, full (B, M) scores, one top_k."""
    s = p @ decode(cfg, wire, k).T
    if mask is not None:
        s = jnp.where(mask > 0, ref.NEG_INF, s)
    return jax.lax.top_k(s, n)


# --------------------------------------------------------------------- #
# compress: the decode-free block-scoring contract
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("codec", ALL_CODECS)
def test_decode_row_block_bitwise(codec):
    cfg, q, wire = _wire(codec, 157, 25)
    full = decode(cfg, wire, 25)
    for start, size in ((0, 64), (64, 64), (128, 29), (37, 100)):
        blk = decode_row_block(cfg, wire, 25, start, size)
        assert blk.shape == (size, 25)
        np.testing.assert_array_equal(np.asarray(blk),
                                      np.asarray(full[start:start + size]))


def test_slice_rows_slices_every_leaf():
    cfg, q, wire = _wire("topk", 64, 24)
    part = slice_rows(wire, 16, 8)
    for leaf, full in zip(jax.tree.leaves(part), jax.tree.leaves(wire)):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.asarray(full[16:24]))


def test_wire_resident_bytes_orders_codecs():
    sizes = {}
    for codec in KERNEL_CODECS:
        _, _, wire = _wire(codec, 256, 24)
        sizes[codec] = wire_resident_bytes(wire)
    assert sizes["fp32"] > sizes["fp16"] > sizes["int8"] > sizes["int4"]
    assert sizes["fp32"] == 256 * 24 * 4


# --------------------------------------------------------------------- #
# chunked ref vs the naive dense path: bit-exact for EVERY codec
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("codec", ALL_CODECS)
@pytest.mark.parametrize("block_m", [64, 300, 1000])
def test_wire_topn_ref_bit_exact(codec, block_m):
    cfg, q, wire = _wire(codec, 700, 25, seed=1)
    p = jnp.asarray(RNG.standard_normal((9, 25)), jnp.float32)
    mask = jnp.asarray((RNG.random((9, 700)) < 0.1).astype(np.float32))
    for m_ in (None, mask):
        want_v, want_i = _dense_topn(cfg, wire, p, 25, 10, m_)
        got_v, got_i = ref.wire_topn_ref(cfg, wire, p, 25, 10,
                                         train_mask=m_, block_m=block_m)
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


# --------------------------------------------------------------------- #
# Pallas kernels (interpret mode) vs ref: bit-exact, fp32/fp16/int8;
# int4 is also exact in interpret mode (documented-ulp on real TPUs)
# --------------------------------------------------------------------- #
def _kernel_topn(codec, wire, p, k, n, mask, block_m):
    if codec in ("fp32", "fp16"):
        return ps_mod.dense_topn(p, wire.values, n, mask,
                                 block_m=block_m, interpret=True)
    if codec == "int8":
        return ps_mod.quant_topn(p, wire.values, wire.scales, n, mask,
                                 block_m=block_m, interpret=True)
    return ps_mod.quant4_topn(p, wire.values, wire.scales, k, n, mask,
                              block_m=block_m, interpret=True)


@pytest.mark.parametrize("codec", KERNEL_CODECS)
@pytest.mark.parametrize("m,block_m", [(512, 128), (700, 256), (97, 128)])
def test_payload_score_kernel_matches_ref(codec, m, block_m):
    cfg, q, wire = _wire(codec, m, 25, seed=2)
    p = jnp.asarray(RNG.standard_normal((7, 25)), jnp.float32)
    mask = jnp.asarray((RNG.random((7, m)) < 0.15).astype(np.float32))
    for m_ in (None, mask):
        want_v, want_i = ref.wire_topn_ref(cfg, wire, p, 25, 10,
                                           train_mask=m_, block_m=block_m)
        got_v, got_i = _kernel_topn(codec, wire, p, 25, 10, m_, block_m)
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


def test_topn_tie_break_is_lowest_index_first():
    # constant scores: every item ties, top-N must be ids 0..N-1 in order —
    # lax.top_k's documented stable rule, reproduced by the kernel merge
    q = jnp.ones((90, 8), jnp.float32)
    p = jnp.ones((4, 8), jnp.float32)
    v, i = ps_mod.dense_topn(p, q, 6, block_m=32, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(i), np.tile(np.arange(6), (4, 1)))
    # ties split across block boundaries resolve identically at any block
    v2, i2 = ps_mod.dense_topn(p, q, 6, block_m=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i2))


def test_train_mask_excludes_interacted_items():
    cfg, q, wire = _wire("int8", 200, 16, seed=3)
    p = jnp.asarray(RNG.standard_normal((5, 16)), jnp.float32)
    mask = np.zeros((5, 200), np.float32)
    banned = RNG.choice(200, size=(5, 40), replace=False)
    for u in range(5):
        mask[u, banned[u]] = 1.0
    _, idx = ps_mod.quant_topn(p, wire.values, wire.scales, 10,
                               jnp.asarray(mask), block_m=64, interpret=True)
    idx = np.asarray(idx)
    for u in range(5):
        assert not set(idx[u]) & set(banned[u]), "masked item recommended"


def test_mask_beats_padding_degenerate_all_masked():
    # every item masked: results fall back to the NEG_INF-sentinel ranking
    # (ties -> lowest ids), identical to the dense oracle's behaviour
    cfg, q, wire = _wire("fp32", 70, 8, seed=4)
    p = jnp.asarray(RNG.standard_normal((3, 8)), jnp.float32)
    mask = jnp.ones((3, 70), jnp.float32)
    want_v, want_i = _dense_topn(cfg, wire, p, 8, 5, mask)
    got_v, got_i = ps_mod.dense_topn(p, wire.values, 5, mask,
                                     block_m=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


# --------------------------------------------------------------------- #
# ops dispatch + chunked eval bit-parity
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("codec", ALL_CODECS)
def test_ops_wire_topn_dispatch(codec):
    from repro.kernels import wire_topn

    cfg, q, wire = _wire(codec, 300, 25, seed=5)
    p = jnp.asarray(RNG.standard_normal((4, 25)), jnp.float32)
    want_v, want_i = _dense_topn(cfg, wire, p, 25, 10)
    got_v, got_i = wire_topn(cfg, wire, p, 25, 10, block_m=128)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


def test_evaluate_users_item_chunk_bit_parity():
    from repro.cf.metrics import evaluate_users

    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.standard_normal((230, 12)), jnp.float32)
    train = jnp.asarray((rng.random((40, 230)) < 0.2).astype(np.float32))
    test = jnp.asarray((rng.random((40, 230)) < 0.05).astype(np.float32))
    dense = evaluate_users(q, train, test)
    for chunk in (64, 128, 512):
        chunked = evaluate_users(q, train, test, item_chunk=chunk)
        for k in ("precision", "recall", "f1", "map"):
            assert float(getattr(dense, k)) == float(getattr(chunked, k)), \
                f"{k} diverged at item_chunk={chunk}"


def test_simulation_eval_reroute_matches_dense():
    from dataclasses import replace

    from repro.data.synthetic import load_dataset
    from repro.federated.simulation import FLSimConfig, run_fcf_simulation

    spec, train, test = load_dataset("movielens-mini", seed=0)
    base = FLSimConfig(rounds=10, eval_every=5, theta=30, eval_users=48,
                       seed=0)
    res_dense = run_fcf_simulation(train, test, base)
    res_fused = run_fcf_simulation(
        train, test, replace(base, eval_user_chunk=16, eval_item_chunk=100))
    for k in ("precision", "recall", "f1", "map"):
        # rankings are identical (see the bit-parity test above); the only
        # slack is user-chunked mean accumulation order, ~1e-10 — a real
        # top-10 swap would move these by >= 1e-3
        assert res_dense.final[k] == pytest.approx(res_fused.final[k],
                                                   abs=1e-8), k


# --------------------------------------------------------------------- #
# serving model + engine
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("codec", KERNEL_CODECS)
def test_install_rows_equals_reencode(codec):
    from repro.serve import ServingModel

    cfg = CodecConfig(name=codec)
    rng = np.random.default_rng(13)
    q = jnp.asarray(rng.standard_normal((120, 17)), jnp.float32)
    rows = jnp.asarray(rng.standard_normal((9, 17)), jnp.float32)
    idx = jnp.asarray(rng.choice(120, size=9, replace=False), jnp.int32)

    model = ServingModel.from_dense(cfg, q)
    patched = model.install_rows(idx, encode(model.cfg, rows))
    want = ServingModel.from_dense(cfg, q.at[idx].set(rows))
    for a, b in zip(jax.tree.leaves(patched.wire),
                    jax.tree.leaves(want.wire)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert patched.version == model.version + 1


def test_snapshot_install_from_async_ring_no_fp32_roundtrip():
    """End to end: async training publishes encoded ring snapshots into the
    engine; the installed rows are the ring's wire bits verbatim (never a
    decoded fp32 Q*), and they match the server's own Q on those rows after
    its own decode — the shared-wire-format contract."""
    from repro.cf.server import latest_snapshot
    from repro.data.synthetic import load_dataset
    from repro.federated.simulation import FLSimConfig, run_fcf_simulation
    from repro.serve import ServingEngine, ServingModel

    spec, train, test = load_dataset("movielens-mini", seed=0)
    m = train.shape[1]
    engine = ServingEngine(
        ServingModel.from_dense(CodecConfig(name="int8"),
                                jnp.zeros((m, 25), jnp.float32)),
        buckets=(4,), top_n=5, block_m=128)
    cfg = FLSimConfig(rounds=6, eval_every=3, theta=32, backend="async",
                      max_staleness=2, codec="int8", eval_users=32, seed=0,
                      snapshot_hook=engine.publisher())
    result = run_fcf_simulation(train, test, cfg)

    stats = engine.stats()
    assert stats.installs == 2 and stats.version >= 2
    # the wire never left int8: codes int8, scales f32, nothing else
    leaves = jax.tree.leaves(engine.model.wire)
    assert sorted(str(a.dtype) for a in leaves) == ["float32", "int8"]

    # installed rows == the ring's freshest wire image, bit for bit
    snap = latest_snapshot(result.server_state)
    got = jax.tree.map(lambda leaf: leaf[np.asarray(snap.indices)],
                       engine.model.wire)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(snap.wire)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and a recommendation comes back well-formed off that model
    p = jnp.asarray(np.random.default_rng(3).standard_normal((3, 25)),
                    jnp.float32)
    vals, idx = engine.recommend(p)
    assert vals.shape == (3, 5) and idx.shape == (3, 5)
    assert np.all(np.diff(np.asarray(vals), axis=1) <= 0)   # sorted


def test_engine_swap_atomicity_under_concurrent_reads():
    """Readers racing a publisher must each see ONE model end to end:
    every result is consistent with some published version, and versions
    advance monotonically."""
    from repro.serve import ServingEngine, ServingModel

    cfg = CodecConfig(name="int8")
    rng = np.random.default_rng(17)
    tables = [jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
              for _ in range(8)]
    models = [ServingModel.from_dense(cfg, t, version=i)
              for i, t in enumerate(tables)]
    engine = ServingEngine(models[0], buckets=(4,), top_n=3, block_m=32)
    p = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    # expected results under each version, computed single-threaded
    expected = {m.version: np.asarray(m.topn(p, 3, block_m=32)[1])
                for m in models}

    errors = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            model = engine.model            # the same view recommend() takes
            got = np.asarray(engine.recommend(p)[1])
            want = expected[engine.model.version]
            # got must equal SOME published version's result (no torn mix)
            if not any(np.array_equal(got, e) for e in expected.values()):
                errors.append("result matches no published model")
        del model, want

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    seen_versions = [engine.stats().version]
    for m in models[1:]:
        engine.swap(m)
        seen_versions.append(engine.stats().version)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors[0]
    assert seen_versions == sorted(seen_versions)
    assert engine.stats().installs == len(models) - 1


def test_engine_bucket_padding_and_chunking():
    from repro.serve import ServingEngine, ServingModel

    cfg = CodecConfig(name="fp16")
    q = jnp.asarray(RNG.standard_normal((150, 10)), jnp.float32)
    model = ServingModel.from_dense(cfg, q)
    engine = ServingEngine(model, buckets=(4, 16), top_n=4, block_m=64)
    for b in (1, 3, 4, 9, 16, 37):      # pad-up and chunk-over cases
        p = jnp.asarray(RNG.standard_normal((b, 10)), jnp.float32)
        v, i = engine.recommend(p)
        assert v.shape == (b, 4) and i.shape == (b, 4)
        want_v, want_i = model.topn(p, 4, block_m=64)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(want_v))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(want_i))


# --------------------------------------------------------------------- #
# examples stay under the dry-run smoke suite
# --------------------------------------------------------------------- #
def test_example_serve_recs_dry_run(capsys):
    import pathlib
    import sys

    repo = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(repo / "examples"))
    try:
        import serve_recs as example
        out = example.main(["--dry-run"])
    finally:
        sys.path.pop(0)
        sys.modules.pop("serve_recs", None)
    assert out["users_per_sec"] > 0
    assert out["model_version"] >= 2          # snapshots actually published
    assert "users/s" in capsys.readouterr().out
