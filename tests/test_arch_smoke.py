"""Per-architecture smoke tests (assignment deliverable f): for each of the
10 assigned architectures, instantiate a REDUCED variant of the same family
(2 layers — or one pattern period — d_model<=512, <=4 experts) and run one
forward/train step plus a prefill+decode step on CPU, asserting output
shapes and the absence of NaNs. The FULL configs are exercised via the
dry-run only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_CONFIGS, get_config
from repro.models.lm import (
    decode_step, init_decode_cache, init_train_state, lm_loss, prefill_step,
    train_step,
)

ARCHS = sorted(ARCH_CONFIGS)
BATCH, SEQ = 2, 16


def _batch(cfg, rng):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (BATCH, SEQ + 1)), jnp.int32)}
    if cfg.modality == "vision":
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((BATCH, cfg.frontend_seq, cfg.d_model)),
            jnp.float32)
    if cfg.is_enc_dec:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((BATCH, cfg.frontend_seq, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_is_within_limits(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 8           # one pattern period for xlstm
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(0)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)

    loss0 = lm_loss(state.params, cfg, batch)
    assert np.isfinite(float(loss0)), f"{arch}: non-finite initial loss"
    # untrained loss should be near ln(V)
    assert abs(float(loss0) - np.log(cfg.vocab_size)) < 2.0

    new_state, loss = jax.jit(train_step, static_argnames=("cfg",))(
        state, batch, cfg)
    assert np.isfinite(float(loss))
    assert int(new_state.step) == 1
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(1)
    params = init_train_state(cfg, jax.random.PRNGKey(1)).params
    batch = _batch(cfg, rng)
    tokens = batch["tokens"][:, :SEQ]

    logits, prefill_cache = prefill_step(
        params, cfg, tokens,
        prefix_embeds=batch.get("prefix_embeds"),
        enc_embeds=batch.get("enc_embeds"))
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # decode continues from a fresh (buffered) cache for shape stability
    max_len = SEQ + 8
    cache = init_decode_cache(cfg, BATCH, max_len)
    enc_out = None
    if cfg.is_enc_dec:
        from repro.models.lm import encode
        enc_out = encode(params, cfg, batch["enc_embeds"])
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, 1)), jnp.int32)
    step_logits, cache = decode_step(params, cfg, cache, tok,
                                     jnp.asarray(0, jnp.int32), enc_out=enc_out)
    assert step_logits.shape == (BATCH, cfg.vocab_size)
    assert np.isfinite(np.asarray(step_logits, np.float32)).all()
    # a second step advances positions without shape changes
    step_logits2, cache = decode_step(params, cfg, cache, tok,
                                      jnp.asarray(1, jnp.int32), enc_out=enc_out)
    assert np.isfinite(np.asarray(step_logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen3-4b", "xlstm-1.3b", "recurrentgemma-2b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the training-mode logits —
    the KV-cache/recurrent-state path is numerically consistent."""
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(2)
    params = init_train_state(cfg, jax.random.PRNGKey(2)).params
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)

    from repro.models.lm import lm_forward
    full_logits, _ = lm_forward(params, cfg, toks)

    cache = init_decode_cache(cfg, 1, 16)
    got = []
    for t in range(8):
        logits, cache = decode_step(params, cfg, cache, toks[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32))
        got.append(logits)
    got = jnp.stack(got, axis=1)                     # (1, 8, V)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2)
