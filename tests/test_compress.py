"""Payload codec subsystem: round-trip properties, byte accounting, error
feedback, and scan-safety of every wire format in the registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import compress as C
from repro.compress import CodecConfig

RNG = np.random.default_rng(7)

ALL = [CodecConfig(name=n) for n in C.CODECS]


def _rows(rows=12, dim=25, scale=3.0, rng=RNG):
    return jnp.asarray(scale * rng.standard_normal((rows, dim)), jnp.float32)


# --------------------------------------------------------------------- #
# round-trip exactness / error bounds
# --------------------------------------------------------------------- #
def test_fp32_roundtrip_is_bitwise_exact():
    x = _rows()
    y = C.roundtrip(CodecConfig(name="fp32"), x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_fp16_roundtrip_error_bound():
    x = _rows()
    y = C.roundtrip(CodecConfig(name="fp16"), x)
    # half precision: ~2^-11 relative error
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("name,qmax", [("int8", 127.0), ("int4", 7.0)])
def test_uniform_quant_error_bounded_by_half_step(name, qmax):
    """|x - dec(enc(x))| <= scale/2 per element, scale = rowmax|x| / qmax."""
    x = _rows(rows=20, dim=33)          # odd dim exercises int4 packing
    y = C.roundtrip(CodecConfig(name=name), x)
    step = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True) / qmax
    err = np.abs(np.asarray(x) - np.asarray(y))
    assert (err <= step / 2 + 1e-6).all()


@pytest.mark.parametrize("name", ["int8", "int4"])
def test_quant_zero_rows_decode_to_exact_zeros(name):
    z = jnp.zeros((5, 16), jnp.float32)
    y = C.roundtrip(CodecConfig(name=name), z)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(z))


def test_int4_pack_unpack_roundtrip_all_codes():
    """Every legal nibble code survives packing, including odd dims."""
    for dim in (8, 9):
        codes = jnp.asarray(
            RNG.integers(-7, 8, size=(6, dim)).astype(np.int8))
        back = C.unpack_int4(C.pack_int4(codes), dim)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_topk_keeps_largest_entries_exactly():
    cfg = CodecConfig(name="topk", topk_fraction=0.25)
    x = _rows(rows=10, dim=40)
    y = np.asarray(C.roundtrip(cfg, x))
    xn = np.asarray(x)
    k = C.topk_k(cfg, 40)
    for r in range(xn.shape[0]):
        kept = np.argsort(-np.abs(xn[r]))[:k]
        # surviving entries are bit-exact, everything else decodes to zero
        np.testing.assert_array_equal(y[r][kept], xn[r][kept])
        assert np.count_nonzero(y[r]) <= k


@settings(deadline=None, max_examples=10)
@given(
    rows=st.integers(min_value=1, max_value=40),
    dim=st.integers(min_value=2, max_value=64),
    scale=st.floats(min_value=0.01, max_value=100.0),
)
def test_property_int8_roundtrip_bound_random_shapes(rows, dim, scale):
    rng = np.random.default_rng(rows * 1000 + dim)
    x = jnp.asarray(scale * rng.standard_normal((rows, dim)), jnp.float32)
    y = C.roundtrip(CodecConfig(name="int8"), x)
    step = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True) / 127.0
    assert (np.abs(np.asarray(x) - np.asarray(y))
            <= step / 2 + 1e-5 * scale).all()


# --------------------------------------------------------------------- #
# error feedback
# --------------------------------------------------------------------- #
def test_error_feedback_residual_mean_converges():
    """EF: transmitting a constant gradient through topk, the time-average
    of the decoded stream converges to the true gradient (the dropped mass
    is re-injected, never lost)."""
    cfg = CodecConfig(name="topk", topk_fraction=0.2)
    g = _rows(rows=6, dim=30, scale=1.0)
    res = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    errs = []
    for t in range(1, 201):
        _, dec, res = C.encode_with_residual(cfg, g, res)
        total = total + dec
        if t in (10, 200):
            errs.append(float(jnp.max(jnp.abs(total / t - g))))
    assert errs[-1] < 0.05                 # converged
    assert errs[-1] < errs[0] / 3          # and it is *converging*


def test_error_feedback_residual_stays_bounded():
    cfg = CodecConfig(name="topk", topk_fraction=0.25)
    rng = np.random.default_rng(3)
    res = jnp.zeros((4, 24))
    bound = 0.0
    for _ in range(100):
        g = jnp.asarray(rng.standard_normal((4, 24)), jnp.float32)
        _, _, res = C.encode_with_residual(cfg, g, res)
        bound = max(bound, float(jnp.max(jnp.abs(res))))
    # residual magnitude stays O(per-round gradient), does not blow up
    assert bound < 20.0


def test_without_error_feedback_mass_is_lost():
    """Control for the EF test: plain topk drops the same mass every round."""
    cfg = CodecConfig(name="topk", topk_fraction=0.2, error_feedback=False)
    assert not C.is_stateful(cfg)
    assert C.codec_state_init(cfg, 8, 30) == ()
    g = _rows(rows=6, dim=30)
    dec = C.roundtrip(cfg, g)
    # time-average of a stateless stream never recovers the small entries
    assert float(jnp.max(jnp.abs(dec - g))) > 0.01


# --------------------------------------------------------------------- #
# byte accounting — wire_bytes is the actual wire size, exactly
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("cfg", ALL, ids=[c.name for c in ALL])
@pytest.mark.parametrize("rows,dim", [(1, 1), (7, 25), (16, 33), (3, 128)])
def test_wire_bytes_equals_actual_wire_nbytes(cfg, rows, dim):
    x = _rows(rows=rows, dim=dim)
    wire = C.encode(cfg, x)
    actual = sum(np.asarray(leaf).nbytes for leaf in jax.tree.leaves(wire))
    assert C.wire_bytes(cfg, rows, dim) == actual


def test_wire_bytes_ordering():
    """Narrower formats must actually be narrower."""
    sizes = {n: C.wire_bytes(CodecConfig(name=n), 100, 64)
             for n in ("fp32", "fp16", "int8", "int4")}
    assert sizes["fp32"] > sizes["fp16"] > sizes["int8"] > sizes["int4"]


def test_payload_bytes_routes_through_dense_bytes():
    from repro.core.payload import payload_bytes
    assert payload_bytes(100, 25, dtype_bits=64) == C.dense_bytes(100, 25, 64)
    assert payload_bytes(100, 25, dtype_bits=32) \
        == C.wire_bytes(CodecConfig(name="fp32"), 100, 25)


def test_payload_selector_codec_accounting():
    from repro.core.payload import make_selector
    sel8 = make_selector("random", num_arms=100, dim=25, keep_fraction=0.1,
                         codec="int8")
    sel32 = make_selector("random", num_arms=100, dim=25, keep_fraction=0.1)
    assert sel8.round_payload_bytes \
        == C.wire_bytes(CodecConfig(name="int8"), 10, 25)
    assert sel8.round_payload_bytes < sel32.round_payload_bytes


def test_direction_configs_topk_is_uplink_only():
    down, up = C.direction_configs(CodecConfig(name="topk"))
    assert down.name == "fp32" and up.name == "topk"
    down, up = C.direction_configs(CodecConfig(name="int8"))
    assert down.name == up.name == "int8"


def test_compression_ratio_sane():
    assert C.compression_ratio(CodecConfig(name="fp32"), 10, 25) == 1.0
    assert C.compression_ratio(CodecConfig(name="int8"), 10, 25) > 3.0
    assert C.compression_ratio(CodecConfig(name="int4"), 10, 25) > 5.0


def test_unknown_codec_rejected():
    with pytest.raises(ValueError):
        C.validate_config(CodecConfig(name="zstd"))
    with pytest.raises(ValueError):
        C.wire_bytes(CodecConfig(name="zstd"), 1, 1)


# --------------------------------------------------------------------- #
# scan/jit-safety: codecs must trace with static shapes
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("cfg", ALL, ids=[c.name for c in ALL])
def test_codec_traces_inside_jit_and_scan(cfg):
    dim = 16

    def body(carry, x):
        y = C.roundtrip(cfg, x)
        return carry + jnp.sum(y), y

    xs = jnp.asarray(RNG.standard_normal((4, 5, dim)), jnp.float32)
    total, ys = jax.jit(
        lambda xs: jax.lax.scan(body, jnp.zeros(()), xs))(xs)
    assert ys.shape == xs.shape
    assert np.isfinite(float(total))
