"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles,
plus hypothesis property tests on the kernels' invariants. All Pallas
kernels run in interpret mode on CPU (the TPU target is compile-checked by
the dry-run)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import fcf_grad as fcf_mod
from repro.kernels import flash_attention as flash_mod
from repro.kernels import payload_gather as pg_mod
from repro.kernels import ref

RNG = np.random.default_rng(42)


# --------------------------------------------------------------------- #
# fcf_grad
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("m,k,b", [
    (64, 25, 8), (100, 25, 32), (300, 16, 100), (1000, 25, 64),
    (257, 8, 5),          # non-multiple of block
    (32, 128, 16),        # wide factor dim
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_fcf_grad_matches_ref(m, k, b, dtype):
    q = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    p = jnp.asarray(RNG.standard_normal((b, k)), dtype)
    x = jnp.asarray((RNG.random((b, m)) < 0.15).astype(np.float32), dtype)
    got = fcf_mod.fcf_grad(q, p, x, block_m=128, interpret=True)
    want = ref.fcf_grad_ref(q, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_fcf_grad_block_size_invariance():
    q = jnp.asarray(RNG.standard_normal((500, 25)), jnp.float32)
    p = jnp.asarray(RNG.standard_normal((40, 25)), jnp.float32)
    x = jnp.asarray((RNG.random((40, 500)) < 0.2).astype(np.float32))
    a = fcf_mod.fcf_grad(q, p, x, block_m=64, interpret=True)
    b_ = fcf_mod.fcf_grad(q, p, x, block_m=512, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5, atol=1e-5)


@settings(deadline=None, max_examples=10)
@given(
    m=st.integers(min_value=4, max_value=300),
    b=st.integers(min_value=1, max_value=48),
    alpha=st.floats(min_value=0.0, max_value=10.0),
    l2=st.floats(min_value=0.0, max_value=5.0),
)
def test_fcf_grad_property_random_shapes(m, b, alpha, l2):
    k = 16
    rng = np.random.default_rng(m * 1000 + b)
    q = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    p = jnp.asarray(rng.standard_normal((b, k)), jnp.float32)
    x = jnp.asarray((rng.random((b, m)) < 0.3).astype(np.float32))
    got = fcf_mod.fcf_grad(q, p, x, alpha=alpha, l2=l2, block_m=128,
                           interpret=True)
    want = ref.fcf_grad_ref(q, p, x, l2=l2, alpha=alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_fcf_grad_zero_interactions_is_pure_regularization():
    """x == 0 => gradient must reduce to -2*(0 - pq)p + 2*l2*B*q with c=1."""
    m, k, b = 128, 8, 4
    q = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    p = jnp.zeros((b, k), jnp.float32)       # p=0 => residual term vanishes
    x = jnp.zeros((b, m), jnp.float32)
    got = fcf_mod.fcf_grad(q, p, x, l2=1.0, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(2.0 * b * q),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# payload gather / scatter-add
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("m,k,ms", [(100, 16, 10), (500, 25, 50),
                                    (1000, 128, 100), (64, 8, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_rows_sweep(m, k, ms, dtype):
    table = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    idx = jnp.asarray(RNG.choice(m, ms, replace=False).astype(np.int32))
    got = pg_mod.gather_rows(table, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.gather_rows_ref(table, idx)))


@pytest.mark.parametrize("m,k,ms", [(100, 16, 10), (500, 25, 50), (64, 8, 64)])
def test_scatter_add_rows_sweep(m, k, ms):
    table = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    idx = jnp.asarray(RNG.choice(m, ms, replace=False).astype(np.int32))
    rows = jnp.asarray(RNG.standard_normal((ms, k)), jnp.float32)
    got = pg_mod.scatter_add_rows(table.copy(), idx, rows, interpret=True)
    want = ref.scatter_add_rows_ref(table, idx, rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("m,k,ms", [(100, 16, 10), (500, 25, 50), (64, 8, 64)])
def test_scatter_set_rows_sweep(m, k, ms):
    table = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    idx = jnp.asarray(RNG.choice(m, ms, replace=False).astype(np.int32))
    rows = jnp.asarray(RNG.standard_normal((ms, k)), jnp.float32)
    got = pg_mod.scatter_set_rows(table.copy(), idx, rows, interpret=True)
    want = ref.scatter_set_rows_ref(table, idx, rows)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # selected rows replaced, untouched rows bit-identical
    np.testing.assert_array_equal(np.asarray(got)[np.asarray(idx)],
                                  np.asarray(rows))
    mask = np.ones(m, bool)
    mask[np.asarray(idx)] = False
    np.testing.assert_array_equal(np.asarray(got)[mask],
                                  np.asarray(table)[mask])


# --------------------------------------------------------------------- #
# shard-local (row-block) variants — the per-device halves of the
# collective row ops used by the sharded round engine
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("m,k,ms,offset", [
    (32, 16, 20, 0), (32, 16, 20, 32), (32, 16, 20, 64),   # 3 shards of 32
    (10, 8, 16, 10),                                       # heavy OOB
])
def test_gather_rows_block_matches_ref(m, k, ms, offset):
    """Clamped local gather: in-range rows exact; OOB rows are clamp
    artifacts with well-defined values (discarded by the owner-select)."""
    table = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    gidx = jnp.asarray(np.sort(RNG.choice(3 * m, ms, replace=False))
                       .astype(np.int32))
    local = gidx - offset
    got = pg_mod.gather_rows_block(table, local, interpret=True)
    want = ref.gather_rows_block_ref(table, local)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    in_range = (np.asarray(local) >= 0) & (np.asarray(local) < m)
    np.testing.assert_array_equal(
        np.asarray(got)[in_range],
        np.asarray(table)[np.asarray(local)[in_range]])


@pytest.mark.parametrize("m,k,ms,offset", [
    (32, 16, 20, 0), (32, 16, 20, 32), (32, 16, 20, 64),
    (10, 8, 16, 10),
])
def test_scatter_set_rows_block_matches_ref(m, k, ms, offset):
    table = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    gidx = jnp.asarray(np.sort(RNG.choice(3 * m, ms, replace=False))
                       .astype(np.int32))
    rows = jnp.asarray(RNG.standard_normal((ms, k)), jnp.float32)
    local = gidx - offset
    got = pg_mod.scatter_set_rows_block(table.copy(), local, rows,
                                        interpret=True)
    want = ref.scatter_set_rows_block_ref(table, local, rows)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # in-range rows replaced, every other row untouched bit-for-bit
    lnp = np.asarray(local)
    in_range = (lnp >= 0) & (lnp < m)
    np.testing.assert_array_equal(np.asarray(got)[lnp[in_range]],
                                  np.asarray(rows)[in_range])
    mask = np.ones(m, bool)
    mask[lnp[in_range]] = False
    np.testing.assert_array_equal(np.asarray(got)[mask],
                                  np.asarray(table)[mask])


def test_scatter_set_rows_block_all_out_of_range_is_identity():
    """M_s < num_shards leaves some shards with nothing to write."""
    table = jnp.asarray(RNG.standard_normal((16, 8)), jnp.float32)
    local = jnp.asarray([-16, -9, 20, 31], jnp.int32)
    rows = jnp.asarray(RNG.standard_normal((4, 8)), jnp.float32)
    got = pg_mod.scatter_set_rows_block(table.copy(), local, rows,
                                        interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(table))
    got_ref = ref.scatter_set_rows_block_ref(table, local, rows)
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(table))


def test_gather_quantize_rows_block_bit_exact_vs_full_table():
    """Owner-shard candidates carry exactly the codes/scales a single-device
    encode of the full table would produce — the collective-aware int8
    downlink's bit-parity contract."""
    from repro.kernels import payload_quant as pq_mod

    full = jnp.asarray(RNG.standard_normal((64, 16)), jnp.float32)
    idx = jnp.asarray(np.sort(RNG.choice(64, 24, replace=False))
                      .astype(np.int32))
    want_codes, want_scales = ref.gather_quantize_rows_ref(full, idx)
    shards, m = 4, 16
    for d in range(shards):
        block = full[d * m:(d + 1) * m]
        local = idx - d * m
        codes, scales = pq_mod.gather_quantize_rows_block(block, local,
                                                          interpret=True)
        owned = (np.asarray(local) >= 0) & (np.asarray(local) < m)
        np.testing.assert_array_equal(np.asarray(codes)[owned],
                                      np.asarray(want_codes)[owned])
        np.testing.assert_array_equal(np.asarray(scales)[owned],
                                      np.asarray(want_scales)[owned])
        # and the kernel must match its own block oracle on every row,
        # out-of-shard garbage rows included
        ref_codes, ref_scales = ref.gather_quantize_rows_block_ref(block,
                                                                   local)
        np.testing.assert_array_equal(np.asarray(codes),
                                      np.asarray(ref_codes))
        np.testing.assert_array_equal(np.asarray(scales),
                                      np.asarray(ref_scales))


# --------------------------------------------------------------------- #
# fused payload compression kernels (bit-exactness contract vs the codec)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("m,k,ms", [(100, 16, 10), (500, 25, 50),
                                    (64, 8, 64), (200, 128, 32)])
def test_gather_quantize_rows_bit_exact(m, k, ms):
    from repro.kernels import payload_quant as pq_mod

    table = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    idx = jnp.asarray(RNG.choice(m, ms, replace=False).astype(np.int32))
    codes, scales = pq_mod.gather_quantize_rows(table, idx, interpret=True)
    want_codes, want_scales = ref.gather_quantize_rows_ref(table, idx)
    assert codes.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(want_codes))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(want_scales))


def test_gather_quantize_matches_pure_codec_path():
    """Fused kernel == gather_rows then codecs.quantize_rows, bit for bit —
    the contract that lets the server route int8 downlinks through the
    kernel while the python-backend reference uses the pure codec."""
    from repro.compress.codecs import quantize_rows
    from repro.kernels import payload_quant as pq_mod

    table = jnp.asarray(RNG.standard_normal((300, 25)), jnp.float32)
    idx = jnp.asarray(RNG.choice(300, 40, replace=False).astype(np.int32))
    codes, scales = pq_mod.gather_quantize_rows(table, idx, interpret=True)
    want_codes, want_scales = quantize_rows(table[idx], nbits=8)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(want_codes))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(want_scales))


def test_gather_quantize_zero_rows():
    from repro.kernels import payload_quant as pq_mod

    table = jnp.zeros((16, 8), jnp.float32)
    idx = jnp.arange(8, dtype=jnp.int32)
    codes, scales = pq_mod.gather_quantize_rows(table, idx, interpret=True)
    assert (np.asarray(codes) == 0).all()
    assert (np.asarray(scales) == 0).all()


@pytest.mark.parametrize("m,k,ms", [(100, 16, 10), (500, 25, 50), (64, 8, 64)])
def test_dequant_scatter_set_rows_bit_exact(m, k, ms):
    from repro.kernels import payload_quant as pq_mod

    table = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    idx = jnp.asarray(RNG.choice(m, ms, replace=False).astype(np.int32))
    codes = jnp.asarray(RNG.integers(-127, 128, (ms, k)).astype(np.int8))
    scales = jnp.asarray(
        np.abs(RNG.standard_normal((ms, 1))).astype(np.float32))
    got = pq_mod.dequant_scatter_set_rows(table.copy(), idx, codes, scales,
                                          interpret=True)
    want = ref.dequant_scatter_set_rows_ref(table, idx, codes, scales)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # untouched rows bit-identical
    mask = np.ones(m, bool)
    mask[np.asarray(idx)] = False
    np.testing.assert_array_equal(np.asarray(got)[mask],
                                  np.asarray(table)[mask])


def test_quantize_wire_roundtrip_through_kernels():
    """gather+quantize then dequant+scatter restores the table rows to
    within the int8 half-step bound — the full downlink wire trip."""
    from repro.kernels import payload_quant as pq_mod

    table = jnp.asarray(RNG.standard_normal((120, 32)), jnp.float32)
    idx = jnp.asarray(RNG.choice(120, 24, replace=False).astype(np.int32))
    codes, scales = pq_mod.gather_quantize_rows(table, idx, interpret=True)
    out = pq_mod.dequant_scatter_set_rows(table.copy(), idx, codes, scales,
                                          interpret=True)
    sel = np.asarray(idx)
    err = np.abs(np.asarray(out)[sel] - np.asarray(table)[sel])
    assert (err <= np.asarray(scales) / 2 + 1e-6).all()


def test_gather_then_scatter_roundtrip():
    """Property: scatter(-gathered rows) restores zeros at selected rows'
    deltas — the payload round-trip used every FL iteration."""
    table = jnp.asarray(RNG.standard_normal((200, 12)), jnp.float32)
    idx = jnp.asarray(RNG.choice(200, 30, replace=False).astype(np.int32))
    rows = pg_mod.gather_rows(table, idx, interpret=True)
    out = pg_mod.scatter_add_rows(table.copy(), idx, -rows, interpret=True)
    np.testing.assert_allclose(np.asarray(out[np.asarray(idx)]),
                               np.zeros((30, 12)), atol=1e-6)
    # untouched rows unchanged
    mask = np.ones(200, bool)
    mask[np.asarray(idx)] = False
    np.testing.assert_array_equal(np.asarray(out[mask]), np.asarray(table[mask]))


# --------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------- #
CASES = [
    # (B, H, KVH, S, T, D, causal, window, q_offset)
    (1, 4, 4, 128, 128, 16, True, None, 0),        # vanilla causal MHA
    (2, 8, 2, 96, 96, 32, True, None, 0),          # GQA, ragged seq
    (1, 4, 1, 64, 64, 16, True, None, 0),          # MQA
    (1, 4, 4, 128, 128, 16, True, 32, 0),          # sliding window
    (1, 4, 4, 100, 100, 8, False, None, 0),        # encoder (bidirectional)
    (1, 2, 2, 1, 200, 16, True, None, 199),        # single-token decode
    (2, 4, 2, 1, 333, 32, True, 64, 332),          # windowed decode, ragged kv
    (1, 2, 2, 7, 129, 16, True, None, 122),        # chunked prefill w/ offset
]


@pytest.mark.parametrize("b,h,kvh,s,t,d,causal,window,q_offset", CASES)
def test_flash_attention_sweep(b, h, kvh, s, t, d, causal, window, q_offset):
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, kvh, t, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, kvh, t, d)), jnp.float32)
    got = flash_mod.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=32, block_k=64, interpret=True)
    want = ref.mha_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 4, 64, 16)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 4, 64, 16)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 4, 64, 16)), jnp.bfloat16)
    got = flash_mod.flash_attention(q, k, v, block_q=32, block_k=32,
                                    interpret=True)
    want = ref.mha_ref(q, k, v)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=0.05, atol=0.05)


def test_flash_block_size_invariance():
    q = jnp.asarray(RNG.standard_normal((1, 2, 160, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 160, 16)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 160, 16)), jnp.float32)
    a = flash_mod.flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    b = flash_mod.flash_attention(q, k, v, block_q=128, block_k=160, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


@settings(deadline=None, max_examples=8)
@given(
    s=st.integers(min_value=1, max_value=96),
    d=st.sampled_from([8, 16, 32]),
    window=st.one_of(st.none(), st.integers(min_value=1, max_value=64)),
)
def test_flash_property_rows_are_convex_combinations(s, d, window):
    """Property: each output row is a convex combination of v rows, so its
    values lie within [min(v), max(v)] per dim; and softmax rows sum to 1
    implicitly (checked via constant-v => constant-out)."""
    rng = np.random.default_rng(s * 100 + d)
    q = jnp.asarray(rng.standard_normal((1, 2, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, s, d)), jnp.float32)
    v = jnp.ones((1, 2, s, d), jnp.float32) * 3.5
    out = flash_mod.flash_attention(q, k, v, window=window, block_q=32,
                                    block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 3.5 * np.ones_like(out),
                               rtol=1e-5)


def test_ops_wrappers_dispatch_on_cpu():
    """ops.py must route to interpret-mode kernels on CPU and match refs."""
    from repro.kernels import ops
    q = jnp.asarray(RNG.standard_normal((1, 2, 32, 8)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 32, 8)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 32, 8)), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.attention(q, k, v)),
                               np.asarray(ref.mha_ref(q, k, v)),
                               rtol=2e-4, atol=2e-5)
    table = jnp.asarray(RNG.standard_normal((64, 8)), jnp.float32)
    idx = jnp.asarray(np.arange(0, 64, 2, dtype=np.int32))
    np.testing.assert_array_equal(np.asarray(ops.gather_rows(table, idx)),
                                  np.asarray(table[idx]))
