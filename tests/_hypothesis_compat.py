"""Optional-hypothesis shim for the test suite.

``hypothesis`` is not part of the baked container image, and a hard import
used to fail collection for whole modules, taking their deterministic tests
down too. Import the property-test tools from here instead:

    from _hypothesis_compat import given, settings, st, hnp

When hypothesis IS installed, these are the real objects. When it is not,
``given`` degrades to a deterministic fallback: the wrapped property test
runs against a handful of fixed pseudo-random samples drawn from lightweight
stand-ins for the strategies actually used in this suite (``st.integers``,
``st.floats``, ``hnp.arrays``). Weaker than real shrinking-based property
testing, but the invariants still get exercised and — crucially — the
deterministic tests in the same module still collect and run.
"""
from __future__ import annotations

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        """Minimal sampler standing in for a hypothesis strategy."""

        def __init__(self, sample_fn):
            self._sample_fn = sample_fn

        def sample(self, rng: np.random.Generator):
            return self._sample_fn(rng)

    class _IntStrategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value: float, max_value: float, **_ignored) -> _Strategy:
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(options) -> _Strategy:
            options = list(options)
            return _Strategy(lambda rng: options[rng.integers(len(options))])

        @staticmethod
        def none() -> _Strategy:
            return _Strategy(lambda rng: None)

        @staticmethod
        def one_of(*strategies: _Strategy) -> _Strategy:
            return _Strategy(
                lambda rng: strategies[rng.integers(len(strategies))]
                .sample(rng))

    class _NumpyStrategies:
        @staticmethod
        def arrays(dtype, shape, elements: _Strategy) -> _Strategy:
            def sample(rng):
                flat = [elements.sample(rng) for _ in range(int(np.prod(shape)))]
                return np.asarray(flat, dtype=dtype).reshape(shape)
            return _Strategy(sample)

    st = _IntStrategies()
    hnp = _NumpyStrategies()

    def settings(*_args, **_kwargs):
        """No-op replacement for ``hypothesis.settings`` as a decorator."""
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        """Run the test body on a few fixed pseudo-random samples."""
        def deco(fn):
            # NOT functools.wraps: pytest must see a zero-arg signature, or
            # it would treat the strategy parameters as fixture requests
            def wrapper():
                rng = np.random.default_rng(0xC0FFEE)
                for _ in range(_FALLBACK_EXAMPLES):
                    drawn = {name: s.sample(rng)
                             for name, s in strategies.items()}
                    fn(**drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco


__all__ = ["given", "settings", "st", "hnp", "HAVE_HYPOTHESIS"]
