"""Tests for synthetic dataset generation and the token pipeline."""
import numpy as np
import pytest

from repro.data.synthetic import (
    DATASET_SPECS, generate_interactions, load_dataset, sparsity, train_test_split,
)
from repro.data.tokens import TokenDataConfig, synthetic_token_batches


def test_mini_dataset_matches_spec():
    spec = DATASET_SPECS["movielens-mini"]
    x = generate_interactions(spec, seed=0)
    assert x.shape == (spec.num_users, spec.num_items)
    total = int(x.sum())
    assert abs(total - spec.num_interactions) / spec.num_interactions < 0.15
    # every user respects the paper's >=5-interaction preprocessing
    assert (x.sum(axis=1) >= spec.min_degree).all()


def test_popularity_is_skewed():
    """The generator must plant a popularity power law (TopList needs it)."""
    x = generate_interactions(DATASET_SPECS["mind-mini"], seed=1)
    counts = np.sort(x.sum(axis=0))[::-1]
    top_decile = counts[: len(counts) // 10].sum()
    assert top_decile / counts.sum() > 0.3


def test_split_is_disjoint_and_complete():
    spec = DATASET_SPECS["lastfm-mini"]
    x = generate_interactions(spec, seed=2)
    train, test = train_test_split(x, 0.8, seed=3)
    assert ((train + test) == x).all()          # partition of interactions
    assert not np.logical_and(train, test).any()
    # all users have at least one train and one test item (degree >= 5)
    assert (train.sum(axis=1) >= 1).all()
    assert (test.sum(axis=1) >= 1).all()
    frac = train.sum() / x.sum()
    assert 0.7 < frac < 0.9


def test_split_determinism():
    x = generate_interactions(DATASET_SPECS["movielens-mini"], seed=0)
    a1, b1 = train_test_split(x, seed=5)
    a2, b2 = train_test_split(x, seed=5)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)


def test_load_dataset_api():
    spec, train, test = load_dataset("mind-mini", seed=0)
    assert train.dtype == np.float32
    assert spec.num_users == train.shape[0]
    assert sparsity(train + test) > 90.0


def test_token_pipeline_shapes_and_noniid():
    cfg = TokenDataConfig(vocab_size=1000, seq_len=32, batch_size=4,
                          num_clients=4, seed=0)
    b0 = next(iter(synthetic_token_batches(cfg, client_id=0, num_batches=1)))
    b1 = next(iter(synthetic_token_batches(cfg, client_id=1, num_batches=1)))
    assert b0["tokens"].shape == (4, 33)
    assert b0["tokens"].dtype == np.int32
    assert (b0["tokens"] >= 0).all() and (b0["tokens"] < 1000).all()
    # non-IID: different clients draw visibly different unigram distributions
    h0 = np.bincount(b0["tokens"].ravel(), minlength=1000)
    h1 = np.bincount(b1["tokens"].ravel(), minlength=1000)
    assert np.abs(h0 - h1).sum() > 0


def test_token_pipeline_batch_count():
    cfg = TokenDataConfig(vocab_size=50, seq_len=8, batch_size=2, seed=1)
    batches = list(synthetic_token_batches(cfg, num_batches=5))
    assert len(batches) == 5
