"""payload_train_step correctness: the paper's selected-subset semantics.

  * unselected vocab rows (params AND Adam moments) are bit-unchanged,
  * selected rows + the whole body update,
  * with selected = every row, it reproduces the plain train_step exactly,
  * feedback has the row-grads shape and is finite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import lm


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-4b").reduced()
    state = lm.init_train_state(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                                cfg.vocab_size, jnp.int32)
    return cfg, state, {"tokens": tokens}


def test_unselected_rows_untouched(setup):
    cfg, state, batch = setup
    # include ids that occur in the batch so the embed table (whose grads
    # are nonzero only for seen tokens) provably updates too
    seen = np.unique(np.asarray(batch["tokens"]))[:2]
    sel = jnp.asarray([int(seen[0]), int(seen[1]), 77, 200], jnp.int32)
    new, loss, fb = jax.jit(
        lambda s, b, i: lm.payload_train_step(s, b, i, cfg))(
        state, batch, sel)
    assert np.isfinite(float(loss))
    assert fb.shape == (4, cfg.d_model)
    assert np.isfinite(np.asarray(fb)).all()

    mask = np.ones(cfg.padded_vocab, bool)
    mask[np.asarray(sel)] = False
    for t in ("embed", "unembed"):
        old_tab = np.asarray(state.params[t]["table"])
        new_tab = np.asarray(new.params[t]["table"])
        np.testing.assert_array_equal(old_tab[mask], new_tab[mask])
        assert not np.allclose(old_tab[~mask], new_tab[~mask])
        np.testing.assert_array_equal(np.asarray(state.m[t]["table"])[mask],
                                      np.asarray(new.m[t]["table"])[mask])
    # body still trains
    assert not np.allclose(
        np.asarray(state.params["final_norm"]["scale"]),
        np.asarray(new.params["final_norm"]["scale"]))


def test_full_selection_matches_train_step(setup):
    cfg, state, batch = setup
    sel = jnp.arange(cfg.padded_vocab, dtype=jnp.int32)
    ref_state, ref_loss = jax.jit(
        lambda s, b: lm.train_step(s, b, cfg))(state, batch)
    new, loss, _ = jax.jit(
        lambda s, b, i: lm.payload_train_step(s, b, i, cfg))(
        state, batch, sel)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-6)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(ref_state.params)[0],
            jax.tree_util.tree_flatten_with_path(new.params)[0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6, err_msg=str(pa))


def test_loss_decreases_over_rounds(setup):
    cfg, state, batch = setup
    step = jax.jit(lambda s, b, i: lm.payload_train_step(s, b, i, cfg,
                                                         lr=1e-2))
    key = jax.random.PRNGKey(3)
    m_s = cfg.padded_vocab // 10
    first = last = None
    for t in range(8):
        key, sub = jax.random.split(key)
        sel = jax.random.choice(sub, cfg.padded_vocab, (m_s,), replace=False)
        state, loss, _ = step(state, batch, sel.astype(jnp.int32))
        first = first if first is not None else float(loss)
        last = float(loss)
    assert last < first
