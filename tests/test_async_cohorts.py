"""Async cohort engine: staleness-bounded queue vs the synchronous scan.

The tentpole contract: ``backend="async"`` with ``max_staleness=0`` must
reproduce the ``backend="scan"`` trajectory BIT-FOR-BIT at equal cohort
blocking (``blocks_per_commit=B`` == ``cohort_shards=B``) — the async
machinery (snapshot ring, pending-attribution buffer, staleness discount,
delayed reward attribution) must compile to a float-exact no-op when every
commit is fresh. On top of that: queue saturation (``staleness_mode="max"``)
commits maximally stale snapshots every round, the staleness discount
really gates the Adam step, the ring really stores payload-sized wire
images, and the sharded composition (``mesh_shards``) reproduces the
single-device async trajectory (fake-device subprocess, like
``tests/test_sharded_rounds.py``).
"""
import os
import pathlib
import subprocess
import sys
from dataclasses import replace

import numpy as np
import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
SRC = str(REPO_ROOT / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.federated.simulation import (  # noqa: E402
    FLSimConfig, _staleness_schedule, run_fcf_simulation,
)

STRATEGIES = ("bts", "random", "full", "magnitude")


def _mini_data(seed=0, users=60, items=80):
    rng = np.random.default_rng(seed)
    train = (rng.random((users, items)) < 0.15).astype(np.float32)
    test = (rng.random((users, items)) < 0.05).astype(np.float32)
    return train, test


def _cfg(strategy, **kw):
    base = dict(strategy=strategy, keep_fraction=0.25, rounds=6, theta=10,
                eval_every=3, eval_users=40, seed=0, record_selections=True)
    base.update(kw)
    return FLSimConfig(**base)


@pytest.fixture(scope="module")
def data():
    return _mini_data()


def assert_bitwise(tag, ref, res):
    np.testing.assert_array_equal(ref.selections, res.selections,
                                  err_msg=f"{tag}: selections")
    np.testing.assert_array_equal(ref.rewards, res.rewards,
                                  err_msg=f"{tag}: rewards")
    np.testing.assert_array_equal(np.asarray(ref.server_state.q),
                                  np.asarray(res.server_state.q),
                                  err_msg=f"{tag}: Q")
    np.testing.assert_array_equal(np.asarray(ref.server_state.opt.m),
                                  np.asarray(res.server_state.opt.m),
                                  err_msg=f"{tag}: adam m")
    assert float(ref.server_state.bytes_down) == \
        float(res.server_state.bytes_down), f"{tag}: bytes_down"
    assert float(ref.server_state.bytes_up) == \
        float(res.server_state.bytes_up), f"{tag}: bytes_up"
    assert ref.history.series("f1") == res.history.series("f1"), \
        f"{tag}: f1 trajectory"


# --------------------------------------------------------------------- #
# max_staleness=0 == the synchronous scan, bit for bit
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_async_s0_matches_scan_bitwise(data, strategy):
    train, test = data
    cfg = _cfg(strategy)
    scan = run_fcf_simulation(train, test, cfg)
    asy = run_fcf_simulation(
        train, test, replace(cfg, backend="async", max_staleness=0))
    assert_bitwise(f"{strategy}/fp32", scan, asy)


@pytest.mark.parametrize("codec", ["int8", "topk"])
def test_async_s0_matches_scan_bitwise_codecs(data, codec):
    """The codec path (incl. the stateful topk EF residual) stays exact."""
    train, test = data
    cfg = _cfg("bts", codec=codec)
    scan = run_fcf_simulation(train, test, cfg)
    asy = run_fcf_simulation(
        train, test, replace(cfg, backend="async", max_staleness=0))
    assert_bitwise(f"bts/{codec}", scan, asy)


def test_async_s0_blocking_matches_cohort_shards(data):
    """blocks_per_commit=B == backend="scan" with cohort_shards=B (padded
    blocks included: theta=10 over 3 blocks -> blocks of 4 with 2 pads)."""
    train, test = data
    cfg = _cfg("bts")
    scan = run_fcf_simulation(train, test, replace(cfg, cohort_shards=3))
    asy = run_fcf_simulation(
        train, test,
        replace(cfg, backend="async", max_staleness=0, blocks_per_commit=3))
    assert_bitwise("bts/blocked", scan, asy)


# --------------------------------------------------------------------- #
# staleness actually happens (and stays bounded)
# --------------------------------------------------------------------- #
def test_saturated_queue_commits_the_max_stale_snapshot(data):
    """staleness_mode="max": round t commits the pull of round t - min(S, t-1).

    The random strategy's pulls depend only on the PRNG stream (never on Q),
    so the synchronous scan's per-round selections ARE the async engine's
    per-round pulls — the committed indices must be exactly those pulls
    shifted by the staleness schedule. This pins both saturation (every
    commit maximally stale) and the bounded-queue arithmetic.
    """
    train, test = data
    s_max = 2
    cfg = _cfg("random", rounds=8)
    scan = run_fcf_simulation(train, test, cfg)
    asy = run_fcf_simulation(
        train, test, replace(cfg, backend="async", max_staleness=s_max,
                             staleness_mode="max"))
    for i in range(8):
        s_i = min(s_max, i)
        np.testing.assert_array_equal(
            asy.selections[i], scan.selections[i - s_i],
            err_msg=f"round {i + 1} should commit the round-{i + 1 - s_i} "
                    f"pull (s={s_i})")
    # stale trajectories are genuinely different from sync
    assert not np.array_equal(np.asarray(asy.server_state.q),
                              np.asarray(scan.server_state.q))


def test_staleness_schedule_is_clamped_and_modal():
    sched = _staleness_schedule(FLSimConfig(
        backend="async", max_staleness=3, rounds=50, staleness_mode="max"))
    assert sched.tolist()[:4] == [0, 1, 2, 3]
    assert (sched[3:] == 3).all()
    uni = _staleness_schedule(FLSimConfig(
        backend="async", max_staleness=3, rounds=200,
        staleness_mode="uniform", seed=1))
    assert uni.min() == 0 and uni.max() == 3
    assert (uni <= np.arange(200)).all()          # never older than history
    # sync backends and S=0 get the all-zero schedule
    assert (_staleness_schedule(FLSimConfig(rounds=10)) == 0).all()


def test_zero_discount_freezes_stale_commits(data):
    """staleness_discount=0: an s>0 commit scales its Adam step by 0**s = 0,
    so under mode="max" (every commit after round 1 stale) Q never moves
    past round 1 — the discount gates the step, not just the accounting."""
    train, test = data
    base = _cfg("bts", backend="async", max_staleness=1,
                staleness_mode="max", staleness_discount=0.0)
    one = run_fcf_simulation(train, test, replace(base, rounds=1))
    five = run_fcf_simulation(train, test, replace(base, rounds=5))
    np.testing.assert_array_equal(np.asarray(one.server_state.q),
                                  np.asarray(five.server_state.q))
    # the undamped run does keep moving
    moving = run_fcf_simulation(
        train, test, replace(base, rounds=5, staleness_discount=1.0))
    assert not np.array_equal(np.asarray(one.server_state.q),
                              np.asarray(moving.server_state.q))


def test_stale_runs_change_quality_not_accounting(data):
    """Staleness may move the metrics, never the wire-byte totals."""
    train, test = data
    cfg = _cfg("bts", codec="int8")
    sync = run_fcf_simulation(train, test, cfg)
    stale = run_fcf_simulation(
        train, test, replace(cfg, backend="async", max_staleness=4))
    assert (stale.bytes_down, stale.bytes_up) == \
        (sync.bytes_down, sync.bytes_up)
    assert stale.rounds == sync.rounds


# --------------------------------------------------------------------- #
# state plumbing
# --------------------------------------------------------------------- #
def test_snapshot_ring_is_payload_sized_wire():
    """Depth-S bounding costs S+1 wire images of the M_s-row payload —
    int8 codes + per-row scales — not S+1 full (M, K) fp32 tables."""
    import jax.numpy as jnp

    from repro.federated.simulation import _build

    train, test = _mini_data()
    cfg = FLSimConfig(strategy="bts", keep_fraction=0.1, rounds=4, theta=10,
                      codec="int8", backend="async", max_staleness=3)
    setup = _build(jnp.asarray(train), jnp.asarray(test), cfg)
    ring = setup.state0.snapshots
    m_s = setup.sel_cfg.num_select
    assert m_s == 8                                 # 10% of 80 items
    assert ring.values.shape == (4, m_s, cfg.num_factors)
    assert ring.values.dtype == jnp.int8
    assert ring.scales.shape == (4, m_s, 1)
    # pending-attribution buffer rides in the selector state
    pend = setup.state0.sel.pending
    assert pend.indices.shape == (4, m_s)
    assert pend.t.shape == (4,)


def test_selector_observe_delay_correction_matches_shifted_round():
    """observe(t_obs=s) must equal observing from a selector whose round
    counter IS s — the reward coefficients see the pull round, nothing
    else changes."""
    import jax
    import jax.numpy as jnp

    from repro.core.selector import (
        SelectorConfig, selector_init, selector_observe, selector_select,
    )

    cfg = SelectorConfig(strategy="bts", num_arms=40, num_select=10, dim=8)
    state = selector_init(cfg)
    key = jax.random.PRNGKey(3)
    # advance to round 9 with a few observes so the buffers are non-trivial
    for r in range(9):
        k = jax.random.fold_in(key, r)
        idx, state = selector_select(cfg, state, k)
        fb = jax.random.normal(jax.random.fold_in(key, 100 + r), (10, 8))
        state, _ = selector_observe(cfg, state, idx, fb)
    idx, state = selector_select(cfg, state, jax.random.fold_in(key, 99))
    fb = jax.random.normal(jax.random.fold_in(key, 999), (10, 8))

    delayed, r_delayed = selector_observe(
        cfg, state, idx, fb, t_obs=jnp.asarray(5, jnp.int32))
    shifted, r_shifted = selector_observe(
        cfg, state._replace(t=jnp.asarray(5, jnp.int32)), idx, fb)
    np.testing.assert_array_equal(np.asarray(r_delayed),
                                  np.asarray(r_shifted))
    np.testing.assert_array_equal(np.asarray(delayed.bts.reward_sum),
                                  np.asarray(shifted.bts.reward_sum))


def test_async_validates_config(data):
    train, test = data
    with pytest.raises(ValueError, match="async"):
        run_fcf_simulation(train, test, _cfg("bts", max_staleness=2))
    with pytest.raises(ValueError, match="staleness_mode"):
        run_fcf_simulation(train, test, _cfg(
            "bts", backend="async", max_staleness=1, staleness_mode="bogus"))
    with pytest.raises(ValueError, match="max_staleness"):
        run_fcf_simulation(train, test, _cfg(
            "bts", backend="async", max_staleness=-1))
    with pytest.raises(ValueError, match="blocks_per_commit"):
        run_fcf_simulation(train, test, _cfg(
            "bts", backend="async", blocks_per_commit=0))
    # a mesh dictates one block per device — conflicting blocking is loud
    with pytest.raises(ValueError, match="mesh_shards"):
        run_fcf_simulation(train, test, _cfg(
            "bts", backend="async", mesh_shards=1, blocks_per_commit=2))


# --------------------------------------------------------------------- #
# sharded composition (fake-device subprocess)
# --------------------------------------------------------------------- #
_SHARD_SCRIPT = r"""
from dataclasses import replace
import numpy as np
from repro.federated.simulation import FLSimConfig, run_fcf_simulation

rng = np.random.default_rng(0)
train = (rng.random((60, 80)) < 0.15).astype(np.float32)
test = (rng.random((60, 80)) < 0.05).astype(np.float32)

checked = 0
for codec in ("fp32", "int8"):
    for s_max in (0, 2):
        cfg = FLSimConfig(strategy="bts", keep_fraction=0.25, rounds=6,
                          theta=10, eval_every=3, eval_users=40, seed=0,
                          codec=codec, record_selections=True,
                          backend="async", max_staleness=s_max,
                          staleness_mode="max")
        ref = run_fcf_simulation(train, test,
                                 replace(cfg, blocks_per_commit=4))
        shard = run_fcf_simulation(train, test, replace(cfg, mesh_shards=4))
        np.testing.assert_array_equal(ref.selections, shard.selections)
        q_ref = np.asarray(ref.server_state.q)
        q_shard = np.asarray(shard.server_state.q)
        if codec == "fp32" and s_max > 0:
            # raw-fp32 stale pops: XLA:CPU contraction ulps (see
            # server_round_step_async docstring), never bit drift
            np.testing.assert_allclose(q_ref, q_shard, rtol=1e-5, atol=1e-6)
        else:
            np.testing.assert_array_equal(q_ref, q_shard)
            np.testing.assert_array_equal(ref.rewards, shard.rewards)
        assert float(ref.server_state.bytes_down) == \
            float(shard.server_state.bytes_down)
        checked += 1

print(f"ASYNC_SHARD_PARITY_OK checked={checked}")
"""


@pytest.mark.subprocess
def test_async_composes_with_shard_mesh():
    """backend="async" + mesh_shards=4 == the single-device async engine at
    blocks_per_commit=4, in a fake-CPU-device subprocess (one jax init)."""
    from repro.launch.mesh import fake_cpu_devices_env

    env = fake_cpu_devices_env(4)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT],
        capture_output=True, text=True, env=env, cwd=str(REPO_ROOT),
        timeout=1200,
    )
    assert proc.returncode == 0, (
        f"async shard parity subprocess failed\nstdout:\n{proc.stdout}\n"
        f"stderr:\n{proc.stderr}")
    assert "ASYNC_SHARD_PARITY_OK checked=4" in proc.stdout
