"""Functional round engine: scan-vs-python equivalence + sweep smoke tests.

The ``lax.scan``-compiled engine must reproduce the per-round-dispatch
Python reference loop bit-for-bit under the same PRNG seed: identical
selections, identical Q trajectory, identical (traced) byte counters — for
every strategy. This is what licenses using the fast engine for the paper's
experiment grids.
"""
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.core.payload import payload_bytes
from repro.core.selector import (
    SelectorConfig, selector_init, selector_observe, selector_select,
)
from repro.federated.simulation import (
    FLSimConfig, run_fcf_simulation, run_seed_sweep, run_strategy_sweep,
)

STRATEGIES = ("bts", "random", "full", "magnitude")


def _mini_data(seed=0, users=60, items=80):
    rng = np.random.default_rng(seed)
    train = (rng.random((users, items)) < 0.15).astype(np.float32)
    test = (rng.random((users, items)) < 0.05).astype(np.float32)
    return train, test


def _cfg(strategy, **kw):
    base = dict(strategy=strategy, keep_fraction=0.25, rounds=12, theta=10,
                eval_every=6, eval_users=40, seed=0, record_selections=True)
    base.update(kw)
    return FLSimConfig(**base)


@pytest.fixture(scope="module")
def data():
    return _mini_data()


# --------------------------------------------------------------------- #
# scan == python, bit for bit
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_scan_matches_python_loop_bitwise(data, strategy):
    train, test = data
    cfg = _cfg(strategy)
    scan = run_fcf_simulation(train, test, replace(cfg, backend="scan"))
    py = run_fcf_simulation(train, test, replace(cfg, backend="python"))

    # same selections every round
    np.testing.assert_array_equal(scan.selections, py.selections)
    # same bandit rewards
    np.testing.assert_array_equal(scan.rewards, py.rewards)
    # same final global model, bit for bit
    np.testing.assert_array_equal(np.asarray(scan.server_state.q),
                                  np.asarray(py.server_state.q))
    # same Adam moments
    np.testing.assert_array_equal(np.asarray(scan.server_state.opt.m),
                                  np.asarray(py.server_state.opt.m))
    # same traced byte counters and exact byte totals
    assert float(scan.server_state.bytes_down) == \
        float(py.server_state.bytes_down)
    assert float(scan.server_state.bytes_up) == \
        float(py.server_state.bytes_up)
    assert (scan.bytes_down, scan.bytes_up) == (py.bytes_down, py.bytes_up)
    # same selection counts and metric trajectory
    np.testing.assert_array_equal(scan.selection_counts, py.selection_counts)
    assert scan.history.series("f1") == py.history.series("f1")


def test_scan_engine_q_actually_changes(data):
    train, test = data
    res = run_fcf_simulation(train, test, _cfg("bts"))
    assert res.rounds == 12
    q = np.asarray(res.server_state.q)
    assert np.isfinite(q).all()
    assert np.abs(q).max() > 0


# --------------------------------------------------------------------- #
# lossy-codec parity: the compressed engine must match its reference too
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_scan_matches_python_loop_bitwise_int8_codec(data, strategy):
    """Same bit-for-bit scan==python contract with the int8 wire format in
    the loop: quantized downlink Q*, quantized uplink gradients, codec-
    routed byte counters."""
    train, test = data
    cfg = _cfg(strategy, codec="int8")
    scan = run_fcf_simulation(train, test, replace(cfg, backend="scan"))
    py = run_fcf_simulation(train, test, replace(cfg, backend="python"))

    np.testing.assert_array_equal(scan.selections, py.selections)
    np.testing.assert_array_equal(scan.rewards, py.rewards)
    np.testing.assert_array_equal(np.asarray(scan.server_state.q),
                                  np.asarray(py.server_state.q))
    assert float(scan.server_state.bytes_down) == \
        float(py.server_state.bytes_down)
    assert float(scan.server_state.bytes_up) == \
        float(py.server_state.bytes_up)
    assert (scan.bytes_down, scan.bytes_up) == (py.bytes_down, py.bytes_up)
    assert scan.history.series("f1") == py.history.series("f1")


def test_topk_codec_threads_residual_through_scan(data):
    """The EF residual must live in the scan carry: after a run it is
    non-zero exactly on rows that were ever selected, and the scan and
    python backends carry it identically."""
    train, test = data
    cfg = _cfg("bts", codec="topk")
    scan = run_fcf_simulation(train, test, replace(cfg, backend="scan"))
    py = run_fcf_simulation(train, test, replace(cfg, backend="python"))
    res_scan = np.asarray(scan.server_state.codec)
    res_py = np.asarray(py.server_state.codec)
    np.testing.assert_array_equal(res_scan, res_py)
    assert res_scan.shape == (train.shape[1], cfg.num_factors)
    selected_ever = np.unique(scan.selections)
    assert np.abs(res_scan[selected_ever]).max() > 0
    untouched = np.setdiff1d(np.arange(train.shape[1]), selected_ever)
    if untouched.size:
        assert np.abs(res_scan[untouched]).max() == 0


def test_codec_byte_counters_route_through_wire_bytes(data):
    from repro.compress import CodecConfig, wire_bytes

    train, test = data
    cfg = _cfg("random", codec="int8")
    res = run_fcf_simulation(train, test, cfg)
    num_select = max(1, int(round(cfg.keep_fraction * train.shape[1])))
    per_round = wire_bytes(CodecConfig(name="int8"), num_select,
                           cfg.num_factors)
    assert res.bytes_down == cfg.rounds * per_round
    assert res.bytes_up == cfg.rounds * per_round * cfg.theta
    assert float(res.server_state.bytes_down) == res.bytes_down
    assert float(res.server_state.bytes_up) == res.bytes_up


def test_lossy_codec_changes_trajectory_but_stays_close(data):
    """int8 must actually bite (different Q than fp32) without wrecking
    the learned model at this scale."""
    train, test = data
    cfg = _cfg("bts", rounds=8)
    r32 = run_fcf_simulation(train, test, cfg)
    r8 = run_fcf_simulation(train, test, replace(cfg, codec="int8"))
    q32 = np.asarray(r32.server_state.q)
    q8 = np.asarray(r8.server_state.q)
    assert not np.array_equal(q32, q8)
    # same selections up to the first reward divergence is not guaranteed,
    # but the models should remain in the same ballpark
    assert np.abs(q8 - q32).max() < 1.0
    assert np.isfinite(q8).all()


def test_strategy_sweep_codec_axis(data):
    train, test = data
    out = run_strategy_sweep(train, test, _cfg("bts", rounds=6, eval_every=3),
                             strategies=("bts",), seeds=(0,),
                             codecs=("fp32", "int8"))
    assert set(out["bts"]) == {"fp32", "int8"}
    fp32 = out["bts"]["fp32"][0]
    int8 = out["bts"]["int8"][0]
    assert int8.bytes_down < fp32.bytes_down
    # codec sweep must match a direct run of the same config
    single = run_fcf_simulation(
        train, test, _cfg("bts", rounds=6, eval_every=3, codec="int8"))
    np.testing.assert_array_equal(int8.selections, single.selections)
    np.testing.assert_array_equal(np.asarray(int8.server_state.q),
                                  np.asarray(single.server_state.q))


# --------------------------------------------------------------------- #
# byte accounting regression (float32 payload, not the Table-1 float64)
# --------------------------------------------------------------------- #
def test_byte_counters_match_float32_payload(data):
    train, test = data
    cfg = _cfg("random")
    res = run_fcf_simulation(train, test, cfg)
    num_select = max(1, int(round(cfg.keep_fraction * train.shape[1])))
    per_round = payload_bytes(num_select, cfg.num_factors, dtype_bits=32)
    assert res.bytes_down == cfg.rounds * per_round
    assert res.bytes_up == cfg.rounds * per_round * cfg.theta
    # the traced in-state counters agree (exactly, at this scale)
    assert float(res.server_state.bytes_down) == res.bytes_down
    assert float(res.server_state.bytes_up) == res.bytes_up


# --------------------------------------------------------------------- #
# vmapped sweeps
# --------------------------------------------------------------------- #
def test_vmap_seed_sweep_matches_single_runs(data):
    train, test = data
    cfg = _cfg("bts")
    sweep = run_seed_sweep(train, test, cfg, seeds=[0, 1])
    assert len(sweep) == 2
    for seed, res in zip([0, 1], sweep):
        single = run_fcf_simulation(train, test, replace(cfg, seed=seed))
        np.testing.assert_array_equal(res.selections, single.selections)
        np.testing.assert_array_equal(np.asarray(res.server_state.q),
                                      np.asarray(single.server_state.q))
    # different seeds must produce different trajectories
    assert not np.array_equal(sweep[0].selections, sweep[1].selections)


def test_seed_sweep_accepts_stacked_per_seed_data():
    trains, tests = zip(*[_mini_data(seed=s) for s in (3, 4)])
    cfg = _cfg("random")
    sweep = run_seed_sweep(np.stack(trains), np.stack(tests), cfg,
                           seeds=[3, 4])
    assert len(sweep) == 2
    for res in sweep:
        assert res.rounds == cfg.rounds
        assert np.isfinite(np.asarray(res.server_state.q)).all()


def test_strategy_sweep_smoke(data):
    train, test = data
    out = run_strategy_sweep(train, test, _cfg("bts", rounds=6, eval_every=3),
                             strategies=("bts", "random"), seeds=(0,))
    assert set(out) == {"bts", "random"}
    for results in out.values():
        assert len(results) == 1
        assert 0.0 <= results[0].final["f1"] <= 1.0


# --------------------------------------------------------------------- #
# pure selector API invariants
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_selector_api_is_scan_safe(strategy):
    """select/observe must trace inside jit+scan with state as pure carry."""
    num_arms, num_select, dim = 40, 40 if strategy == "full" else 10, 4
    cfg = SelectorConfig(strategy=strategy, num_arms=num_arms,
                         num_select=num_select, dim=dim)
    state0 = selector_init(cfg)

    def body(carry, key):
        state = carry
        idx, state = selector_select(cfg, state, key)
        state, rewards = selector_observe(
            cfg, state, idx, jax.numpy.ones((num_select, dim)))
        return state, (idx, rewards)

    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    state, (idxs, rewards) = jax.jit(
        lambda s, k: jax.lax.scan(body, s, k))(state0, keys)
    assert idxs.shape == (5, num_select)
    assert rewards.shape == (5, num_select)
    assert int(state.t) == 5
    # every per-round selection is unique
    for row in np.asarray(idxs):
        assert len(np.unique(row)) == num_select


def test_magnitude_selection_counts_accumulate():
    """Satellite regression: magnitude counts used to be all zeros."""
    from repro.core.payload import make_selector

    sel = make_selector("magnitude", num_arms=30, dim=4, keep_fraction=0.2)
    for _ in range(7):
        idx = sel.select()
        sel.observe(idx, jax.numpy.ones((6, 4)))
    counts = sel.selection_counts()
    assert counts.sum() == 7 * 6
    assert (counts >= 0).all() and counts.max() <= 7


def test_full_and_random_selection_counts_meaningful():
    from repro.core.payload import make_selector

    sel = make_selector("random", num_arms=30, dim=4, keep_fraction=0.5)
    for _ in range(4):
        sel.select()
    assert sel.selection_counts().sum() == 4 * 15

    full = make_selector("full", num_arms=12, dim=4)
    full.select()
    full.select()
    np.testing.assert_array_equal(full.selection_counts(),
                                  2.0 * np.ones(12))
