"""repro.analysis engine tests: each rule fires exactly where the fixture
corpus says it should, suppressions and the baseline are honored, the CLI
exits non-zero on new findings, and the repo itself lints clean."""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (
    Finding, load_baseline, load_project, run_rules, split_findings,
    write_baseline,
)
from repro.analysis.rules import (
    DtypeWidthRule, FaultCarryRule, KernelParityRule, LockGuardRule,
    PytreeCarryRule, TracedPurityRule, default_rules, rule_names,
)

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lint(case, rules):
    root = os.path.join(FIXTURES, case)
    project = load_project([root], root=root, excludes=("__pycache__",))
    return run_rules(project, rules)


def _at(findings, rule, path_tail, line):
    hits = [f for f in findings
            if f.rule == rule and f.path.endswith(path_tail)
            and f.line == line]
    return hits


# --------------------------------------------------------------------- #
# traced-purity
# --------------------------------------------------------------------- #
def test_purity_flags_every_planted_violation():
    findings = _lint("purity_bad", [TracedPurityRule()])
    got = {(f.path.rsplit("/", 1)[-1], f.line) for f in findings}
    assert ("traced.py", 14) in got, "host clock in jitted fn"
    assert ("traced.py", 15) in got, "host RNG in jitted fn"
    assert ("traced.py", 16) in got, "free-variable .append in jitted fn"
    assert ("traced.py", 22) in got, "global declaration in jitted fn"
    assert ("traced.py", 31) in got, \
        "scan body discovered via lax.scan(chunk, ...) by-name root"
    assert ("cb.py", 6) in got, "unsanctioned io_callback (module-wide)"
    assert all(f.rule == "traced-purity" for f in findings)


def test_purity_silent_on_pure_code_and_sanctioned_callback():
    assert _lint("purity_good", [TracedPurityRule()]) == []


# --------------------------------------------------------------------- #
# pytree-carry
# --------------------------------------------------------------------- #
def test_pytree_flags_scalar_callable_and_transitive_fields():
    findings = _lint("pytree_fix", [PytreeCarryRule()])
    lines = sorted(f.line for f in findings)
    assert lines == [16, 26, 27, 28], [f.render() for f in findings]
    by_line = {f.line: f.message for f in findings}
    assert "InnerBuf" in by_line[16], "transitive closure via NestState.buf"
    assert "`int`" in by_line[26]
    assert "Callable" in by_line[27]
    assert "`str`" in by_line[28]


# --------------------------------------------------------------------- #
# kernel-parity
# --------------------------------------------------------------------- #
def test_parity_flags_missing_oracle_and_missing_test():
    findings = _lint("parity_fix", [KernelParityRule()])
    assert len(findings) == 2, [f.render() for f in findings]
    missing_oracle = _at(findings, "kernel-parity", "widget.py", 10)
    assert missing_oracle and "uncovered_op_ref" in missing_oracle[0].message
    missing_test = _at(findings, "kernel-parity", "widget.py", 14)
    assert missing_test and "not exercised" in missing_test[0].message
    # covered_op (oracle + test) and _private_helper produce nothing
    assert not [f for f in findings if f.line not in (10, 14)]


# --------------------------------------------------------------------- #
# dtype-width
# --------------------------------------------------------------------- #
def test_dtype_strict_scope_covers_wire_modules_and_traced_functions():
    findings = _lint("dtype_fix", [DtypeWidthRule()])
    got = {(f.path.rsplit("/", 1)[-1], f.line) for f in findings}
    assert ("codec.py", 6) in got, "bare np.array in wire module"
    assert ("codec.py", 7) in got, ".float64 reference"
    assert ("codec.py", 8) in got, "dtype=float"
    assert ("driver.py", 8) in got, "bare np.ones inside jitted fn"
    # host scope: bare asarray in summarize() must NOT fire
    assert not [f for f in findings
                if f.path.endswith("driver.py") and f.line > 9], \
        [f.render() for f in findings]
    assert len(got) == 4


# --------------------------------------------------------------------- #
# lock-guard
# --------------------------------------------------------------------- #
def test_locks_flag_unguarded_access_only():
    findings = _lint("locks_fix", [LockGuardRule()])
    assert sorted(f.line for f in findings) == [18, 21], \
        [f.render() for f in findings]
    assert "write" in _at(findings, "lock-guard", "engine.py", 18)[0].message
    assert "read" in _at(findings, "lock-guard", "engine.py", 21)[0].message


# --------------------------------------------------------------------- #
# fault-carry
# --------------------------------------------------------------------- #
def test_fault_carry_flags_module_state_and_swallowed_excepts():
    findings = _lint("faultcarry_fix", [FaultCarryRule()])
    got = {(f.path.rsplit("/", 1)[-1], f.line) for f in findings}
    assert ("sched.py", 5) in got, "module-level list"
    assert ("sched.py", 6) in got, "module-level dict"
    assert ("sched.py", 7) in got, "module-level set() call"
    assert ("sched.py", 11) in got, "global declaration"
    assert ("eng.py", 24) in got, "swallowing except without counter"
    # compliant constructs stay silent: the tuple constant, function-local
    # list, counter-incrementing handler and re-raising handler
    assert len(got) == 5, [f.render() for f in findings]
    assert all(f.rule == "fault-carry" for f in findings)


def test_fault_carry_counter_recognition():
    """Subscript counters (`d[\"total\"] += 1`) and attribute counters
    (`self._publish_failures += 1`) both satisfy the except contract."""
    import ast as ast_mod

    from repro.analysis.rules.faults import _handler_surfaces

    def handler_of(code):
        tree = ast_mod.parse(code)
        return next(n for n in ast_mod.walk(tree)
                    if isinstance(n, ast_mod.ExceptHandler))

    ok = "try:\n    x()\nexcept OSError:\n    _failures['total'] += 1\n"
    assert _handler_surfaces(handler_of(ok))
    ok2 = "try:\n    x()\nexcept OSError:\n    self.shed_count += 1\n"
    assert _handler_surfaces(handler_of(ok2))
    bad = "try:\n    x()\nexcept OSError:\n    pass\n"
    assert not _handler_surfaces(handler_of(bad))


# --------------------------------------------------------------------- #
# suppressions + baseline
# --------------------------------------------------------------------- #
def test_inline_and_file_suppressions():
    findings = _lint("suppress_fix", [DtypeWidthRule()])
    # sup.py: A (same-line) and B (line-above) silenced; C fires; D's
    # wrong-rule suppression does not apply. supfile.py: fully silenced.
    assert [f.line for f in findings] == [7, 8], \
        [f.render() for f in findings]
    assert all(f.path.endswith("sup.py") for f in findings)


def test_baseline_grandfathers_and_reports_stale(tmp_path):
    # lock-guard findings have distinct messages -> distinct baseline keys
    findings = _lint("locks_fix", [LockGuardRule()])
    assert len(findings) == 2
    path = str(tmp_path / "baseline.json")
    write_baseline(path, findings[:1])
    keys = load_baseline(path) + ["lock-guard::gone.py::never fires"]
    new, old, stale = split_findings(findings, keys)
    assert [f.key() for f in new] == [findings[1].key()]
    assert [f.key() for f in old] == [findings[0].key()]
    assert stale == ["lock-guard::gone.py::never fires"]


def test_baseline_key_is_line_number_free():
    f1 = Finding(rule="r", path="p.py", line=10, message="m")
    f2 = Finding(rule="r", path="p.py", line=99, message="m")
    assert f1.key() == f2.key()


# --------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------- #
def _run_cli(*args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=cwd or REPO)


def test_cli_fails_on_violations_with_json_report():
    case = os.path.join(FIXTURES, "locks_fix")
    proc = _run_cli(case, "--root", case, "--no-baseline", "--json",
                    "--no-default-excludes")
    assert proc.returncode == 1, proc.stdout + proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["ok"] is False
    assert {f["rule"] for f in doc["new"]} == {"lock-guard"}


def test_cli_passes_on_clean_tree():
    case = os.path.join(FIXTURES, "purity_good")
    proc = _run_cli(case, "--root", case, "--no-baseline",
                    "--no-default-excludes")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK: no new findings" in proc.stdout


def test_cli_lists_rules_and_rejects_unknown_disable():
    proc = _run_cli("--list-rules")
    assert proc.returncode == 0
    assert set(proc.stdout.split()) == set(rule_names())
    case = os.path.join(FIXTURES, "purity_good")
    proc = _run_cli(case, "--disable", "no-such-rule")
    assert proc.returncode == 2


def test_cli_disable_silences_a_rule():
    case = os.path.join(FIXTURES, "locks_fix")
    proc = _run_cli(case, "--root", case, "--no-baseline",
                    "--no-default-excludes", "--disable", "lock-guard")
    assert proc.returncode == 0, proc.stdout + proc.stderr


# --------------------------------------------------------------------- #
# the repo's own sources lint clean (same invocation CI runs)
# --------------------------------------------------------------------- #
def test_repo_lints_clean_with_all_rules():
    project = load_project(
        [os.path.join(REPO, d) for d in ("src", "tests", "benchmarks")],
        root=REPO)
    findings = run_rules(project, default_rules())
    baseline = load_baseline(os.path.join(REPO, "analysis_baseline.json"))
    new, _, _ = split_findings(findings, baseline)
    assert new == [], "\n".join(f.render() for f in new)


# --------------------------------------------------------------------- #
# shape-lint
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_shape_lint_clean_on_small_grid():
    from repro.analysis.shapelint import run_shape_lint

    errs = run_shape_lint(grid=[(32, 4, 4)], codecs=["fp32", "int8"],
                          strategies=["bts"])
    assert errs == [], "\n".join(errs)


def test_shape_lint_reports_instead_of_raising():
    from repro.analysis.shapelint import run_shape_lint

    errs = run_shape_lint(grid=[(0, 4, 4)], codecs=["fp32"],
                          strategies=["bts"])
    assert errs and any("M=0" in e for e in errs)
