"""Observability layer: the disabled-path bit-parity contract, the round
telemetry stream, span tracing, histograms and the serving /metrics surface.

The hard contract (repro.obs docstring): with ``obs=None`` or
``ObsConfig(enabled=False)`` every telemetry hook is skipped at
Python/trace time, so trajectories are BIT-identical to a build without
the obs package — checked here for the scan, python and async engines
in-process and for the D=8 sharded engine in a fake-device subprocess.
With ``enabled=True`` the trajectory must STILL be bit-identical (the
telemetry ops are pure observers) while the sink receives one schema-valid
round event per (rate-limited) round whose traced regret aggregates match
the host-side ``core.regret.RegretTracker`` fold.
"""
import json
import os
import pathlib
import subprocess
import sys
import threading
from dataclasses import replace

import numpy as np
import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
SRC = str(REPO_ROOT / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.federated.simulation import FLSimConfig, run_fcf_simulation  # noqa: E402
from repro.launch.mesh import fake_cpu_devices_env  # noqa: E402
from repro.obs import (  # noqa: E402
    InMemorySink, LatencyHistogram, ObsConfig, TELEMETRY_FIELDS, Tracer,
    install_tracer, rows_to_events, span, validate_round_event,
)
from repro.obs.prom import parse, validate_text  # noqa: E402
from repro.obs.trace import NullTracer, active_tracer, validate_span_event  # noqa: E402

BACKENDS = ("scan", "python", "async")


def _mini_data(seed=0, users=60, items=80):
    rng = np.random.default_rng(seed)
    train = (rng.random((users, items)) < 0.15).astype(np.float32)
    test = (rng.random((users, items)) < 0.05).astype(np.float32)
    return train, test


def _cfg(backend, **kw):
    base = dict(strategy="bts", keep_fraction=0.25, rounds=6, theta=10,
                eval_every=3, eval_users=40, seed=0, codec="int8",
                record_selections=True)
    if backend == "async":
        base["max_staleness"] = 2
    base["backend"] = backend if backend != "scan" else "scan"
    base.update(kw)
    return FLSimConfig(**base)


def _assert_bitwise(tag, a, b):
    np.testing.assert_array_equal(a.selections, b.selections,
                                  err_msg=f"{tag}: selections")
    np.testing.assert_array_equal(a.rewards, b.rewards,
                                  err_msg=f"{tag}: rewards")
    np.testing.assert_array_equal(np.asarray(a.server_state.q),
                                  np.asarray(b.server_state.q),
                                  err_msg=f"{tag}: Q")
    np.testing.assert_array_equal(np.asarray(a.server_state.opt.m),
                                  np.asarray(b.server_state.opt.m),
                                  err_msg=f"{tag}: adam m")
    assert float(a.server_state.bytes_down) == \
        float(b.server_state.bytes_down), f"{tag}: bytes_down"
    assert float(a.server_state.bytes_up) == \
        float(b.server_state.bytes_up), f"{tag}: bytes_up"
    assert a.history.series("f1") == b.history.series("f1"), \
        f"{tag}: f1 trajectory"


# --------------------------------------------------------------------- #
# the bit-parity contract (scan / python / async, in-process)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_disabled_obs_is_bit_identical(backend):
    """obs=None and ObsConfig(enabled=False) must produce the exact same
    trajectory — the disabled path adds zero ops by construction."""
    train, test = _mini_data()
    cfg = _cfg(backend)
    base = run_fcf_simulation(train, test, cfg)
    off = run_fcf_simulation(
        train, test, replace(cfg, obs=ObsConfig(enabled=False)))
    _assert_bitwise(f"{backend}/disabled", base, off)


@pytest.mark.parametrize("backend", BACKENDS)
def test_enabled_obs_preserves_trajectory_and_emits(backend):
    """Telemetry ops are pure observers: enabling them must not perturb
    the round math, and every round must land in the sink as one
    schema-valid event with monotone t and non-decreasing cum_regret."""
    train, test = _mini_data()
    cfg = _cfg(backend)
    base = run_fcf_simulation(train, test, cfg)
    sink = InMemorySink()
    on = run_fcf_simulation(
        train, test, replace(cfg, obs=ObsConfig(enabled=True, sink=sink)))
    _assert_bitwise(f"{backend}/enabled", base, on)

    events = sink.events
    assert len(events) == cfg.rounds
    for e in events:
        assert validate_round_event(e) == [], validate_round_event(e)
    ts = [e["t"] for e in events]
    assert ts == list(range(1, cfg.rounds + 1))
    cum = [e["cum_regret"] for e in events]
    assert all(b >= a for a, b in zip(cum, cum[1:])), cum
    assert all(e["collective_bytes"] == 0.0 for e in events)  # off-mesh
    assert all(e["bytes_down"] > 0 and e["bytes_up"] > 0 for e in events)
    if backend == "async":
        for e in events:
            assert 0 <= e["staleness"] <= cfg.max_staleness
            np.testing.assert_allclose(
                e["step_weight"],
                cfg.staleness_discount ** e["staleness"], rtol=1e-6)
    else:
        assert all(e["staleness"] == 0 and e["step_weight"] == 1.0
                   for e in events)


def test_telemetry_every_rate_limit():
    """telemetry_every=4 over 8 rounds -> events at t=1 (always), 4, 8."""
    train, test = _mini_data()
    sink = InMemorySink()
    cfg = _cfg("scan", rounds=8,
               obs=ObsConfig(enabled=True, sink=sink, telemetry_every=4))
    run_fcf_simulation(train, test, cfg)
    assert [e["t"] for e in sink.events] == [1, 4, 8]


def test_traced_regret_matches_host_tracker():
    """The in-scan regret fold must reproduce core.regret.RegretTracker
    (the float64 host reference) on the same selections/rewards stream."""
    from repro.core.regret import RegretTracker

    train, test = _mini_data()
    sink = InMemorySink()
    cfg = _cfg("scan", rounds=8, obs=ObsConfig(enabled=True, sink=sink))
    result = run_fcf_simulation(train, test, cfg)

    tracker = RegretTracker(num_arms=train.shape[1])
    for idx, rew in zip(result.selections, result.rewards):
        tracker.record(idx, rew)
    traced_cum = [e["cum_regret"] for e in sink.events]
    np.testing.assert_allclose(traced_cum, tracker.cumulative,
                               rtol=1e-4, atol=1e-5)
    traced_mean = [e["reward_mean"] for e in sink.events]
    np.testing.assert_allclose(traced_mean, tracker.per_round_mean,
                               rtol=1e-5, atol=1e-6)


def test_seed_sweep_rejects_enabled_obs():
    from repro.federated.simulation import run_seed_sweep

    train, test = _mini_data()
    cfg = _cfg("scan", obs=ObsConfig(enabled=True))
    with pytest.raises(ValueError, match="obs"):
        run_seed_sweep(train, test, cfg, seeds=(0, 1))


# --------------------------------------------------------------------- #
# D=8 sharded engine (fake-device subprocess, one jax init)
# --------------------------------------------------------------------- #
_SHARD_SCRIPT = r"""
from dataclasses import replace
import numpy as np
from repro.federated.simulation import FLSimConfig, run_fcf_simulation
from repro.obs import InMemorySink, ObsConfig, validate_round_event

rng = np.random.default_rng(0)
train = (rng.random((60, 80)) < 0.15).astype(np.float32)
test = (rng.random((60, 80)) < 0.05).astype(np.float32)

cfg = FLSimConfig(strategy="bts", keep_fraction=0.25, rounds=6, theta=10,
                  eval_every=3, eval_users=40, seed=0, codec="int8",
                  record_selections=True, backend="shard", mesh_shards=8)

base = run_fcf_simulation(train, test, cfg)
off = run_fcf_simulation(train, test,
                         replace(cfg, obs=ObsConfig(enabled=False)))
sink = InMemorySink()
on = run_fcf_simulation(train, test,
                        replace(cfg, obs=ObsConfig(enabled=True, sink=sink)))

for tag, other in (("disabled", off), ("enabled", on)):
    np.testing.assert_array_equal(base.selections, other.selections,
                                  err_msg=f"{tag}: selections")
    np.testing.assert_array_equal(np.asarray(base.server_state.q),
                                  np.asarray(other.server_state.q),
                                  err_msg=f"{tag}: Q")
    assert base.history.series("f1") == other.history.series("f1"), tag

events = sink.events
assert len(events) == cfg.rounds, len(events)
assert [e["t"] for e in events] == list(range(1, cfg.rounds + 1))
for e in events:
    assert validate_round_event(e) == [], validate_round_event(e)
    # the sharded engine's psum-reduced cross-device byte counter: D shards
    # each move (downlink wire + m_s*k*4 fp32 grad rows) over the mesh
    assert e["collective_bytes"] > 0, e
cum = [e["cum_regret"] for e in events]
assert all(b >= a for a, b in zip(cum, cum[1:])), cum

print("SHARD_OBS_OK rounds=%d" % len(events))
"""


@pytest.mark.subprocess
def test_shard_backend_obs_parity_and_collectives():
    """D=8 sharded engine: disabled AND enabled obs are bit-identical to
    the plain shard run; the telemetry stream reports psum-reduced
    collective bytes > 0 (it runs on a real 8-device mesh)."""
    env = fake_cpu_devices_env(8)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT],
        capture_output=True, text=True, env=env, cwd=str(REPO_ROOT),
        timeout=1200,
    )
    assert proc.returncode == 0, (
        f"shard obs subprocess failed\nstdout:\n{proc.stdout}\n"
        f"stderr:\n{proc.stderr}")
    assert "SHARD_OBS_OK rounds=6" in proc.stdout


# --------------------------------------------------------------------- #
# telemetry row/event plumbing
# --------------------------------------------------------------------- #
def test_rows_to_events_shapes_and_rate_limit():
    row = np.arange(1, len(TELEMETRY_FIELDS) + 1, dtype=np.float32)
    (event,) = rows_to_events(row)                       # single row ok
    assert event["type"] == "round" and event["t"] == 1
    rows = np.stack([row * 0 + np.arange(len(TELEMETRY_FIELDS))
                     for _ in range(3)])
    rows[:, 0] = [1, 2, 3]                               # t column
    assert [e["t"] for e in rows_to_events(rows, every=3)] == [1, 3]
    with pytest.raises(ValueError, match="fields"):
        rows_to_events(np.zeros((2, 3)))


def test_validate_round_event_rejects_bad_events():
    good = rows_to_events(
        np.arange(1, len(TELEMETRY_FIELDS) + 1, dtype=np.float32))[0]
    assert validate_round_event(good) == []
    assert validate_round_event({"type": "round"})       # missing fields
    bad_type = dict(good, type="span")
    assert any("type" in e for e in validate_round_event(bad_type))
    neg = dict(good, bytes_down=-1.0)
    assert any("bytes_down" in e for e in validate_round_event(neg))
    frac_t = dict(good, t=1.5)
    assert any("integral" in e for e in validate_round_event(frac_t))


# --------------------------------------------------------------------- #
# span tracing
# --------------------------------------------------------------------- #
def test_tracer_nested_spans_schema_and_restore(tmp_path):
    tracer = Tracer()                                    # in-memory
    prev = install_tracer(tracer)
    try:
        with span("outer", phase="train"):
            with span("inner"):
                pass
    finally:
        restored = install_tracer(prev)
    assert restored is tracer and active_tracer() is prev

    # spans close inner-first; nesting is recorded as depth + parent name
    inner, outer = tracer.events
    assert (inner["name"], inner["depth"], inner["parent"]) == \
        ("inner", 1, "outer")
    assert (outer["name"], outer["depth"], outer["parent"]) == \
        ("outer", 0, None)
    assert outer["attrs"] == {"phase": "train"}
    assert outer["dur"] >= inner["dur"] >= 0
    for e in tracer.events:
        assert validate_span_event(e) == [], validate_span_event(e)

    # file-backed tracer writes parseable JSONL
    path = tmp_path / "trace.jsonl"
    jt = Tracer(str(path))
    with jt.span("write"):
        pass
    jt.close()
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == 1 and validate_span_event(lines[0]) == []


def test_null_tracer_span_is_shared_noop():
    """The default tracer hands back ONE reusable null context — the cost
    of an instrumented call site with tracing off is near zero."""
    nt = NullTracer()
    assert nt.span("a") is nt.span("b", attr=1)
    with nt.span("a"):
        pass                                             # no-op, no error


# --------------------------------------------------------------------- #
# latency histogram properties
# --------------------------------------------------------------------- #
@settings(deadline=None, max_examples=20)
@given(n=st.integers(min_value=1, max_value=200),
       scale=st.floats(min_value=1e-5, max_value=10.0))
def test_property_histogram_quantiles_bounded_and_monotone(n, scale):
    rng = np.random.default_rng(n * 7919 + int(scale * 100))
    vals = scale * rng.random(n)
    h = LatencyHistogram.from_values(vals)
    assert h.total == n
    qs = h.quantiles([0.0, 0.25, 0.5, 0.9, 0.99, 1.0])
    assert all(b >= a for a, b in zip(qs, qs[1:])), qs
    assert qs[0] >= float(vals.min()) - 1e-12
    assert qs[-1] <= float(vals.max()) + 1e-12
    # bucket resolution: every quantile lies within one geometric bucket
    # (~9% relative) of an actually-recorded value — the HDR guarantee.
    # (np.median-style midpoint interpolation is a DIFFERENT definition and
    # can sit a whole order statistic away at small n; the shared-definition
    # point of obs.hist is exactly that all reporters agree on this one.)
    for qv in qs:
        nearest = float(np.min(np.abs(vals - qv)))
        assert nearest <= qv * (2 ** (1 / 8) - 1) + 2 * h.min_value, \
            (qv, nearest)


@settings(deadline=None, max_examples=20)
@given(na=st.integers(min_value=0, max_value=100),
       nb=st.integers(min_value=0, max_value=100))
def test_property_histogram_merge_is_exact(na, nb):
    rng = np.random.default_rng(na * 1000 + nb)
    a_vals, b_vals = rng.random(na) * 0.1, rng.random(nb) * 10.0
    a = LatencyHistogram.from_values(a_vals)
    b = LatencyHistogram.from_values(b_vals)
    merged = a.merge(b)
    both = LatencyHistogram.from_values(np.concatenate([a_vals, b_vals]))
    np.testing.assert_array_equal(merged.counts, both.counts)
    assert merged.total == na + nb
    np.testing.assert_allclose(merged.sum, both.sum, rtol=1e-12)
    if na + nb:
        assert merged.quantile(0.5) == both.quantile(0.5)
    # merge leaves the operands untouched
    assert a.total == na and b.total == nb


def test_histogram_edge_cases():
    h = LatencyHistogram()
    assert h.total == 0 and np.isnan(h.quantile(0.5))
    with pytest.raises(ValueError):
        h.record(-1.0)
    with pytest.raises(ValueError):
        h.record(float("inf"))
    with pytest.raises(ValueError):
        h.quantile(1.5)
    h.record(0.01)
    assert h.quantile(0.0) == h.quantile(1.0) == 0.01    # exact envelope
    other = LatencyHistogram(min_value=1e-3)
    with pytest.raises(ValueError, match="geometry"):
        h.merge(other)
    # out-of-range values land in the first / overflow buckets
    h2 = LatencyHistogram.from_values([1e-9, 5e3])
    assert h2.counts[0] == 1 and h2.counts[-1] == 1


# --------------------------------------------------------------------- #
# MetricLogger on the obs sinks (satellite regression)
# --------------------------------------------------------------------- #
def test_metric_logger_csv_stable_columns_and_restval(tmp_path):
    """Heterogeneous rows: column order is a function of the key SET only
    (front keys, then sorted), and missing cells are explicit ''."""
    import csv

    from repro.utils.logging import MetricLogger

    path = tmp_path / "m.csv"
    log = MetricLogger(str(path))
    log.log(1, loss=0.5)
    log.log(2, f1=0.3, precision=0.2)                    # eval-only keys
    log.log(3, loss=0.4)
    log.to_csv()
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        header = reader.fieldnames
        rows = list(reader)
    assert header == ["step", "wall_s", "f1", "loss", "precision"]
    assert rows[0]["f1"] == "" and rows[0]["loss"] == "0.5"
    assert rows[1]["loss"] == "" and rows[1]["f1"] == "0.3"
    assert [r["step"] for r in rows] == ["1", "2", "3"]

    # logging the keys in a different order yields the same header
    log2 = MetricLogger(str(tmp_path / "m2.csv"))
    log2.log(1, precision=0.2, f1=0.3)
    log2.log(2, loss=0.5)
    log2.to_csv()
    with open(tmp_path / "m2.csv", newline="") as f:
        assert csv.DictReader(f).fieldnames == header

    stream_only = type("S", (), {"emit": lambda self, e: None,
                                 "close": lambda self: None})()
    with pytest.raises(ValueError, match="events"):
        MetricLogger(sink=stream_only)


# --------------------------------------------------------------------- #
# serving /metrics surface
# --------------------------------------------------------------------- #
def _tiny_engine(obs):
    import jax.numpy as jnp

    from repro.compress import CodecConfig
    from repro.serve import ServingEngine, ServingModel

    rng = np.random.default_rng(3)
    q = jnp.asarray(0.1 * rng.standard_normal((64, 8)), jnp.float32)
    model = ServingModel.from_dense(CodecConfig(name="int8"), q)
    return ServingEngine(model, buckets=(4,), top_n=5, obs=obs)


def test_serving_metrics_parse_and_counters():
    from repro.obs.check import REQUIRED_SERVE_FAMILIES

    engine = _tiny_engine(ObsConfig(enabled=True))
    rng = np.random.default_rng(5)
    p = rng.standard_normal((4, 8)).astype(np.float32)
    for _ in range(3):
        engine.recommend(p)
    text = engine.metrics()
    assert validate_text(text, require=REQUIRED_SERVE_FAMILIES) == []
    fams = parse(text)
    assert fams["frs_serve_requests_total"]["samples"][
        "frs_serve_requests_total"][0][1] == 3.0
    assert fams["frs_serve_users_total"]["samples"][
        "frs_serve_users_total"][0][1] == 12.0
    assert fams["frs_serve_queue_depth"]["samples"][
        "frs_serve_queue_depth"][0][1] == 0.0
    hist = fams["frs_serve_latency_seconds"]["samples"]
    counts = {tuple(sorted(l.items())): v
              for l, v in hist["frs_serve_latency_seconds_count"]}
    assert sum(counts.values()) == 3.0                   # one timed chunk/req
    assert engine.latency_histogram().total == 3


def test_serving_metrics_without_obs_still_render():
    """metrics() must expose the required families even with obs off —
    latency histograms just stay empty (no timing syncs on the read path)."""
    from repro.obs.check import REQUIRED_SERVE_FAMILIES

    engine = _tiny_engine(None)
    engine.recommend(np.zeros((2, 8), np.float32))
    text = engine.metrics()
    assert validate_text(text, require=REQUIRED_SERVE_FAMILIES) == []
    assert engine.latency_histogram().total == 0
    fams = parse(text)
    assert fams["frs_serve_requests_total"]["samples"][
        "frs_serve_requests_total"][0][1] == 1.0


def test_serving_metrics_monotone_under_concurrent_readers():
    """Counters never move backwards across scrapes racing recommend()."""
    engine = _tiny_engine(ObsConfig(enabled=True))
    rng = np.random.default_rng(11)
    p = rng.standard_normal((4, 8)).astype(np.float32)
    stop = threading.Event()
    errors = []

    def scrape():
        last = -1.0
        while not stop.is_set():
            try:
                fams = parse(engine.metrics())
                cur = fams["frs_serve_requests_total"]["samples"][
                    "frs_serve_requests_total"][0][1]
            except Exception as exc:          # malformed mid-race scrape
                errors.append(exc)
                return
            if cur < last:
                errors.append(
                    AssertionError(f"requests_total {cur} < {last}"))
                return
            last = cur

    readers = [threading.Thread(target=scrape) for _ in range(2)]
    for r in readers:
        r.start()
    try:
        for _ in range(20):
            engine.recommend(p)
    finally:
        stop.set()
        for r in readers:
            r.join(timeout=30)
    assert not errors, errors
    assert engine.stats().requests == 20
