"""Tests for payload selection strategies and the paper's payload accounting."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.payload import PayloadSelector, make_selector, payload_bytes


def test_payload_bytes_reproduces_table1():
    """Paper Table 1: K=20, float64. 3912 items -> ~625KB; 1M -> ~160MB."""
    assert payload_bytes(3912, 20, 64) == 3912 * 20 * 8          # 625,920 B
    assert payload_bytes(3912, 20, 64) / 1e3 == pytest.approx(625.9, abs=0.1)
    assert payload_bytes(10_000, 20, 64) / 1e6 == pytest.approx(1.6, abs=0.01)
    assert payload_bytes(100_000, 20, 64) / 1e6 == pytest.approx(16.0, abs=0.1)
    assert payload_bytes(1_000_000, 20, 64) / 1e6 == pytest.approx(160.0, abs=1)
    assert payload_bytes(10_000_000, 20, 64) / 1e9 == pytest.approx(1.6, abs=0.01)


@pytest.mark.parametrize("strategy", ["bts", "random", "magnitude"])
def test_selector_counts_and_uniqueness(strategy):
    sel = make_selector(strategy, num_arms=100, dim=8, keep_fraction=0.25, seed=3)
    idx = np.asarray(sel.select())
    assert idx.shape == (25,)
    assert len(np.unique(idx)) == 25
    assert idx.min() >= 0 and idx.max() < 100
    rewards = sel.observe(jnp.asarray(idx), jnp.ones((25, 8)))
    assert rewards.shape == (25,)


def test_full_strategy_selects_everything():
    sel = make_selector("full", num_arms=42, dim=4)
    np.testing.assert_array_equal(np.asarray(sel.select()), np.arange(42))
    assert sel.reduction_pct == 0.0


def test_reduction_pct():
    sel = make_selector("random", num_arms=1000, dim=4, keep_fraction=0.1)
    assert sel.reduction_pct == pytest.approx(90.0)
    # the simulation transmits float32, so the selector's accounting defaults
    # to dtype_bits=32 (the bare payload_bytes default stays at the paper's
    # Table-1 float64 convention)
    assert sel.round_payload_bytes == payload_bytes(100, 4, 32)
    assert sel.full_payload_bytes == payload_bytes(1000, 4, 32)
    assert sel.round_payload_bytes == 100 * 4 * 4


def test_round_payload_bytes_matches_transmitted_dtype():
    """Regression (payload-accounting fix): round_payload_bytes must equal
    the bytes the server actually moves per round for the simulated float32
    payload — it used to report 2x (float64 default)."""
    import jax.numpy as jnp

    sel = make_selector("random", num_arms=64, dim=8, keep_fraction=0.5)
    idx = sel.select()
    q_star = jnp.zeros((64, 8), jnp.float32)[idx]
    assert sel.round_payload_bytes == q_star.size * q_star.dtype.itemsize
    # opting back into the paper's float64 accounting stays possible
    sel64 = make_selector("random", num_arms=64, dim=8, keep_fraction=0.5,
                          dtype_bits=64)
    assert sel64.round_payload_bytes == 2 * sel.round_payload_bytes


def test_bad_strategy_raises():
    with pytest.raises(ValueError):
        PayloadSelector(num_arms=10, num_select=5, dim=2, strategy="nope")


def test_magnitude_strategy_tracks_gradient_mass():
    sel = make_selector("magnitude", num_arms=20, dim=3, keep_fraction=0.25, seed=0)
    idx = sel.select()
    grads = jnp.zeros((5, 3)).at[2].set(100.0)   # arm idx[2] gets huge gradients
    sel.observe(idx, grads)
    big_arm = int(idx[2])
    nxt = np.asarray(sel.select())
    assert big_arm in nxt


def test_random_selection_changes_across_rounds():
    sel = make_selector("random", num_arms=500, dim=2, keep_fraction=0.1, seed=1)
    a = set(np.asarray(sel.select()).tolist())
    b = set(np.asarray(sel.select()).tolist())
    assert a != b


def test_bts_selector_end_to_end_concentrates():
    """Feed rewards that favour arms 0..9; selection frequency must follow."""
    sel = make_selector("bts", num_arms=40, dim=4, keep_fraction=0.25,
                        tau_theta=1.0, gamma=0.9, seed=7)
    hits_good = 0
    rng = np.random.default_rng(0)
    for t in range(300):
        idx = sel.select()
        idx_np = np.asarray(idx)
        # synthetic gradients: good arms (0..9) have persistent large gradients
        g = rng.standard_normal((10, 4)).astype(np.float32) * 0.01
        g[idx_np < 10] += 1.0
        sel.observe(idx, jnp.asarray(g))
        if t >= 250:
            hits_good += (idx_np < 10).sum()
    # in the last 50 rounds, good arms should clearly beat the 25% base rate
    # a uniform selector would give (10/40)*10 = 2.5 hits/round = 0.25
    assert hits_good / (50 * 10) > 0.45
