"""Correctness of the §Perf distributed-LSE decode path: the KV-time-
sharded attention (shard_map over a 16-device mesh) must produce the same
logits as the plain single-device decode.

Runs in a subprocess because the sharded path needs
XLA_FLAGS=--xla_force_host_platform_device_count and jax pins the device
count at first init (the main pytest process must keep seeing 1 device).
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config
from repro.launch.sharding import input_pspecs, param_pspecs, to_shardings
from repro.models import lm
from repro.utils import hints

cfg = get_config("qwen3-4b").reduced(num_layers=2, d_model=256, vocab=1024)
key = jax.random.PRNGKey(0)
params = lm.init_lm_params(cfg, key)
B, T = 4, 64
cache = lm.init_decode_cache(cfg, B, T)

# prefill a few tokens the plain way so the cache is non-trivial
tok0 = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab_size, jnp.int32)
tok1 = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab_size, jnp.int32)
logits_a, cache_a = lm.decode_step(params, cfg, cache, tok0, jnp.asarray(0, jnp.int32))
ref_logits, _ = lm.decode_step(params, cfg, cache_a, tok1, jnp.asarray(1, jnp.int32))

mesh = jax.make_mesh((2, 8), ("data", "model"))
with mesh, hints.batch_axes(("data",), mesh=mesh, kv_time_shard=True):
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, cfg, c, t, pos))
    logits_b, cache_b = step(params, cache, tok0, jnp.asarray(0, jnp.int32))
    sh_logits, _ = step(params, cache_b, tok1, jnp.asarray(1, jnp.int32))

np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(sh_logits),
                           rtol=2e-4, atol=2e-4)
print("KV-SHARDED-DECODE-OK")
"""


@pytest.mark.slow
def test_kv_sharded_decode_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "KV-SHARDED-DECODE-OK" in out.stdout, (
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}")
