"""Sharded round engine: shard_map == single-device scan, bit for bit.

The tentpole contract of the sharded engine: running the FL round
data-parallel over a ("data",) device mesh (row-sharded tables, one cohort
block per device, collective payload movement, ordered-psum gradient
reduction) must reproduce the single-device ``backend="scan"`` trajectory —
selections, Q, Adam moments, byte counters — exactly, for every strategy,
with the fp32 and int8 codecs. ``cohort_shards=D`` pins the scan reference
to the same client-phase block structure (the float semantics of a round are
a function of the block structure only; see ``server_round_step``).

Multi-device CPU meshes require ``--xla_force_host_platform_device_count``
to be set before jax initializes, so the D=8 parity matrix runs in one
subprocess; single-device properties (D=1 == plain scan, config validation,
pspec rules) run in-process.
"""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
SRC = str(REPO_ROOT / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.launch.mesh import fake_cpu_devices_env  # noqa: E402

STRATEGIES = ("bts", "random", "full", "magnitude")


def _mini_data(seed=0, users=60, items=80):
    rng = np.random.default_rng(seed)
    train = (rng.random((users, items)) < 0.15).astype(np.float32)
    test = (rng.random((users, items)) < 0.05).astype(np.float32)
    return train, test


# --------------------------------------------------------------------- #
# D=8 parity matrix (subprocess with 8 fake CPU devices)
# --------------------------------------------------------------------- #
_PARITY_SCRIPT = r"""
from dataclasses import replace
import numpy as np
from repro.federated.simulation import FLSimConfig, run_fcf_simulation

rng = np.random.default_rng(0)
train = (rng.random((60, 80)) < 0.15).astype(np.float32)
test = (rng.random((60, 80)) < 0.05).astype(np.float32)

def run_pair(strategy, codec, shards):
    cfg = FLSimConfig(strategy=strategy, keep_fraction=0.25, rounds=6,
                      theta=10, eval_every=3, eval_users=40, seed=0,
                      codec=codec, record_selections=True)
    scan = run_fcf_simulation(train, test, replace(cfg, cohort_shards=shards))
    shard = run_fcf_simulation(
        train, test, replace(cfg, backend="shard", mesh_shards=shards))
    return scan, shard

def assert_bitwise(tag, scan, shard):
    np.testing.assert_array_equal(scan.selections, shard.selections,
                                  err_msg=f"{tag}: selections")
    np.testing.assert_array_equal(scan.rewards, shard.rewards,
                                  err_msg=f"{tag}: rewards")
    np.testing.assert_array_equal(np.asarray(scan.server_state.q),
                                  np.asarray(shard.server_state.q),
                                  err_msg=f"{tag}: Q")
    np.testing.assert_array_equal(np.asarray(scan.server_state.opt.m),
                                  np.asarray(shard.server_state.opt.m),
                                  err_msg=f"{tag}: adam m")
    assert float(scan.server_state.bytes_down) == \
        float(shard.server_state.bytes_down), f"{tag}: bytes_down"
    assert float(scan.server_state.bytes_up) == \
        float(shard.server_state.bytes_up), f"{tag}: bytes_up"
    assert scan.history.series("f1") == shard.history.series("f1"), \
        f"{tag}: f1 trajectory"

checked = 0
# the hard bit-parity contract: every strategy x {fp32, int8} at D=8
for strategy in ("bts", "random", "full", "magnitude"):
    for codec in ("fp32", "int8"):
        scan, shard = run_pair(strategy, codec, 8)
        assert_bitwise(f"{strategy}/{codec}/D=8", scan, shard)
        checked += 1

# D=1 sharded == the untouched default scan engine, bit for bit
for codec in ("fp32", "int8"):
    scan, shard = run_pair("bts", codec, 1)
    assert_bitwise(f"bts/{codec}/D=1", scan, shard)
    checked += 1

# int4/topk: selections identical; trajectories agree to contraction ulps
# (XLA:CPU FMA-choice inside their dequant fusions — see server_round_step)
for codec in ("int4", "topk"):
    scan, shard = run_pair("bts", codec, 8)
    np.testing.assert_array_equal(scan.selections, shard.selections)
    np.testing.assert_allclose(np.asarray(scan.server_state.q),
                               np.asarray(shard.server_state.q),
                               rtol=1e-5, atol=1e-6)
    checked += 1

print(f"SHARDED_PARITY_OK checked={checked}")
"""


@pytest.mark.subprocess
@pytest.mark.parametrize("devices", [8])
def test_sharded_matches_scan_bitwise_all_strategies(devices):
    """All four strategies x {fp32, int8} at D=8 + the D=1 identity, in a
    subprocess seeded with fake CPU devices (one process, one jax init)."""
    env = fake_cpu_devices_env(devices)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _PARITY_SCRIPT],
        capture_output=True, text=True, env=env, cwd=str(REPO_ROOT),
        timeout=1200,
    )
    assert proc.returncode == 0, (
        f"parity subprocess failed\nstdout:\n{proc.stdout}\n"
        f"stderr:\n{proc.stderr}")
    assert "SHARDED_PARITY_OK" in proc.stdout
    assert "checked=12" in proc.stdout


# --------------------------------------------------------------------- #
# in-process properties (single device)
# --------------------------------------------------------------------- #
def test_shard_backend_single_device_matches_scan():
    from dataclasses import replace

    from repro.federated.simulation import FLSimConfig, run_fcf_simulation

    train, test = _mini_data()
    cfg = FLSimConfig(strategy="bts", keep_fraction=0.25, rounds=6, theta=10,
                      eval_every=3, eval_users=40, seed=0, codec="int8",
                      record_selections=True)
    scan = run_fcf_simulation(train, test, cfg)
    shard = run_fcf_simulation(
        train, test, replace(cfg, backend="shard", mesh_shards=1))
    np.testing.assert_array_equal(scan.selections, shard.selections)
    np.testing.assert_array_equal(np.asarray(scan.server_state.q),
                                  np.asarray(shard.server_state.q))
    assert scan.history.series("f1") == shard.history.series("f1")
    assert (scan.bytes_down, scan.bytes_up) == \
        (shard.bytes_down, shard.bytes_up)


def test_cohort_blocking_is_scan_python_consistent():
    """cohort_shards > 1 (padded blocks included) keeps scan == python."""
    from dataclasses import replace

    from repro.federated.simulation import FLSimConfig, run_fcf_simulation

    train, test = _mini_data()
    # theta=10 over 4 blocks -> blocks of 3 with 2 padded users
    cfg = FLSimConfig(strategy="bts", keep_fraction=0.25, rounds=6, theta=10,
                      eval_every=3, eval_users=40, seed=0, cohort_shards=4,
                      record_selections=True)
    scan = run_fcf_simulation(train, test, cfg)
    py = run_fcf_simulation(train, test, replace(cfg, backend="python"))
    np.testing.assert_array_equal(scan.selections, py.selections)
    np.testing.assert_array_equal(np.asarray(scan.server_state.q),
                                  np.asarray(py.server_state.q))


def test_cohort_blocking_stays_numerically_close_to_unblocked():
    """Blocking changes the gradient summation order (ulp-level), never the
    math: trajectories at C=1 and C=4 agree to float tolerance."""
    from dataclasses import replace

    from repro.federated.simulation import FLSimConfig, run_fcf_simulation

    train, test = _mini_data()
    cfg = FLSimConfig(strategy="random", keep_fraction=0.25, rounds=6,
                      theta=10, eval_every=3, eval_users=40, seed=0)
    r1 = run_fcf_simulation(train, test, cfg)
    r4 = run_fcf_simulation(train, test, replace(cfg, cohort_shards=4))
    np.testing.assert_allclose(np.asarray(r1.server_state.q),
                               np.asarray(r4.server_state.q),
                               rtol=1e-4, atol=1e-5)


def test_shard_backend_validates_divisibility_and_devices():
    from dataclasses import replace

    from repro.federated.simulation import FLSimConfig, run_fcf_simulation

    train, test = _mini_data()           # 80 items
    cfg = FLSimConfig(strategy="random", keep_fraction=0.25, rounds=2,
                      theta=10, eval_every=2, eval_users=20, seed=0,
                      backend="shard")
    # 3 does not divide 80 rows -> divisibility guard (checked before the
    # mesh is built, so it fires even on a single-device host)
    with pytest.raises(ValueError, match="divide evenly"):
        run_fcf_simulation(train, test, replace(cfg, mesh_shards=3))
    # 16 divides 80, but this host has no 16-device mesh
    with pytest.raises(ValueError, match="devices"):
        run_fcf_simulation(train, test, replace(cfg, mesh_shards=16))
    with pytest.raises(ValueError, match="unknown|backend|one of"):
        run_fcf_simulation(train, test, replace(cfg, backend="bogus"))


def test_fcf_state_pspecs_shards_only_row_tables():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.cf.server import server_init
    from repro.compress import CodecConfig
    from repro.core.selector import SelectorConfig
    from repro.launch.sharding import fcf_state_pspecs

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (40, 8))
    sel_cfg = SelectorConfig(strategy="bts", num_arms=40, num_select=10, dim=8)
    state = server_init(q, sel_cfg, key=key,
                        codec_cfg=CodecConfig(name="topk"))
    specs = fcf_state_pspecs(state)
    assert specs.q == P("data", None)
    assert specs.opt.m == P("data", None)
    assert specs.opt.v == P("data", None)
    assert specs.opt.t == P()                    # (M,) vector: replicated
    assert specs.sel.reward.v == P("data", None)
    assert specs.sel.reward.prev_grad == P("data", None)
    assert specs.sel.bts.counts == P()           # (M,) posterior: replicated
    assert specs.codec == P("data", None)        # topk EF residual
    assert specs.key == P() and specs.t == P()


def test_fake_cpu_devices_env_replaces_previous_flag():
    env = fake_cpu_devices_env(4, env={"XLA_FLAGS": (
        "--xla_foo=1 --xla_force_host_platform_device_count=2")})
    assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
    assert "device_count=2" not in env["XLA_FLAGS"]
    assert "--xla_foo=1" in env["XLA_FLAGS"]
