"""Threaded stress test for the ServingEngine swap/read/metrics contract.

Writers hammer ``swap`` while readers hammer ``recommend``/``stats``/
``metrics``. Each published table is a constant-fill whose value encodes
its publish sequence number, so every score a reader gets back names
exactly one published model — a torn read (scoring against a mix of two
tables, or a model/version pair from different swaps) produces a score no
single publish could. Versions must be strictly monotone across swaps and
non-decreasing from any single observer's point of view.
"""
import re
import threading

import jax.numpy as jnp
import numpy as np

from repro.compress import CodecConfig
from repro.serve import LoadShedError, ServingEngine, ServingModel

M, K, TOP_N = 32, 8, 3
N_WRITERS, SWAPS_PER_WRITER = 2, 25
N_READERS, READS_PER_READER = 4, 40


def _fill_model(seq: int) -> ServingModel:
    """Constant-fill table: every score row equals (seq + 1) * K."""
    q = jnp.full((M, K), float(seq + 1), jnp.float32)
    return ServingModel.from_dense(CodecConfig(name="fp32"), q)


def test_concurrent_swap_read_metrics_consistency():
    engine = ServingEngine(_fill_model(0), buckets=(4,), top_n=TOP_N,
                           block_m=32)
    published = {1.0}               # constant fills already swapped in
    published_lock = threading.Lock()
    stop = threading.Event()
    errors = []
    swap_versions = [[] for _ in range(N_WRITERS)]

    def writer(wid):
        try:
            for i in range(SWAPS_PER_WRITER):
                seq = wid * SWAPS_PER_WRITER + i + 1
                with published_lock:
                    # record BEFORE the swap so a reader can never observe
                    # a fill value absent from `published`
                    published.add(float(seq + 1))
                installed = engine.swap(_fill_model(seq))
                swap_versions[wid].append(installed.version)
        except Exception as e:      # noqa: BLE001 — surfaced by the join
            errors.append(("writer", wid, e))
        finally:
            stop.set()

    def reader(rid):
        try:
            p = jnp.ones((2, K), jnp.float32)
            last_version = -1
            last_installs = -1
            for i in range(READS_PER_READER):
                vals, ids = engine.recommend(p)
                arr = np.asarray(vals)
                assert arr.shape == (2, TOP_N)
                # constant-fill model: every score in the batch identical
                assert np.all(arr == arr[0, 0]), \
                    f"torn read: mixed scores {arr}"
                fill = arr[0, 0] / K
                with published_lock:
                    assert fill in published, \
                        f"score fill {fill} was never published"
                s = engine.stats()
                assert s.version >= last_version, \
                    f"version went backwards: {last_version} -> {s.version}"
                assert s.installs >= last_installs
                last_version, last_installs = s.version, s.installs
                if i % 8 == 0:
                    text = engine.metrics()
                    ver = int(float(re.search(
                        r"^frs_serve_model_version (\S+)$", text,
                        re.MULTILINE).group(1)))
                    assert ver >= last_version - 1  # scrape may pre-date s
        except Exception as e:      # noqa: BLE001
            errors.append(("reader", rid, e))

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(N_WRITERS)]
    threads += [threading.Thread(target=reader, args=(r,))
                for r in range(N_READERS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not any(t.is_alive() for t in threads), "stress threads hung"
    assert errors == [], errors

    # per-writer versions strictly increase; across writers all distinct
    # (every swap bumps under the lock — two swaps can never share one)
    all_versions = []
    for vs in swap_versions:
        assert vs == sorted(vs) and len(set(vs)) == len(vs)
        all_versions.extend(vs)
    assert len(set(all_versions)) == len(all_versions)

    stats = engine.stats()
    assert stats.installs == N_WRITERS * SWAPS_PER_WRITER
    assert stats.requests == N_READERS * READS_PER_READER
    assert stats.users == 2 * N_READERS * READS_PER_READER
    assert stats.version == max(all_versions)

    # final scrape reflects the settled counters exactly
    text = engine.metrics()
    assert f"frs_serve_installs_total {float(stats.installs)}" in text \
        or f"frs_serve_installs_total {stats.installs}" in text


class _ExplodingState:
    """A ServerState stand-in whose snapshot access always raises."""

    @property
    def snapshots(self):
        raise RuntimeError("simulated publish-path failure")


def test_swap_under_failed_install_never_tears(tmp_path):
    """Readers racing a FAILING install must keep the old model in full.

    A publisher hook whose install path raises (every retry) runs
    concurrently with readers; every read must score against the intact
    pre-failure table at the pre-failure version — never a torn or
    partially-installed state — and the engine must count the failures
    instead of propagating them. A subsequent good swap then goes live.
    """
    engine = ServingEngine(_fill_model(0), buckets=(4,), top_n=TOP_N,
                           block_m=32, publish_max_retries=1,
                           publish_backoff_s=0.001)
    v0 = engine.stats().version
    hook = engine.publisher()
    stop = threading.Event()
    errors = []

    def reader(rid):
        try:
            p = jnp.ones((2, K), jnp.float32)
            while not stop.is_set():
                vals, _ = engine.recommend(p)
                arr = np.asarray(vals)
                assert np.all(arr == float(K)), f"torn read: {arr}"
                s = engine.stats()
                assert s.version == v0, \
                    f"failed install changed version: {v0} -> {s.version}"
        except Exception as e:      # noqa: BLE001
            errors.append((rid, e))

    threads = [threading.Thread(target=reader, args=(r,)) for r in range(3)]
    for t in threads:
        t.start()
    try:
        for round_ in range(1, 9):
            hook(round_, _ExplodingState())     # must not raise
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
    assert not any(t.is_alive() for t in threads), "reader threads hung"
    assert errors == [], errors

    stats = engine.stats()
    assert stats.version == v0 and stats.installs == 0
    assert stats.publish_failures == 8 * 2     # 8 rounds x (1 try + 1 retry)
    text = engine.metrics()
    assert re.search(r"frs_serve_publish_failures_total 16(\.0)?$",
                     text, re.MULTILINE)
    assert re.search(r"frs_serve_publish_retries_total 8(\.0)?$",
                     text, re.MULTILINE)

    # recovery: a good swap after the failure storm goes fully live
    engine.swap(_fill_model(5))
    vals, _ = engine.recommend(jnp.ones((2, K), jnp.float32))
    assert np.all(np.asarray(vals) == 6.0 * K)
    assert engine.stats().version == v0 + 1


def test_bounded_queue_sheds_and_recovers():
    """max_inflight=1 + a blocked in-flight read => concurrent requests
    shed with reason='queue'; the slot frees on completion."""
    base = _fill_model(0)
    entered, release = threading.Event(), threading.Event()

    class _SlowModel:
        version = base.version

        def topn(self, p, n, train_mask=None, block_m=None):
            entered.set()
            release.wait(30)
            return base.topn(p, n, train_mask=train_mask, block_m=block_m)

        def resident_bytes(self):
            return 0

    engine = ServingEngine(base, buckets=(4,), top_n=TOP_N, block_m=32,
                           max_inflight=1)
    engine._model = _SlowModel()
    p = jnp.ones((2, K), jnp.float32)
    t = threading.Thread(target=lambda: engine.recommend(p))
    t.start()
    assert entered.wait(30), "in-flight request never started"
    try:
        with np.testing.assert_raises(LoadShedError):
            engine.recommend(p)
    finally:
        release.set()
        t.join(timeout=30)
    assert not t.is_alive()
    engine._model = base
    engine.recommend(p)                 # slot freed: admitted again
    stats = engine.stats()
    assert stats.shed == 1 and stats.requests == 2
    assert 'frs_serve_shed_total{reason="queue"} 1' in engine.metrics()
