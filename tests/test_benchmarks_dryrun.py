"""Benchmarks can't silently rot: every benchmarks/*.py module must expose
``main(argv)`` with a fast ``--dry-run`` smoke mode, and the smoke must
actually run. (The orchestrator ``benchmarks.run --dry-run`` chains them;
here each module is driven directly so a failure names the culprit.)"""
import importlib
import pathlib
import sys

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

# every CLI benchmark module (common.py is shared plumbing, not a CLI)
BENCH_MODULES = sorted(
    p.stem for p in (REPO_ROOT / "benchmarks").glob("*.py")
    if p.stem not in ("common", "__init__", "run")
)


def test_module_list_is_nonempty_and_current():
    assert "payload_compression" in BENCH_MODULES
    assert "round_engine" in BENCH_MODULES


@pytest.mark.parametrize("name", BENCH_MODULES)
def test_benchmark_dry_run(name, capsys):
    mod = importlib.import_module(f"benchmarks.{name}")
    assert hasattr(mod, "main"), f"benchmarks/{name}.py must expose main()"
    out = mod.main(["--dry-run"])
    # dry-runs return a summary (dict/list) and print a visible marker
    assert out is not None
    captured = capsys.readouterr().out
    assert captured.strip(), f"{name} --dry-run printed nothing"


def test_orchestrator_dry_run(capsys):
    mod = importlib.import_module("benchmarks.run")
    mod.main(["--dry-run"])
    captured = capsys.readouterr().out
    assert "all sections smoke-checked" in captured
