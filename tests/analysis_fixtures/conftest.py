# fixture corpus for tests/test_analysis.py: every file below deliberately
# violates (or deliberately satisfies) one lint rule. Never collected as
# tests, never linted by the repo run (DEFAULT_EXCLUDES skips
# "analysis_fixtures").
collect_ignore_glob = ["*"]
