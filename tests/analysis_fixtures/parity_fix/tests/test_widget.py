"""Fixture parity test: mentions covered_op AND covered_op_ref only."""
from repro.kernels import ref
from repro.kernels.widget import covered_op


def test_covered_op_matches_ref():
    assert covered_op(3) == ref.covered_op_ref(3)
