"""Fixture: kernel-parity — covered, oracle-less, and untested kernels."""

PARITY_ORACLES = {"unmapped_op": "shared_ref"}


def covered_op(x):
    return x + 1


def uncovered_op(x):                   # L10: no `uncovered_op_ref` oracle
    return x * 2


def unmapped_op(x):                    # L14: oracle exists, no test pairs them
    return x - 1


def _private_helper(x):                # fine: private
    return x
