"""Fixture oracles for widget.py."""


def covered_op_ref(x):
    return x + 1


def shared_ref(x):
    return x - 1
