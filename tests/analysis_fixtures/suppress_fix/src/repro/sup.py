"""Fixture: inline suppression mechanics."""
import numpy as np

A = np.float64(1.0)  # repro-lint: disable=dtype-width -- fixture: silenced
# repro-lint: disable=dtype-width -- comment-above form
B = np.float64(2.0)
C = np.float64(3.0)                    # L7: NOT suppressed — must fire
D = np.float64(4.0)  # repro-lint: disable=traced-purity -- wrong rule: fires
