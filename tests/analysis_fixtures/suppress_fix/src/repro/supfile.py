"""Fixture: whole-file suppression."""
# repro-lint: disable-file=dtype-width -- fixture: host-stats module
import numpy as np

A = np.float64(1.0)
B = np.float64(2.0)
