"""Fixture: traced-purity violations (every flagged line is deliberate)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

_CACHE = []
_T = 0


@jax.jit
def bad_step(x):
    t = time.time()                      # L15: host clock
    noise = np.random.rand(4)            # L16: host RNG
    _CACHE.append(x)                     # L17: free-variable mutation
    return x * t + jnp.sum(jnp.asarray(noise))


@jax.jit
def bad_global(x):
    global _T                            # L22: global declaration
    _T = 3
    return x


def driver(xs):
    # `chunk` is never decorated — it must be discovered as a traced root
    # because it is passed by name into lax.scan
    def chunk(c, x):
        time.sleep(0.0)                  # L31: host clock in scan body
        return c, x

    return jax.lax.scan(chunk, 0, xs)
