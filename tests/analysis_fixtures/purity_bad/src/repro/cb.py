"""Fixture: unsanctioned host callback (module-wide check)."""
from jax.experimental import io_callback


def leak(x):
    io_callback(print, None, x)          # L6: callback outside sanctioned mod
    return x
