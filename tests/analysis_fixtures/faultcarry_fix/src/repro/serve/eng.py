"""Fixture: fault-carry — except handlers in a degradation layer."""


class Engine:
    def __init__(self):
        self._publish_failures = 0
        self.last = None

    def good_counted(self, state):
        try:
            self.install(state)
        except Exception:
            self._publish_failures += 1    # fine: counter incremented

    def good_reraise(self, state):
        try:
            self.install(state)
        except ValueError:
            raise                          # fine: re-raised

    def bad_swallow(self, state):
        try:
            self.install(state)
        except Exception:                  # L24: swallowed, uncounted
            self.last = state

    def install(self, state):
        self._model = state
