"""Fixture: fault-carry — carry-pure schedule module with violations."""

ROUND_BANDS = (0.1, 0.2)               # fine: immutable module constant

_pending = []                          # L5: module-level mutable list
_by_round = {}                         # L6: module-level mutable dict
_seen = set()                          # L7: constructor call


def record(t):
    global _counter                    # L11: global declaration
    _counter = t


def build(rounds):
    local = []                         # fine: function-local state
    for t in range(rounds):
        local.append(t)
    return tuple(local)
