"""Fixture: lock-guard — one compliant and two violating methods."""
import threading


class Engine:
    def __init__(self):
        self._lock = threading.Lock()
        self._model = None
        self._count = 0
        self.block = 8                 # init-frozen config: never guarded

    def swap(self, model):
        with self._lock:
            self._model = model        # fine: under the lock
            self._count += 1           # fine: under the lock

    def bad_swap(self, model):
        self._model = model            # L18: write outside lock

    def peek(self):
        return self._model             # L21: read outside lock

    def geometry(self):
        return self.block              # fine: init-frozen attribute


class NoLocks:
    def __init__(self):
        self.x = 0

    def bump(self):
        self.x += 1                    # fine: class owns no lock
