"""Fixture: pytree-carry rule — clean and violating carry NamedTuples."""
from typing import Any, Callable, Dict, NamedTuple, Optional, Union

import jax


class GoodState(NamedTuple):
    q: jax.Array
    codec: Any = ()
    extra: Optional[jax.Array] = None
    table: Dict[str, jax.Array] = {}


class InnerBuf(NamedTuple):          # reached transitively via NestState
    vals: jax.Array
    count: int                       # L16: scalar leaf, found via closure


class NestState(NamedTuple):
    buf: InnerBuf
    more: "jax.Array"                # string annotation: fine


class BadState(NamedTuple):
    q: jax.Array
    num_rounds: int                  # L25: scalar field
    hook: Callable                   # L26: callable field
    note: str                        # L27: scalar field


AliasState = Union[GoodState, BadState]


class NotACarry(NamedTuple):
    anything: int                    # fine: not *State/*Wire, not referenced
