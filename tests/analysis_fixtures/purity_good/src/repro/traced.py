"""Fixture: pure traced code the purity rule must stay silent on."""
import jax
import jax.numpy as jnp


@jax.jit
def good_step(x):
    parts = []
    for i in range(3):
        parts.append(x * i)              # local container: fine
    key = jax.random.PRNGKey(0)          # traced RNG: fine
    return jnp.stack(parts).sum() + jax.random.normal(key, ())


def driver(xs):
    def chunk(c, x):
        acc = {}
        acc["y"] = c + x                 # local dict: fine
        return acc["y"], x

    return jax.lax.scan(chunk, jnp.zeros(()), xs)
