"""Fixture: the sanctioned batched-telemetry module may host io_callback."""
from jax.experimental import io_callback


def emit(rows, emitter):
    io_callback(emitter, None, rows, ordered=True)   # sanctioned here
    return rows
