"""Fixture: dtype-width host/traced scope split in one driver module."""
import jax
import numpy as np


@jax.jit
def traced(x):
    w = np.ones((3,))                        # L8: bare constructor (traced)
    return x * w


def summarize(vals):
    arr = np.asarray(vals)                   # fine: host scope
    return float(arr.mean())
