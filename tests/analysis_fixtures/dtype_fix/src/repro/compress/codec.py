"""Fixture: dtype-width in a strict (wire-format) module."""
import numpy as np


def encode_rows(rows):
    scale = np.array([1.0])                  # L6: bare constructor (strict)
    wide = np.zeros((4,), np.float64)        # L7: .float64 reference
    out = np.asarray(rows, dtype=float)      # L8: dtype=float
    ok = np.zeros((4,), dtype=np.int32)      # fine: explicit 32-bit
    return scale, wide, out, ok
