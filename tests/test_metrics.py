"""Tests for the normalized recommendation metrics (Sec. 6.2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cf.metrics import ranked_metrics, theoretical_best
from repro.cf.toplist import evaluate_toplist, toplist_ranking


def test_perfect_ranking_scores_one():
    """Scoring exactly the test items highest => all normalized metrics = 1."""
    m = 50
    train = np.zeros((2, m), np.float32)
    test = np.zeros((2, m), np.float32)
    test[0, :4] = 1          # user 0: 4 test items
    test[1, 10:25] = 1       # user 1: 15 test items
    scores = test + 0.5      # test items strictly highest
    got = ranked_metrics(jnp.asarray(scores), jnp.asarray(train), jnp.asarray(test))
    for v in got.as_dict().values():
        assert v == pytest.approx(1.0, abs=1e-5)


def test_train_items_are_excluded_from_ranking():
    m = 20
    train = np.zeros((1, m), np.float32)
    test = np.zeros((1, m), np.float32)
    train[0, :10] = 1
    test[0, 10:12] = 1
    scores = np.zeros((1, m), np.float32)
    scores[0, :10] = 100.0     # train items score huge but must be masked
    scores[0, 10:12] = 1.0
    got = ranked_metrics(jnp.asarray(scores), jnp.asarray(train), jnp.asarray(test))
    assert got.precision == pytest.approx(1.0, abs=1e-5)


def test_theoretical_best_formulas():
    best = theoretical_best(jnp.asarray([0.0, 3.0, 10.0, 40.0]), top_k=10)
    np.testing.assert_allclose(best.precision, [0.0, 0.3, 1.0, 1.0], atol=1e-6)
    np.testing.assert_allclose(best.recall, [0.0, 1.0, 1.0, 0.25], atol=1e-6)
    np.testing.assert_allclose(best.map, [0.0, 1.0, 1.0, 1.0], atol=1e-6)


def test_empty_test_users_do_not_contribute():
    m = 30
    train = np.zeros((2, m), np.float32)
    test = np.zeros((2, m), np.float32)
    test[0, :5] = 1            # user 1 has an empty test set
    scores = np.asarray(test) + 0.1
    got = ranked_metrics(jnp.asarray(scores), jnp.asarray(train), jnp.asarray(test))
    assert got.precision == pytest.approx(1.0, abs=1e-5)  # only user 0 counts


def test_map_penalizes_late_hits():
    m = 30
    train = np.zeros((1, m), np.float32)
    test = np.zeros((1, m), np.float32)
    test[0, [0, 1]] = 1
    early = np.zeros((1, m), np.float32)
    early[0, 0], early[0, 1] = 10, 9          # hits at ranks 1,2
    late = np.zeros((1, m), np.float32)
    late[0, 0], late[0, 1] = 2, 1             # hits at ranks 9,10
    late[0, 2:10] = np.linspace(9, 3, 8)
    m_early = ranked_metrics(jnp.asarray(early), jnp.asarray(train), jnp.asarray(test))
    m_late = ranked_metrics(jnp.asarray(late), jnp.asarray(train), jnp.asarray(test))
    assert float(m_early.map) > float(m_late.map)
    assert float(m_early.precision) == pytest.approx(float(m_late.precision))


def test_toplist_ranks_by_popularity():
    counts = jnp.asarray([5.0, 100.0, 1.0, 50.0])
    idx = np.asarray(toplist_ranking(counts, list_len=4))
    np.testing.assert_array_equal(idx, [1, 3, 0, 2])


def test_toplist_evaluation_runs():
    rng = np.random.default_rng(0)
    n, m = 20, 40
    train = (rng.random((n, m)) < 0.3).astype(np.float32)
    test = ((rng.random((n, m)) < 0.1) * (1 - train)).astype(np.float32)
    counts = train.sum(0)
    got = evaluate_toplist(jnp.asarray(counts), jnp.asarray(train), jnp.asarray(test))
    for v in got.as_dict().values():
        assert 0.0 <= v <= 1.0
